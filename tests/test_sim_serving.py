"""repro.sim.serving: service-model exactness, queueing behaviour under
load, the SLO-constrained serving autotuner, and the BENCH_serving
schema round-trip."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro import api, sim
from repro.core import photonics


def _mlp_model():
    return api.build_model("mnist_mlp")  # shape-only; tiny forward workload


def _svc(n_buses=1, f_s=None):
    pcfg = photonics.PhotonicConfig(n_buses=n_buses)
    return sim.service_model(_mlp_model(), pcfg, f_s=f_s)


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------

def test_forward_workload_mlp():
    work = sim.forward_workload(_mlp_model(), t=3)
    assert [g.name for g in work] == ["h0", "h1", "head"]
    assert [(g.m, g.k) for g in work] == [(800, 784), (800, 800), (10, 800)]
    assert all(g.t == 3 for g in work)


def test_forward_workload_transformer():
    model = api.build_model("qwen1.5-0.5b")
    work = sim.forward_workload(model, t=1)
    # 24 layers x 7 projections (q,k,v,o,gate,up,down) + unembed
    assert len(work) == 24 * 7 + 1
    assert work[-1].name == "head.unembed"
    assert work[-1].k == model.cfg.d_model


# ---------------------------------------------------------------------------
# service model
# ---------------------------------------------------------------------------

def test_service_model_affine_is_exact():
    """wall(T) = a*T + b is an identity of the panel timeline, not a fit:
    the 2-point model reproduces the full simulator at any T."""
    svc = _svc()
    pcfg = photonics.PhotonicConfig()
    for t in (3, 7, 33):
        full = sim.simulate(sim.forward_workload(_mlp_model(), t), pcfg,
                            include_weight_update=False).wall_clock_s
        assert full == pytest.approx(svc.round_s(t), rel=1e-12)
    assert svc.round_s(0) == 0.0


def test_service_model_scales_with_buses():
    """More buses shorten the round (the per-token slope drops)."""
    a1, a4 = _svc(1).a, _svc(4).a
    assert a4 < a1


# ---------------------------------------------------------------------------
# request-level DES
# ---------------------------------------------------------------------------

def test_poisson_requests_statistics():
    reqs = sim.poisson_requests(100.0, 2000, prompt_len=8, decode_len=4,
                                seed=0)
    arr = np.array([r.arrival_s for r in reqs])
    assert len(reqs) == 2000 and np.all(np.diff(arr) >= 0)
    gaps = np.diff(np.concatenate([[0.0], arr]))
    assert np.mean(gaps) == pytest.approx(1e-2, rel=0.1)
    with pytest.raises(ValueError):
        sim.poisson_requests(0.0, 4)


def test_latency_monotone_in_offered_load():
    """Queueing: p99 end-to-end latency grows with the offered rate."""
    svc = _svc()
    cap = 1.0 / svc.round_s(1)
    p99 = []
    for frac in (0.05, 0.5, 5.0):
        reqs = sim.poisson_requests(frac * cap, 200, prompt_len=16,
                                    decode_len=8, seed=3)
        rep = sim.simulate_serving(reqs, svc, batch_slots=4, prefill_chunk=8)
        p99.append(rep.latency_p99_s)
        assert rep.ttft_p50_s <= rep.latency_p50_s
        assert rep.n_requests == 200 and rep.j_per_request > 0
    assert p99[0] < p99[1] < p99[2]


def test_serving_round_accounting():
    """One request, prompt S, chunk C: ceil(S/C) prefill rounds and
    decode_len - 1 decode rounds — mirroring the engine's tick counts."""
    svc = _svc()
    reqs = [sim.RequestSpec(arrival_s=0.0, prompt_len=9, decode_len=5)]
    rep = sim.simulate_serving(reqs, svc, batch_slots=4, prefill_chunk=4)
    assert rep.prefill_tokens == 9
    assert rep.decode_tokens == 4  # first token rides the prefill forward
    assert rep.rounds == 3 + 4
    # makespan is the sum of the round durations (single request, no idle)
    expect = (svc.round_s(4) * 2 + svc.round_s(1)) + 4 * svc.round_s(1)
    assert rep.makespan_s == pytest.approx(expect, rel=1e-12)


def test_serving_report_metrics_finite():
    svc = _svc()
    reqs = sim.poisson_requests(50.0, 64, prompt_len=8, decode_len=4, seed=1)
    rep = sim.simulate_serving(reqs, svc, batch_slots=8)
    m = rep.as_metrics("s_")
    assert all(np.isfinite(v) for v in m.values())
    assert m["s_requests_per_s"] > 0 and 0 < m["s_utilisation"] <= 1


# ---------------------------------------------------------------------------
# SLO autotuner
# ---------------------------------------------------------------------------

def test_autotune_serving_meets_slo_in_budget():
    model = _mlp_model()
    pcfg = photonics.PhotonicConfig()
    svc1 = sim.service_model(model, pcfg)
    cap = 1.0 / svc1.round_s(1)
    reqs = sim.poisson_requests(2.0 * cap, 64, prompt_len=16, decode_len=8,
                                seed=5)
    # SLO at half of what the overloaded single-bus default achieves
    default = sim.simulate_serving(reqs, svc1, batch_slots=8)
    budget = sim.bank_power_w(pcfg, n_buses=4)
    tuned = sim.autotune_serving(model, reqs, pcfg,
                                 slo_p99_s=0.5 * default.latency_p99_s,
                                 power_budget_w=budget,
                                 bus_counts=(1, 2, 4))
    assert tuned.report.latency_p99_s <= tuned.slo_p99_s
    assert tuned.power_w <= budget
    assert tuned.report.requests_per_s > default.requests_per_s
    # every in-budget candidate was actually simulated
    assert any(c.feasible and not c.meets_slo for c in tuned.candidates) or \
        all(c.meets_slo for c in tuned.candidates if c.feasible)
    # the tuned (n_buses, f_s) maps back onto hardware
    applied = tuned.apply(pcfg)
    assert applied.n_buses == tuned.n_buses and applied.f_s == tuned.f_s
    assert "p99" in tuned.describe()


def test_autotune_serving_raises_when_slo_unmeetable():
    model = _mlp_model()
    pcfg = photonics.PhotonicConfig()
    reqs = sim.poisson_requests(10.0, 16, prompt_len=16, decode_len=8, seed=2)
    with pytest.raises(ValueError, match="meets p99 SLO"):
        sim.autotune_serving(model, reqs, pcfg, slo_p99_s=1e-15,
                             bus_counts=(1, 2))


def test_autotune_serving_raises_when_budget_too_tight():
    model = _mlp_model()
    pcfg = photonics.PhotonicConfig()
    reqs = sim.poisson_requests(10.0, 16, prompt_len=16, decode_len=8, seed=2)
    with pytest.raises(ValueError, match="power_budget_w"):
        sim.autotune_serving(model, reqs, pcfg, slo_p99_s=10.0,
                             power_budget_w=1e-3, bus_counts=(1, 2))


# ---------------------------------------------------------------------------
# BENCH_serving schema round-trip
# ---------------------------------------------------------------------------

def test_bench_serving_round_trip(tmp_path):
    from benchmarks import serving as bench_serving
    from repro.bench import load_bench

    results = {
        "arch": "mnist_mlp", "capacity_req_per_s": 100.0,
        "sweep": [{
            "load_fraction": f, "offered_rate": f * 100, "requests_per_s": 90.0,
            "ttft_p50_ms": 1.0, "ttft_p99_ms": 2.0, "latency_p50_ms": 3.0,
            "latency_p99_ms": 4.0, "utilisation": 0.5, "power_w": 20.0,
            "j_per_request": 0.1} for f in (0.3, 0.6, 0.9)],
        "autotune": {
            "n_buses": 2, "f_s_ghz": 10.0, "batch_slots": 8, "power_w": 40.0,
            "power_budget_w": 80.0, "slo_p99_ms": 50.0, "p99_latency_ms": 20.0,
            "slo_margin_ms": 30.0, "requests_per_s": 200.0,
            "default_requests_per_s": 100.0, "default_p99_latency_ms": 100.0,
            "speedup_vs_default": 2.0, "j_per_request": 0.05},
    }
    path = bench_serving.write_report(results, str(tmp_path))
    r = load_bench(path)
    m = r["metrics"]
    for frac in (30, 60, 90):
        assert f"load{frac:02d}_latency_p99_ms" in m
        assert f"load{frac:02d}_requests_per_s" in m
        assert f"load{frac:02d}_j_per_request" in m
    assert m["auto_slo_margin_ms"] == 30.0
    assert m["auto_speedup_vs_default"] == 2.0


@pytest.mark.slow
def test_bench_serving_runs_real():
    """The full benchmark (real qwen workload) holds its acceptance shape:
    3 load rows + an autotune row that meets its SLO within budget and
    beats the default single-bus configuration on requests/s."""
    from benchmarks import serving as bench_serving

    results = bench_serving.run(n=48)
    assert len(results["sweep"]) == 3
    a = results["autotune"]
    assert a["slo_margin_ms"] >= 0
    assert a["power_w"] <= a["power_budget_w"]
    assert a["speedup_vs_default"] > 1.0