"""Elastic scaling: checkpoints are logical arrays — a snapshot taken under
one device layout restores under another (the re-shard happens at
device_put against the new mesh's NamedShardings)."""

import json
import os
import subprocess
import sys
import textwrap

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.utils.tree import tree_allclose


@hypothesis.given(
    shapes=st.lists(
        st.tuples(st.integers(1, 8), st.integers(1, 8)), min_size=1, max_size=5),
    dtype=st.sampled_from(["float32", "int32", "bfloat16"]),
    step=st.integers(0, 10**9),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_checkpoint_roundtrip_property(tmp_path_factory, shapes, dtype, step):
    tmp = tmp_path_factory.mktemp("ck")
    rng = np.random.default_rng(0)
    tree = {f"leaf{i}": jnp.asarray(rng.normal(size=s).astype("float32")).astype(dtype)
            for i, s in enumerate(shapes)}
    path = str(tmp / "c.msgpack")
    ckpt.save(path, tree, step=step)
    loaded, got_step = ckpt.load(path, template=tree)
    assert got_step == step
    assert tree_allclose(tree, loaded, rtol=0, atol=0)


_SUBPROC = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.dist import sharding
    from repro.train import checkpoint as ckpt

    path, mode = sys.argv[1], sys.argv[2]
    model = configs.get("qwen3-1.7b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    mesh = jax.make_mesh((%d, %d), ("data", "model"))
    sh = sharding.make_param_shardings(mesh, params)
    if mode == "save":
        placed = jax.tree_util.tree_map(jax.device_put, params, sh)
        ckpt.save(path, placed, step=7)
        print(json.dumps({"ok": True}))
    else:  # restore under THIS (different) mesh
        restored, step = ckpt.load(path, template=params, shardings=sh)
        loss, _ = model.loss(restored, {
            "tokens": jnp.zeros((4, 16), jnp.int32),
            "labels": jnp.ones((4, 16), jnp.int32)})
        print(json.dumps({"ok": True, "step": step, "loss": float(loss)}))
""")


@pytest.mark.slow
def test_elastic_restore_across_device_counts(tmp_path):
    """Save sharded on a (2,4)/8-device mesh; restore + run on (2,2)/4."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    path = str(tmp_path / "elastic.msgpack")

    save_src = _SUBPROC % (8, 2, 4)
    p1 = subprocess.run([sys.executable, "-c", save_src, path, "save"],
                        env=env, capture_output=True, text=True, timeout=600)
    assert p1.returncode == 0, p1.stderr[-1500:]

    load_src = _SUBPROC % (4, 2, 2)
    p2 = subprocess.run([sys.executable, "-c", load_src, path, "load"],
                        env=env, capture_output=True, text=True, timeout=600)
    assert p2.returncode == 0, p2.stderr[-1500:]
    out = json.loads(p2.stdout.strip().splitlines()[-1])
    assert out["step"] == 7
    assert np.isfinite(out["loss"])
