"""repro.sim: schedule replay fidelity, energy cross-check, autotuner
feasibility, and the BENCH_pipeline schema round-trip."""

from __future__ import annotations

import dataclasses

import pytest

from repro import api, sim
from repro.core import energy, photonics

QWEN_LAYERS = 24
QWEN_D = 1024


def _qwen_workload(t=64):
    model = api.build_model("qwen1.5-0.5b")  # shape-only, no params
    work = sim.dfa_backward_workload(model, t=t)
    assert len(work) == QWEN_LAYERS
    assert work[0].m == work[0].k == QWEN_D
    return work


# ---------------------------------------------------------------------------
# cycle-count identity with the static scheduling math
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_buses", [1, 2, 5])
@pytest.mark.parametrize("m,k", [(50, 20), (73, 61), (800, 10), (1024, 1024)])
def test_cycle_identity_with_gemm_cycles(n_buses, m, k):
    """The simulator's per-GEMM schedule length IS ``photonics.gemm_cycles``
    — both read the same tiling; indivisible panel counts included."""
    cfg = photonics.PhotonicConfig(n_buses=n_buses)
    g = sim.Gemm("g", t=1, m=m, k=k)
    r = sim.simulate([g], cfg, include_weight_update=False)
    assert r.cycles == photonics.gemm_cycles(m, k, cfg)
    assert r.cycles_per_gemm["g"] == photonics.gemm_cycles(m, k, cfg)
    # and the bus-cycle count is the emulator's own ceiling division
    nm, n_alive, nj, n_panels = sim.panel_schedule(g, cfg)
    assert n_alive == n_buses
    assert nj == photonics.n_bank_passes(k, cfg)
    assert n_panels == photonics.n_contraction_panels(k, cfg)


def test_panel_schedule_counts_real_panels():
    """Real slots across buses == nm × n_panels (idle-bus padding excluded
    from useful work, exactly as ``bank_product`` noise-masks it)."""
    cfg = photonics.PhotonicConfig(n_buses=2)
    g = sim.Gemm("g", t=4, m=73, k=61)  # 4 panels over 2 buses, nm=2
    nm, nb, nj, n_panels = sim.panel_schedule(g, cfg)
    real = nm * n_panels
    r = sim.simulate([g], cfg, include_weight_update=False)
    assert sum(r.bus_busy_s) == pytest.approx(
        real * g.t / cfg.f_s, rel=1e-9)


def test_failed_bus_lengthens_schedule():
    """Bus yield: panels reroute onto the survivors and the schedule
    stretches by the static model's own ceiling."""
    ok = photonics.PhotonicConfig(n_buses=4)
    degraded = dataclasses.replace(ok, failed_buses=(2,))
    g = sim.Gemm("g", t=8, m=200, k=400)  # 20 panels
    r_ok = sim.simulate([g], ok, include_weight_update=False)
    r_bad = sim.simulate([g], degraded, include_weight_update=False)
    assert r_bad.n_buses == 3
    assert r_ok.cycles == photonics.gemm_cycles(200, 400, ok)
    assert r_bad.cycles == photonics.gemm_cycles(200, 400, degraded)
    assert r_bad.wall_clock_s > r_ok.wall_clock_s


# ---------------------------------------------------------------------------
# energy cross-check against core/energy.py (Eq. 2/4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_buses", [1, 2, 4])
def test_energy_agrees_with_static_model(n_buses):
    """Simulated power × streaming makespan lands within 1% of the static
    Eq. 2/4 pricing (``energy.dfa_backward_cost``) on a deep workload —
    the cross-check is real: the sim integrates its event timeline (fills
    included), the static model multiplies cycle counts."""
    t = 64
    work = [sim.Gemm(f"l{i}", t=t, m=QWEN_D, k=QWEN_D)
            for i in range(QWEN_LAYERS)]
    pcfg = photonics.PhotonicConfig(n_buses=n_buses)
    ecfg = energy.EnergyConfig(n_buses=n_buses)
    r = sim.simulate(work, pcfg, ecfg, include_weight_update=False)
    static = energy.dfa_backward_cost([QWEN_D] * QWEN_LAYERS, QWEN_D, ecfg)
    assert r.energy_compute_j == pytest.approx(static["energy_j"] * t,
                                               rel=0.01)
    assert r.cycles == static["cycles"]
    assert r.power_w == pytest.approx(
        energy.total_power(pcfg.bank_rows, pcfg.bank_cols, ecfg), rel=1e-9)


def test_shared_comb_amortises_laser_power():
    """Satellite: one comb source across the buses — the Eq. 3 laser floor
    is paid once, every other Eq. 4 term stays per-bus."""
    per_bus = energy.EnergyConfig(n_buses=4)
    shared = dataclasses.replace(per_bus, shared_comb=True)
    single = energy.EnergyConfig(n_buses=1)
    saved = 3 * 20 * energy.laser_power(50, per_bus)  # 3 extra laser stacks
    assert energy.total_power(50, 20, shared) == pytest.approx(
        energy.total_power(50, 20, per_bus) - saved, rel=1e-12)
    # degenerate case: one bus — sharing changes nothing
    assert energy.total_power(50, 20, dataclasses.replace(
        single, shared_comb=True)) == energy.total_power(50, 20, single)
    # and E_op improves accordingly at 4 buses
    assert (energy.energy_per_op(50, 20, shared)
            < energy.energy_per_op(50, 20, per_bus))


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def test_autotune_respects_power_budget():
    work = _qwen_workload()
    pcfg = photonics.PhotonicConfig()
    budget = sim.bank_power_w(pcfg, n_buses=2)  # room for exactly 2 buses
    tuned = sim.autotune(work, pcfg, power_budget_w=budget)
    assert tuned.power_w <= budget
    assert tuned.n_buses <= 2
    for cand in tuned.candidates:
        if cand.feasible:
            assert cand.power_w <= budget


def test_autotune_beats_single_bus_default_on_qwen_backward():
    """Acceptance: the tuned schedule is strictly faster than the default
    n_buses=1 schedule on the qwen1.5-0.5b backward."""
    work = _qwen_workload()
    pcfg = photonics.PhotonicConfig()
    default = sim.simulate(work, pcfg)
    tuned = sim.autotune(work, pcfg,
                         power_budget_w=sim.bank_power_w(pcfg, n_buses=4))
    assert tuned.wall_clock_s < default.wall_clock_s
    assert tuned.n_buses > 1
    # and applying the schedule configures the session's hardware
    applied = tuned.apply(pcfg)
    assert applied.n_buses == tuned.n_buses
    assert applied.f_s == tuned.f_s


def test_autotune_infeasible_budget_raises():
    work = [sim.Gemm("g", t=1, m=50, k=20)]
    with pytest.raises(ValueError, match="no schedule fits"):
        sim.autotune(work, photonics.PhotonicConfig(), power_budget_w=0.1)


def test_build_session_schedule_auto():
    """api.build_session(schedule="auto") runs the tuner on the session's
    own model and configures the photonics accordingly."""
    session = api.build_session(arch="qwen1.5-0.5b", smoke=True,
                                schedule="auto", log_every=10**9)
    assert session.schedule is not None
    hw = session.config.dfa.photonics
    assert hw.n_buses == session.schedule.n_buses
    assert hw.f_s == session.schedule.f_s
    # a pinned bus count narrows the search instead of being overridden
    pinned = api.build_session(arch="mnist_mlp", smoke=True, n_buses=2,
                               schedule="auto", log_every=10**9)
    assert pinned.config.dfa.photonics.n_buses == 2
    with pytest.raises(ValueError, match="unknown schedule"):
        api.build_session(arch="mnist_mlp", smoke=True, schedule="fastest")


# ---------------------------------------------------------------------------
# report plumbing + BENCH_pipeline schema
# ---------------------------------------------------------------------------

def test_occupancy_and_utilisation_sane():
    r = sim.simulate(_qwen_workload(), photonics.PhotonicConfig(n_buses=2))
    assert 0.0 < r.utilisation <= 1.0
    for stage in sim.STAGES:
        assert 0.0 < r.occupancy[stage] <= 1.0
    assert r.weight_update_s > 0.0  # heater epilogue on by default
    assert r.wall_clock_s == pytest.approx(
        r.compute_s + r.weight_update_s)
    assert r.macs == sum(g.macs for g in _qwen_workload())


def test_bench_pipeline_schema_roundtrip(tmp_path):
    from benchmarks import pipeline_sim

    results = pipeline_sim.run(bus_counts=(1, 2), t=8)
    path = pipeline_sim.write_report(results, str(tmp_path))
    assert path.endswith("BENCH_pipeline.json")
    from repro.bench import load_bench

    report = load_bench(path)
    assert report["name"] == "pipeline"
    m = report["metrics"]
    assert m["qwen1_5_0_5b_b2_wall_us"] < m["qwen1_5_0_5b_b1_wall_us"]
    assert m["qwen1_5_0_5b_auto_speedup_vs_b1"] > 1.0


def test_autotune_prices_degraded_chip_honestly():
    """A chip with a failed bus is tuned AS the degraded chip: candidate
    schedules and power both see only the surviving buses, and the tuned
    config still carries the failure."""
    work = _qwen_workload(t=8)
    degraded = photonics.PhotonicConfig(n_buses=4, failed_buses=(1,))
    tuned = sim.autotune(work, degraded, bus_counts=(4,),
                         f_s_grid=(degraded.f_s,), tilings=("panel",))
    healthy3 = sim.simulate(
        work, photonics.PhotonicConfig(n_buses=3), tiling="panel")
    assert tuned.report.n_buses == 3
    assert tuned.wall_clock_s == pytest.approx(healthy3.wall_clock_s)
    assert tuned.power_w == pytest.approx(healthy3.power_w)
    assert tuned.apply(degraded).failed_buses == (1,)


def test_budget_kwargs_require_auto_schedule():
    with pytest.raises(ValueError, match="require schedule='auto'"):
        api.build_session(arch="mnist_mlp", smoke=True, power_budget_w=50.0)
    with pytest.raises(ValueError, match="require schedule='auto'"):
        api.build_session(arch="mnist_mlp", smoke=True, schedule_batch=32)


# ---------------------------------------------------------------------------
# measured-feedback loop (PR 7): digital overlap, recal cost, co-tuning
# ---------------------------------------------------------------------------

def test_simulate_digital_overlap():
    """The digital side overlaps the photonic timeline: wall clock is
    max(compute, digital) + epilogues, not their sum."""
    work = _qwen_workload(t=8)
    cfg = photonics.PhotonicConfig(n_buses=2)
    base = sim.simulate(work, cfg, tiling="panel")
    hidden = sim.simulate(work, cfg, tiling="panel",
                          digital_s=base.compute_s / 2)
    assert hidden.wall_clock_s == pytest.approx(base.wall_clock_s)
    dominating = sim.simulate(work, cfg, tiling="panel",
                              digital_s=10 * base.wall_clock_s)
    assert dominating.wall_clock_s > 9 * base.wall_clock_s
    assert dominating.digital_s == pytest.approx(10 * base.wall_clock_s)


def test_simulate_recalibration_amortised_cost():
    """recalibrate_every prices the heater sweep at 1/every per step."""
    work = _qwen_workload(t=8)
    cfg = photonics.PhotonicConfig(n_buses=2)
    base = sim.simulate(work, cfg, tiling="panel")
    recal = sim.simulate(work, cfg, tiling="panel", recalibrate_every=100)
    assert recal.recal_s > 0
    assert recal.wall_clock_s == pytest.approx(
        base.wall_clock_s + recal.recal_s)
    sparser = sim.simulate(work, cfg, tiling="panel", recalibrate_every=1000)
    assert sparser.recal_s == pytest.approx(recal.recal_s / 10)


def test_expected_drift_sigma_monotone():
    """OU residual: 0 with drift off, grows with the window, saturates at
    the stationary σ, floors at the calibration noise."""
    from repro.hardware import mrr

    device = mrr.MRRConfig()  # drift_sigma=0.05, tau=1000, cal_noise=0.005
    assert sim.expected_drift_sigma(None, 100) == 0.0
    assert sim.expected_drift_sigma(device, 0) == device.drift_sigma
    r100 = sim.expected_drift_sigma(device, 100)
    r1000 = sim.expected_drift_sigma(device, 1000)
    assert device.cal_noise < r100 < r1000 < device.drift_sigma


def test_autotune_co_optimises_recalibration():
    """Under a drift budget the tuner picks the sparsest cadence that
    holds the residual under budget (cheapest recal epilogue wins)."""
    work = _qwen_workload(t=8)
    cfg = photonics.PhotonicConfig(
        n_buses=2, mrr=__import__("repro.hardware.mrr",
                                  fromlist=["MRRConfig"]).MRRConfig())
    budget = 0.5 * cfg.mrr.drift_sigma
    tuned = sim.autotune(work, cfg, tilings=("panel",),
                         recal_candidates=sim.DEFAULT_RECAL_CANDIDATES,
                         drift_budget=budget)
    assert tuned.recalibrate_every > 0
    assert tuned.drift_resid <= budget
    # every sparser candidate in the grid must bust the budget
    for every in sim.DEFAULT_RECAL_CANDIDATES:
        if every == 0 or every <= tuned.recalibrate_every:
            continue
        assert sim.expected_drift_sigma(cfg.mrr, every) > budget
    assert f"recal@{tuned.recalibrate_every}" in tuned.describe()


def test_autotune_drift_budget_infeasible_raises():
    work = _qwen_workload(t=8)
    cfg = photonics.PhotonicConfig(
        n_buses=2, mrr=__import__("repro.hardware.mrr",
                                  fromlist=["MRRConfig"]).MRRConfig())
    with pytest.raises(ValueError, match="drift_budget"):
        sim.autotune(work, cfg, tilings=("panel",),
                     recal_candidates=(0, 1000),
                     drift_budget=1e-6)


def test_build_session_recalibrate_auto():
    """schedule='auto' + recalibrate_every='auto' lands the co-tuned
    cadence in the TrainerConfig; digital_step_s feeds the overlap."""
    session = api.build_session(arch="mnist_mlp", smoke=True,
                                backend="emu", hardware="emu_onchip",
                                schedule="auto", recalibrate_every="auto",
                                digital_step_s=1e-5)
    assert session.schedule is not None
    assert session.schedule.recalibrate_every > 0
    assert session.config.recalibrate_every == \
        session.schedule.recalibrate_every
    assert session.schedule.digital_s == pytest.approx(1e-5)


def test_digital_step_kwargs_require_auto_schedule():
    with pytest.raises(ValueError, match="require schedule='auto'"):
        api.build_session(arch="mnist_mlp", smoke=True, digital_step_s=1e-5)
    with pytest.raises(ValueError, match="require schedule='auto'"):
        api.build_session(arch="mnist_mlp", smoke=True,
                          recalibrate_every="auto")
