"""§Perf feature correctness: every optimisation must be semantics-
preserving (or its documented trade explicit)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, nn
from repro.core import dfa
from repro.models.mamba import MambaConfig, MambaLM
from repro.train.optimizer import SGDM
from repro.utils.tree import tree_allclose


def test_moe_gather_equals_einsum_dispatch():
    kwargs = dict(d_model=16, d_ff_expert=32, n_experts=4, top_k=2,
                  capacity_factor=8.0)
    p = nn.MoE(**kwargs).init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y1, a1 = nn.MoE(dispatch="einsum", **kwargs)(p, x)
    y2, a2 = nn.MoE(dispatch="gather", **kwargs)(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-6)
    for k in a1:
        np.testing.assert_allclose(float(a1[k]), float(a2[k]), rtol=1e-5)


def test_moe_gather_equals_einsum_with_drops():
    kwargs = dict(d_model=16, d_ff_expert=32, n_experts=4, top_k=2,
                  capacity_factor=0.5)  # forces token dropping
    p = nn.MoE(**kwargs).init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y1, a1 = nn.MoE(dispatch="einsum", **kwargs)(p, x)
    y2, a2 = nn.MoE(dispatch="gather", **kwargs)(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-6)
    assert float(a1["dropped_frac"]) == float(a2["dropped_frac"]) > 0


def test_mamba_split_proj_decode_parity():
    mb = nn.Mamba2Block(d_model=32, d_state=16, head_dim=16, chunk=8,
                        split_proj=True)
    p = mb.init(jax.random.PRNGKey(0))
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    full = mb(p, u)
    cache = mb.init_cache(2)
    outs = []
    for t in range(16):
        o, cache = mb.decode(p, u[:, t:t+1], cache, jnp.zeros((2,), jnp.int32) + t)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=1e-4, atol=2e-5)


def test_vocab_padding_loss_invariant_to_pad_columns():
    """Padded logits are masked to -inf — CE over real labels unaffected by
    the pad region's parameters."""
    cfg = dict(name="t", n_layers=2, d_model=32, vocab_size=100,
               d_state=16, head_dim=16, chunk=8)
    m = MambaLM(MambaConfig(pad_vocab_to=128, **cfg))
    p = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    loss1, _ = m.loss(p, batch)
    # perturb ONLY pad rows/cols
    p2 = jax.tree_util.tree_map(lambda x: x, p)
    p2["head"]["out"]["w"] = p["head"]["out"]["w"].at[:, 100:].add(7.0)
    loss2, _ = m.loss(p2, batch)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
    logits = m.head_logits(p, m.run_segments(p, m.embed(p, batch))[0], batch)
    assert logits.shape[-1] == 128
    assert float(logits[..., 100:].max()) < -1e29


def test_freeze_norms_zeroes_norm_grads_only():
    model = configs.get("qwen3-1.7b").make_smoke()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    cfg_f = dfa.DFAConfig(freeze_norms=True)
    fb = dfa.init_feedback(model, key, cfg_f)
    (_, _), g = dfa.value_and_grad(model, cfg_f)(params, fb, batch, key)
    # norm scales in blocks get exactly zero grads
    assert float(jnp.abs(g["blocks"]["norm1"]["scale"]).max()) == 0.0
    assert float(jnp.abs(g["blocks"]["norm2"]["scale"]).max()) == 0.0
    # non-norm params still train
    assert float(jnp.abs(g["blocks"]["attn"]["q"]["w"]).max()) > 0.0


def test_fused_train_step_matches_unfused_sgdm():
    from repro.models.mlp import MLPClassifier

    model = MLPClassifier(in_dim=12, hidden=(24, 16), n_classes=5)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    cfg = dfa.DFAConfig()
    fb = dfa.init_feedback(model, key, cfg)
    opt = SGDM(lr=0.05, momentum=0.9)
    opt_state = opt.init(params)
    batch = {"x": jax.random.normal(key, (8, 12)),
             "y": jax.random.randint(key, (8,), 0, 5)}
    rng = jax.random.PRNGKey(3)
    (_, _), grads = dfa.value_and_grad(model, cfg)(params, fb, batch, rng)
    pa, sa, _ = opt.update(grads, opt_state, params)
    pb, sb, _ = dfa.make_fused_train_step(model, cfg, opt)(
        params, fb, opt_state, batch, rng)
    assert tree_allclose(pa, pb, rtol=1e-5, atol=1e-7)
    assert tree_allclose(sa["mom"], sb["mom"], rtol=1e-5, atol=1e-7)


def test_opt_variants_instantiate_and_train():
    """Every arch with a make_opt variant still runs a DFA step (reduced
    via eval_shape for the big ones: structure check only)."""
    for name in configs.ASSIGNED:
        arch = configs.get(name)
        if arch.make_opt is None:
            continue
        model = arch.make_opt(jnp.bfloat16)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        assert len(jax.tree_util.tree_leaves(shapes)) > 0
