"""Data-parallel training path + bench telemetry.

The in-process tests exercise the sharded path whenever the test run has
more than one device (CI's XLA_FLAGS=--xla_force_host_platform_device_count=8
matrix job); the subprocess test forces 8 host devices so the equivalence
claim is checked even from a single-device tier-1 run.
"""

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.bench import report, telemetry
from repro.data.pipeline import DevicePrefetcher
from repro.dist import sharding

MULTI = jax.device_count() >= 2


def _batch(model, key, n=32):
    return {"x": jax.random.normal(key, (n, model.in_dim)),
            "y": jax.random.randint(key, (n,), 0, model.n_classes)}


def _max_diff(a, b):
    diffs = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)
    return max(jax.tree_util.tree_leaves(diffs))


# ---------------------------------------------------------------------------
# sharded vs single-device equivalence (runs under the 8-device CI job)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not MULTI, reason="needs >1 device (XLA_FLAGS force)")
@pytest.mark.parametrize("algo", ["bp", "dfa"])
def test_sharded_grads_match_single_device(algo):
    s_dp = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                             data_parallel=True, log_every=10**9)
    s_1d = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                             data_parallel=False, log_every=10**9)
    assert s_dp.mesh is not None and s_1d.mesh is None
    batch = _batch(s_1d.model, jax.random.PRNGKey(0),
                   n=8 * jax.device_count())
    rng = jax.random.PRNGKey(7)
    state = s_1d.init_state()

    (l1, _), g1 = jax.jit(s_1d.value_and_grad())(
        state["params"], state["fb"], batch, rng)

    mesh = s_dp.mesh
    with sharding.use_mesh(mesh):
        rep = sharding.replicate(mesh, {"p": state["params"], "fb": state["fb"]})
        db = sharding.put_batch(mesh, batch)
        assert db["x"].sharding.spec[0] is not None  # actually split on dim 0
        (l2, _), g2 = jax.jit(s_dp.value_and_grad())(
            rep["p"], rep["fb"], db, rng)

    assert abs(float(l1) - float(l2)) < 1e-5
    assert _max_diff(g1, g2) < 1e-5


@pytest.mark.skipif(not MULTI, reason="needs >1 device (XLA_FLAGS force)")
def test_data_parallel_fit_matches_single_device():
    batch = None
    states, losses = {}, {}
    for dp in (True, False):
        s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                              data_parallel=dp, log_every=10**9)
        if batch is None:
            batch = _batch(s.model, jax.random.PRNGKey(1),
                           n=8 * jax.device_count())
        state, metrics = s.fit(lambda step: batch, total_steps=4,
                               verbose=False)
        states[dp], losses[dp] = state, float(metrics["loss"])
    assert losses[True] == pytest.approx(losses[False], abs=1e-5)
    assert _max_diff(states[True]["params"], states[False]["params"]) < 1e-5


@pytest.mark.skipif(not MULTI, reason="needs >1 device (XLA_FLAGS force)")
def test_data_parallel_composes_with_microbatching():
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel=True, microbatches=2,
                          log_every=10**9)
    batch = _batch(s.model, jax.random.PRNGKey(2), n=8 * jax.device_count())
    state, metrics = s.fit(lambda step: batch, total_steps=2, verbose=False)
    assert int(state["step"]) == 2
    assert jnp.isfinite(metrics["loss"])


@pytest.mark.skipif(not MULTI, reason="needs >1 device (XLA_FLAGS force)")
def test_indivisible_batch_falls_back_to_replication():
    """Batch size not divisible by the device count must still train (the
    batch sharding falls back to replicated)."""
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel=True, log_every=10**9)
    n = 8 * jax.device_count() - 3
    batch = _batch(s.model, jax.random.PRNGKey(3), n=n)
    state, metrics = s.fit(lambda step: batch, total_steps=1, verbose=False)
    assert jnp.isfinite(metrics["loss"])


# ---------------------------------------------------------------------------
# mesh-less fallback
# ---------------------------------------------------------------------------

def test_meshless_fallback_still_trains():
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel=False, log_every=10**9)
    assert s.mesh is None
    batch = _batch(s.model, jax.random.PRNGKey(4), n=16)
    state, metrics = s.fit(lambda step: batch, total_steps=2, verbose=False)
    assert int(state["step"]) == 2
    assert jnp.isfinite(metrics["loss"])


def test_data_parallel_off_string_means_off():
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel="off", log_every=10**9)
    assert s.mesh is None
    with pytest.raises(ValueError, match="data_parallel"):
        api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel="bogus")


@pytest.mark.skipif(not MULTI, reason="needs >1 device (XLA_FLAGS force)")
def test_report_throughput_replication_fallback_multiplier_is_one(tmp_path):
    """Indivisible batch -> replication fallback -> per-device flops are
    full-batch flops, so MACs/s must NOT be multiplied by the mesh size."""
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel=True, log_every=10**9)
    n = 8 * jax.device_count() - 3
    batch = _batch(s.model, jax.random.PRNGKey(8), n=n)
    t = telemetry.StepTimer(warmup=report.clamped_warmup(2, 4))
    state, _ = s.fit(lambda step: batch, total_steps=2, verbose=False, timer=t)
    _, summary = report.report_throughput(
        s, state, batch, t, out_dir=str(tmp_path))
    assert summary["device_count"] == 1


def test_auto_resolves_by_device_count():
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel="auto", log_every=10**9)
    if jax.local_device_count() > 1:
        assert s.mesh is not None
        assert s.mesh.devices.size == jax.local_device_count()
    else:
        assert s.mesh is None


# ---------------------------------------------------------------------------
# subprocess: force 8 host devices from a single-device tier-1 run
# ---------------------------------------------------------------------------

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro import api
    from repro.dist import sharding

    out = {"devices": jax.device_count()}
    batch = None
    for algo in ("bp", "dfa"):
        s_dp = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                                 data_parallel=True, log_every=10**9)
        s_1d = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                                 data_parallel=False, log_every=10**9)
        if batch is None:
            key = jax.random.PRNGKey(0)
            batch = {"x": jax.random.normal(key, (64, s_1d.model.in_dim)),
                     "y": jax.random.randint(key, (64,), 0,
                                             s_1d.model.n_classes)}
        rng = jax.random.PRNGKey(7)
        state = s_1d.init_state()
        (l1, _), g1 = jax.jit(s_1d.value_and_grad())(
            state["params"], state["fb"], batch, rng)
        mesh = s_dp.mesh
        with sharding.use_mesh(mesh):
            rep = sharding.replicate(mesh, {"p": state["params"],
                                            "fb": state["fb"]})
            db = sharding.put_batch(mesh, batch)
            (l2, _), g2 = jax.jit(s_dp.value_and_grad())(
                rep["p"], rep["fb"], db, rng)
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
        out[algo] = {"loss_diff": abs(float(l1) - float(l2)),
                     "grad_diff": max(jax.tree_util.tree_leaves(diffs)),
                     "batch_split": str(db["x"].sharding.spec[0])}
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_equivalence_on_8_forced_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    for algo in ("bp", "dfa"):
        assert out[algo]["loss_diff"] < 1e-5
        assert out[algo]["grad_diff"] < 1e-5
        assert out[algo]["batch_split"] == "data"


# ---------------------------------------------------------------------------
# bench: schema round-trip + telemetry units
# ---------------------------------------------------------------------------

def test_bench_schema_round_trip(tmp_path):
    path = report.write_bench(
        "unit", {"steps_per_s": 12.5, "examples_per_s": 800.0},
        meta={"arch": "mnist_mlp"}, out_dir=str(tmp_path))
    assert os.path.basename(path) == "BENCH_unit.json"
    obj = report.load_bench(path)
    assert obj["schema"] == report.SCHEMA
    assert obj["metrics"]["steps_per_s"] == 12.5
    assert obj["env"]["device_count"] == jax.device_count()


@pytest.mark.parametrize("mutate", [
    lambda r: r.update(schema="repro.bench/v0"),
    lambda r: r.update(name=""),
    lambda r: r.update(metrics={}),
    lambda r: r["metrics"].update(bad=float("nan")),
    lambda r: r["metrics"].update(bad="fast"),
])
def test_bench_validate_rejects_drift(mutate):
    rep = report.make_report("unit", {"steps_per_s": 1.0})
    mutate(rep)
    with pytest.raises(ValueError):
        report.validate(rep)


def test_step_timer_derives_throughput():
    t = telemetry.StepTimer(warmup=2, examples_per_step=64)
    t.start()
    for _ in range(6):
        time.sleep(0.002)
        t.tick()
    assert t.recorded_steps == 4
    t.set_step_cost(flops_per_device=2e6, device_count=4)
    s = t.summary()
    assert s["steps_per_s"] > 0
    assert s["examples_per_s"] == pytest.approx(64 * s["steps_per_s"])
    assert s["macs_per_s"] == pytest.approx(s["steps_per_s"] * 1e6 * 4)
    assert s["mean_step_s"] >= 0.002


def test_step_timer_requires_measured_steps():
    t = telemetry.StepTimer(warmup=5)
    t.start()
    t.tick()
    with pytest.raises(ValueError):
        t.summary()


def test_device_prefetcher_double_buffers_and_limits():
    calls = []

    def data_fn(step):
        calls.append(step)
        return {"x": step}

    feed = DevicePrefetcher(data_fn, put_fn=lambda b: b, depth=2, limit=4)
    assert feed(0) == {"x": 0}
    assert calls == [0, 1, 2]       # depth=2: two steps prefetched ahead
    assert feed(1) == {"x": 1}
    assert calls == [0, 1, 2, 3]    # buffered batches reused, 3 enqueued
    assert feed(3) == {"x": 3}      # seek drops stale entries
    assert 4 not in calls           # limit stops the lookahead


def test_clamped_warmup_always_leaves_a_measured_step():
    assert report.clamped_warmup(32, 4) == 4
    assert report.clamped_warmup(2, 4) == 1
    assert report.clamped_warmup(1, 4) == 0
    assert report.clamped_warmup(0, 4) == 0


def test_report_throughput_uses_mesh_size_not_host_devices(tmp_path):
    """MACs/s must scale by the mesh the step is sharded over (1 without a
    mesh), never by the host device count — an un-sharded run on a
    multi-device host must not overcount."""
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          data_parallel=False, log_every=10**9)
    batch = _batch(s.model, jax.random.PRNGKey(6), n=16)
    t = telemetry.StepTimer(warmup=report.clamped_warmup(2, 4))
    state, _ = s.fit(lambda step: batch, total_steps=2, verbose=False, timer=t)
    path, summary = report.report_throughput(
        s, state, batch, t, meta={"arch": "mnist_mlp"}, out_dir=str(tmp_path))
    obj = report.load_bench(path)
    assert obj["meta"]["devices"] == 1
    assert obj["meta"]["data_parallel"] is False
    assert obj["metrics"]["macs_per_s"] == pytest.approx(
        summary["steps_per_s"] * summary["flops_per_step_per_device"] / 2.0)


def test_trainer_fit_with_timer_records_steps():
    s = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                          log_every=10**9)
    batch = _batch(s.model, jax.random.PRNGKey(5), n=16)
    t = telemetry.StepTimer(warmup=1)
    state, _ = s.fit(lambda step: batch, total_steps=4, verbose=False,
                     timer=t)
    assert t.recorded_steps == 3
    assert t.examples_per_step == 16
    cost = s.step_cost(state, batch)
    assert cost.flops > 0
    t.set_step_cost(cost.flops)
    assert t.summary()["macs_per_s"] > 0
