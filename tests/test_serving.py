"""Serving: decode-vs-forward parity, engine batched generation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.serve import Engine, Request
from repro.serve.decode import make_prefill, make_serve_step


def test_decode_matches_forward_logits():
    """Greedy decode over a teacher-forced prompt reproduces the parallel
    forward's logits at every position."""
    model = configs.get("qwen3-1.7b").make_smoke()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 8), 0, 128)
    full_logits = make_prefill(model)(params, {"tokens": toks})
    caches = model.init_caches(2, 16)
    cl = jnp.zeros((2,), jnp.int32)
    outs = []
    for t in range(8):
        logits, caches = model.decode_step(params, toks[:, t : t + 1], caches, cl + t)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_engine_serves_more_requests_than_slots():
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=2, max_len=32)
    reqs = [Request(prompt=[i + 1], max_new=4) for i in range(5)]
    done, ticks = eng.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 4 for r in done)
    assert ticks < 60


def test_engine_deterministic():
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = Engine(model, params, batch_slots=2, max_len=32)
        reqs = [Request(prompt=[3, 5], max_new=6)]
        eng.run(reqs)
        outs.append(tuple(reqs[0].out))
    assert outs[0] == outs[1]


def test_serve_step_builder():
    model = configs.get("mamba2-130m").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_serve_step(model))
    caches = model.init_caches(2, 8)
    tok = jnp.ones((2, 1), jnp.int32)
    nxt, logits, caches2 = step(params, tok, caches, jnp.zeros((2,), jnp.int32))
    assert nxt.shape == (2, 1) and nxt.dtype == jnp.int32
    assert logits.shape[-1] == 128
