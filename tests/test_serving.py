"""Serving: decode-vs-forward parity, engine batched generation, chunked
prefill, photonic-backend inference, request lifecycle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import photonics as ph
from repro.hardware.mrr import MRRConfig
from repro.serve import DONE, Engine, Request
from repro.serve.decode import make_prefill, make_serve_step


def _serve(model, params, prompt, max_new=5, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 48)
    eng = Engine(model, params, **kw)
    reqs = [Request(prompt=list(prompt), max_new=max_new)]
    eng.run(reqs)
    return reqs[0], eng


def test_decode_matches_forward_logits():
    """Greedy decode over a teacher-forced prompt reproduces the parallel
    forward's logits at every position."""
    model = configs.get("qwen3-1.7b").make_smoke()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 8), 0, 128)
    full_logits = make_prefill(model)(params, {"tokens": toks})
    caches = model.init_caches(2, 16)
    cl = jnp.zeros((2,), jnp.int32)
    outs = []
    for t in range(8):
        logits, caches = model.decode_step(params, toks[:, t : t + 1], caches, cl + t)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_engine_serves_more_requests_than_slots():
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=2, max_len=32)
    reqs = [Request(prompt=[i + 1], max_new=4) for i in range(5)]
    done, ticks = eng.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 4 for r in done)
    assert ticks < 60


def test_engine_deterministic():
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = Engine(model, params, batch_slots=2, max_len=32)
        reqs = [Request(prompt=[3, 5], max_new=6)]
        eng.run(reqs)
        outs.append(tuple(reqs[0].out))
    assert outs[0] == outs[1]


def test_serve_step_builder():
    model = configs.get("mamba2-130m").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_serve_step(model))
    caches = model.init_caches(2, 8)
    tok = jnp.ones((2, 1), jnp.int32)
    nxt, logits, caches2 = step(params, tok, caches, jnp.zeros((2,), jnp.int32))
    assert nxt.shape == (2, 1) and nxt.dtype == jnp.int32
    assert logits.shape[-1] == 128


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

def test_prefill_tick_counts():
    """A length-S prompt fills in ceil(S/chunk) batched forwards; the first
    token falls out of the final prefill forward, so decode runs N-1 steps."""
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    s, chunk, n = 9, 4, 5
    prompt = [(7 * i + 3) % 100 for i in range(s)]
    req, eng = _serve(model, params, prompt, max_new=n, prefill_chunk=chunk)
    assert req.done and len(req.out) == n
    assert eng.stats["prefill_steps"] == -(-s // chunk) == 3
    assert eng.stats["prefill_tokens"] == s
    assert eng.stats["decode_steps"] == n - 1


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-130m"])
def test_prefill_chunk_parity(arch):
    """Chunked prefill is numerically the same computation as token-by-token
    cache filling: greedy outputs match across chunk sizes (the parallel
    scatter path for attention models, the masked decode-scan for SSMs)."""
    model = configs.get(arch).make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    prompt = [(5 * i + 2) % 64 for i in range(7)]
    outs = [
        _serve(model, params, prompt, prefill_chunk=c)[0].out for c in (4, 1)
    ]
    assert outs[0] == outs[1]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["minicpm3-4b", "recurrentgemma-9b"])
def test_prefill_chunk_parity_slow_archs(arch):
    """MLA absorbed-form prefill and the windowed ring-buffer scan fallback."""
    model = configs.get(arch).make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    prompt = [(5 * i + 2) % 64 for i in range(7)]
    outs = [
        _serve(model, params, prompt, prefill_chunk=c)[0].out for c in (3, 1)
    ]
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# request lifecycle / scheduler regressions
# ---------------------------------------------------------------------------

def test_submit_rejects_bad_requests():
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=2, max_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(prompt=[], max_new=4))
    with pytest.raises(ValueError, match="does not fit"):
        eng.submit(Request(prompt=list(range(16)), max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(prompt=[1], max_new=0))


def test_dead_slots_cost_no_decode_work():
    """Once a request finishes, its slot stops contributing decode steps:
    serving a short and a long request together costs exactly as many
    decode forwards as the long request alone (finished slots are masked,
    not fed stale tokens)."""
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    long_alone, eng_alone = _serve(model, params, [3, 5], max_new=10)
    eng = Engine(model, params, batch_slots=2, max_len=48)
    short = Request(prompt=[7, 11], max_new=2)
    long = Request(prompt=[3, 5], max_new=10)
    eng.run([short, long])
    assert short.done and long.done
    assert len(short.out) == 2 and len(long.out) == 10
    # the shared pool runs the same number of decode forwards as the long
    # request alone — the dead slot adds zero ticks
    assert eng.stats["decode_steps"] == eng_alone.stats["decode_steps"]
    # and masking preserves the long request's tokens exactly
    assert long.out == long_alone.out


def test_finish_at_max_len_is_single_transition():
    """A request that hits the cache ceiling finishes exactly once, with
    output truncated to what fit (the seed engine double-marked here)."""
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    req, eng = _serve(model, params, [3, 5], max_new=50, max_len=8,
                      prefill_chunk=4)
    assert req.state == DONE and req.done
    # prompt fills 2 positions; decode writes until cache_len == max_len:
    # first token from prefill + 6 decode tokens
    assert len(req.out) == 1 + (8 - 2)
    assert eng.stats["decode_steps"] == 6


def test_request_timestamps_ordered():
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    req, _ = _serve(model, params, [2, 4, 6], max_new=4)
    assert req.submit_s <= req.first_token_s <= req.finish_s
    assert req.ttft_s >= 0 and req.latency_s >= req.ttft_s


# ---------------------------------------------------------------------------
# photonic backends
# ---------------------------------------------------------------------------

def _emu_ideal_cfg():
    return dataclasses.replace(ph.PRESETS["emu_ideal"], mrr=MRRConfig.ideal())


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-130m"])
def test_emu_ideal_serving_matches_digital(arch):
    """Greedy serving through the ideal emulated MRR bank (and the ref
    photonic backend) is token-for-token identical to the digital engine —
    the serving analogue of the backend-equivalence tests."""
    model = configs.get(arch).make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    prompt = [(7 * i + 3) % 64 for i in range(6)]
    digital = _serve(model, params, prompt, prefill_chunk=4)[0].out
    cfg = _emu_ideal_cfg()
    for backend in ("emu", "ref"):
        out = _serve(model, params, prompt, prefill_chunk=4,
                     backend=backend, photonics=cfg)[0].out
        assert out == digital, backend


def test_drifted_emu_serving_terminates_finite():
    """A drifting device (nonzero residual detuning) still serves to
    completion with real token ids — inference inherits the hardware
    imperfection without NaN/Inf fallout."""
    from repro.hardware import drift

    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    cfg = dataclasses.replace(ph.PRESETS["emu_onchip"], mrr=MRRConfig())
    state = drift.init_state(cfg)
    state["drift"] = 0.2 * jax.random.normal(jax.random.PRNGKey(7),
                                             state["drift"].shape)
    req, eng = _serve(model, params, [3, 5, 7], max_new=6, backend="emu",
                      photonics=cfg, hw_state=state, seed=3)
    assert req.done and len(req.out) == 6
    vocab = model.cfg.vocab_size
    assert all(0 <= t < vocab for t in req.out)


def test_session_engine_round_trip():
    """api.build_session -> Session.engine serves on the session's cell."""
    from repro import api

    session = api.build_session(arch="qwen1.5-0.5b", algo="bp",
                                hardware="digital", smoke=True)
    eng = session.engine(batch_slots=2, max_len=32, prefill_chunk=4)
    reqs = [Request(prompt=[1, 2, 3], max_new=4) for _ in range(3)]
    done, _ticks = eng.run(reqs)
    assert all(r.done and len(r.out) == 4 for r in done)
