"""Paper §6 claim: "the DFA algorithm is particularly well suited for
implementations with analog hardware as the gradient vector is calculated by
propagating the error through fixed random feedback connections directly
from the output layer to each hidden layer, which is advantageous as noise
does not accumulate between layers — unlike the backpropagation algorithm,
where the error is back-propagated layer by layer."

Test: per-layer gradient SNR under analog noise is depth-INDEPENDENT for
DFA (each layer gets one noisy B(k)e product), whereas a noisy chained
backward accumulates noise with depth.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dfa, photonics
from repro.models.mlp import MLPClassifier

DEPTH = 6


def _grad_snr_per_layer(noise_std: float, n_trials: int = 8):
    """SNR of DFA hidden-layer grads vs the noiseless DFA grads."""
    model = MLPClassifier(in_dim=16, hidden=(32,) * DEPTH, n_classes=5)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    clean_cfg = dfa.DFAConfig()
    fb = dfa.init_feedback(model, key, clean_cfg)
    batch = {"x": jax.random.normal(key, (32, 16)),
             "y": jax.random.randint(key, (32,), 0, 5)}
    (_, _), g_clean = dfa.value_and_grad(model, clean_cfg)(params, fb, batch, key)

    noisy_cfg = dfa.DFAConfig(
        photonics=photonics.PhotonicConfig(noise_std=noise_std))
    vg = jax.jit(dfa.value_and_grad(model, noisy_cfg))
    err_power = {f"h{i}": 0.0 for i in range(DEPTH)}
    sig_power = {f"h{i}": float(jnp.sum(jnp.square(g_clean[f"h{i}"]["w"])))
                 for i in range(DEPTH)}
    for t in range(n_trials):
        (_, _), g = vg(params, fb, batch, jax.random.PRNGKey(100 + t))
        for i in range(DEPTH):
            d = g[f"h{i}"]["w"] - g_clean[f"h{i}"]["w"]
            err_power[f"h{i}"] += float(jnp.sum(jnp.square(d))) / n_trials
    return [sig_power[f"h{i}"] / max(err_power[f"h{i}"], 1e-30)
            for i in range(DEPTH)]


def test_dfa_gradient_snr_depth_independent():
    snrs = _grad_snr_per_layer(noise_std=0.098)
    # exclude the first layer (different fan-in) and compare the rest:
    # depth-independence ⇒ max/min SNR ratio stays O(1) across 5 layers
    rest = snrs[1:]
    ratio = max(rest) / min(rest)
    assert ratio < 8.0, f"SNR varies {ratio:.1f}x across depth: {snrs}"
    # and every layer retains usable signal
    assert min(snrs) > 0.5


def test_chained_noise_accumulates_with_depth():
    """Contrast case: inject the same per-product noise into a CHAINED
    (backprop-style) error propagation — SNR degrades with depth."""
    key = jax.random.PRNGKey(1)
    d, depth = 32, DEPTH
    ws = [jax.random.normal(jax.random.fold_in(key, i), (d, d)) / np.sqrt(d)
          for i in range(depth)]
    e0 = jax.random.normal(jax.random.fold_in(key, 99), (d,))

    def chain(noise_key, sigma):
        outs = []
        e = e0
        for i, w in enumerate(ws):
            e = w @ e
            e = e + sigma * float(jnp.max(jnp.abs(e))) * jax.random.normal(
                jax.random.fold_in(noise_key, i), e.shape)
            outs.append(e)
        return outs

    clean = chain(jax.random.PRNGKey(0), 0.0)
    snrs = []
    for layer in range(depth):
        sig = float(jnp.sum(jnp.square(clean[layer])))
        errp = 0.0
        for t in range(8):
            noisy = chain(jax.random.PRNGKey(10 + t), 0.098)
            errp += float(jnp.sum(jnp.square(noisy[layer] - clean[layer]))) / 8
        snrs.append(sig / max(errp, 1e-30))
    # noise accumulates: deepest layer is markedly worse than the first
    assert snrs[-1] < snrs[0] / 2, snrs
