"""Photonic execution model: noise calibration, GeMM tiling, quantization."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import photonics


def test_effective_bits_match_paper():
    # Fig. 3(c) and Fig. 5(a): log2(2/σ)
    assert abs(photonics.std_to_bits(0.019) - 6.72) < 0.01
    assert abs(photonics.std_to_bits(0.098) - 4.35) < 0.01
    assert abs(photonics.std_to_bits(0.202) - 3.31) < 0.01
    for bits in [3.31, 4.35, 6.72, 8.0]:
        assert abs(photonics.std_to_bits(photonics.bits_to_std(bits)) - bits) < 1e-9


@hypothesis.given(bits=st.floats(0.25, 40.0))
@hypothesis.settings(max_examples=50, deadline=None)
def test_resolution_sigma_round_trip_is_exact(bits):
    """resolution_to_sigma / sigma_to_resolution are inverses to float
    precision (computed via 1 - log2(σ), no division rounding) — and
    PhotonicConfig.effective_bits is the same function."""
    sigma = photonics.resolution_to_sigma(bits)
    assert abs(photonics.sigma_to_resolution(sigma) - bits) < 1e-9
    cfg = photonics.PhotonicConfig(noise_std=sigma)
    assert abs(cfg.effective_bits - bits) < 1e-9


@hypothesis.given(sigma=st.floats(1e-9, 2.0))
@hypothesis.settings(max_examples=50, deadline=None)
def test_sigma_resolution_round_trip_is_exact(sigma):
    bits = photonics.sigma_to_resolution(sigma)
    back = photonics.resolution_to_sigma(bits)
    assert abs(back - sigma) <= 1e-12 * sigma


def test_resolution_degenerate_cases():
    assert photonics.sigma_to_resolution(0.0) == float("inf")
    assert photonics.PhotonicConfig(noise_std=0.0).effective_bits == float("inf")


def test_gemm_cycles_paper_mlp():
    """800×10 matvec on the 50×20 bank: ceil(800/50)·ceil(10/20) = 16."""
    cfg = photonics.PhotonicConfig()
    assert photonics.gemm_cycles(800, 10, cfg) == 16
    assert photonics.n_bank_passes(10, cfg) == 1
    assert photonics.n_bank_passes(40, cfg) == 2


def test_noiseless_is_exact():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (32, 24))
    b = jax.random.normal(jax.random.fold_in(key, 1), (48, 24))
    out = photonics.photonic_matmul(a, b, photonics.preset("ideal"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b.T), rtol=1e-5, atol=1e-5)


def test_disabled_bypasses_everything():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (8, 4))
    b = jax.random.normal(key, (6, 4))
    out = photonics.photonic_matmul(a, b, photonics.preset("digital"), key=key)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a @ b.T))


@pytest.mark.parametrize("convention,expect_mult", [("absolute", 1.0), ("fullscale", 20.0)])
def test_noise_conventions(convention, expect_mult):
    cfg = photonics.PhotonicConfig(noise_std=0.1, noise_convention=convention)
    sigma = photonics.noise_sigma_total(20, 1.0, 1.0, cfg)  # one bank pass
    assert abs(sigma - 0.1 * expect_mult) < 1e-9


def test_noise_accumulates_sqrt_passes():
    cfg = photonics.PhotonicConfig(noise_std=0.1)
    s1 = photonics.noise_sigma_total(20, 1.0, 1.0, cfg)
    s4 = photonics.noise_sigma_total(80, 1.0, 1.0, cfg)  # 4 passes
    assert abs(s4 / s1 - 2.0) < 1e-9


def test_empirical_noise_std_calibrated():
    cfg = photonics.preset("offchip_bpd")
    key = jax.random.PRNGKey(2)
    a = jax.random.uniform(key, (512, 10), minval=-1, maxval=1)
    b = jax.random.uniform(jax.random.fold_in(key, 1), (800, 10), minval=-1, maxval=1)
    out = photonics.photonic_matmul(a, b, cfg, key=key)
    err = np.asarray(out - a @ b.T)
    s = float(jnp.max(jnp.abs(a)) * jnp.max(jnp.abs(b)))
    assert abs(err.std() / (0.098 * s) - 1.0) < 0.03


def test_fake_quant_levels():
    x = jnp.linspace(-1, 1, 1001)
    q = photonics.fake_quant(x, 4)
    assert len(np.unique(np.asarray(q))) <= 2**4 - 1 + 2
    np.testing.assert_allclose(np.asarray(photonics.fake_quant(x, None)), np.asarray(x))


@hypothesis.given(
    m=st.integers(1, 300), k=st.integers(1, 100),
    rows=st.integers(5, 100), cols=st.integers(5, 100),
)
@hypothesis.settings(max_examples=50, deadline=None)
def test_gemm_cycles_cover_matrix(m, k, rows, cols):
    """GeMM compiler invariant: cycles × bank area >= matrix area, and the
    tiling never exceeds one extra panel per dimension."""
    cfg = photonics.PhotonicConfig(bank_rows=rows, bank_cols=cols)
    cycles = photonics.gemm_cycles(m, k, cfg)
    assert cycles * rows * cols >= m * k
    assert cycles <= ((m // rows + 1) * (k // cols + 1))


@hypothesis.given(t=st.integers(1, 16), k=st.integers(1, 32), m=st.integers(1, 32))
@hypothesis.settings(max_examples=30, deadline=None)
def test_projection_linearity_ideal(t, k, m):
    """Ideal hardware is linear: photonic(a1+a2) == photonic(a1)+photonic(a2)."""
    key = jax.random.PRNGKey(t + 13 * k + 131 * m)
    a1 = jax.random.normal(key, (t, k))
    a2 = jax.random.normal(jax.random.fold_in(key, 1), (t, k))
    b = jax.random.normal(jax.random.fold_in(key, 2), (m, k))
    cfg = photonics.preset("ideal")
    lhs = photonics.photonic_matmul(a1 + a2, b, cfg)
    rhs = photonics.photonic_matmul(a1, b, cfg) + photonics.photonic_matmul(a2, b, cfg)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)


def test_project_shapes():
    cfg = photonics.preset("ideal")
    e = jnp.ones((3, 7, 10))
    b = jnp.ones((64, 10))
    out = photonics.photonic_project(e, b, cfg)
    assert out.shape == (3, 7, 64)
