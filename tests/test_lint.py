"""repro.lint: static rules RL001-RL005 (bad fixture + clean twin each),
suppression/baseline plumbing, and the runtime sanitizers (checkify value
checks + recompile sentinels) through the Trainer and the emu channel."""

import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import checkify

from repro import api, lint
from repro.core import photonics
from repro.hardware import channel, mrr
from repro.lint import runtime
from repro.train import trainer as trainer_lib


def rules_of(source, path="fixture.py"):
    return {f.rule for f in lint.lint_source(textwrap.dedent(source), path)}


# ---------------------------------------------------------------------------
# RL001 — PRNG key discipline
# ---------------------------------------------------------------------------

def test_rl001_flags_key_reuse():
    assert "RL001" in rules_of("""
        import jax

        def f(key):
            a = jax.random.normal(key, (4,))
            b = jax.random.uniform(key, (4,))
            return a + b
    """)


def test_rl001_clean_with_split():
    assert "RL001" not in rules_of("""
        import jax

        def f(key):
            ka, kb = jax.random.split(key)
            a = jax.random.normal(ka, (4,))
            b = jax.random.uniform(kb, (4,))
            return a + b
    """)


def test_rl001_fold_in_derivations_do_not_spend():
    assert "RL001" not in rules_of("""
        import jax

        def f(key):
            a = jax.random.normal(jax.random.fold_in(key, 1), (4,))
            b = jax.random.normal(jax.random.fold_in(key, 2), (4,))
            return a + b
    """)


def test_rl001_use_after_consume_flags():
    assert "RL001" in rules_of("""
        import jax
        from repro.utils import prng

        def f(key):
            a = jax.random.normal(prng.consume(key), (4,))
            b = jax.random.normal(key, (4,))
            return a + b
    """)


def test_rl001_unknown_consumer_counts_as_spend():
    assert "RL001" in rules_of("""
        import jax

        def f(key, helper):
            a = helper(key)
            b = jax.random.normal(key, (4,))
            return a + b
    """)


def test_rl001_derive_only_callee_is_not_a_spend():
    # the repo's named-folding idiom: callees that only fold_in from their
    # key parameter may share one base key
    assert "RL001" not in rules_of("""
        import jax

        def seg(x, key):
            return jax.random.fold_in(key, 7)

        def f(key):
            a = seg(1, key)
            b = seg(2, key)
            return a + b
    """)


def test_rl001_exclusive_branches_do_not_stack_spends():
    assert "RL001" not in rules_of("""
        import jax

        def f(key, fast):
            if fast:
                return jax.random.normal(key, (2,))
            return jax.random.uniform(key, (4,))
    """)


def test_rl001_loop_invariant_key_flags():
    assert "RL001" in rules_of("""
        import jax

        def f(key):
            out = []
            for _ in range(3):
                out.append(jax.random.normal(key, (2,)))
            return out
    """)


def test_rl001_nested_producer_does_not_make_result_a_key():
    # jax.eval_shape(init, PRNGKey(0)) returns shapes, not a key
    assert "RL001" not in rules_of("""
        import jax

        def f(init, use):
            shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
            use(shapes)
            use(shapes)
            return shapes
    """)


# ---------------------------------------------------------------------------
# RL002 — host sync in a hot path
# ---------------------------------------------------------------------------

def test_rl002_flags_float_in_jitted_fn():
    assert "RL002" in rules_of("""
        import jax

        @jax.jit
        def f(x):
            return float(x) + 1.0
    """)


def test_rl002_flags_sync_reached_through_calls():
    assert "RL002" in rules_of("""
        import jax

        def helper(x):
            return x.item()

        @jax.jit
        def f(x):
            return helper(x)
    """)


def test_rl002_flags_per_iteration_sync_in_driver_loop():
    assert "RL002" in rules_of("""
        import jax

        def g(x):
            return x * 2

        step = jax.jit(g)

        def run(xs):
            out = []
            for x in xs:
                out.append(float(step(x)))
            return out
    """)


def test_rl002_clean_driver_reads_once_after_loop():
    assert "RL002" not in rules_of("""
        import jax

        def g(x):
            return x * 2

        step = jax.jit(g)

        def run(xs):
            y = None
            for x in xs:
                y = step(x)
            return float(y)
    """)


def test_rl002_inline_suppression():
    src = textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            return float(x) + 1.0  # lint: disable=RL002
    """)
    assert not lint.lint_source(src)


# ---------------------------------------------------------------------------
# RL003 — tracer-unsafe control flow / non-hashable static args
# ---------------------------------------------------------------------------

def test_rl003_flags_if_on_tracer_value():
    assert "RL003" in rules_of("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            if jnp.any(x > 0):
                return x
            return -x
    """)


def test_rl003_clean_with_static_reflection():
    assert "RL003" not in rules_of("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x * 2.0
            return x
    """)


def test_rl003_flags_list_literal_static_arg():
    assert "RL003" in rules_of("""
        import jax

        def g(x, shape):
            return x.reshape(shape)

        h = jax.jit(g, static_argnums=(1,))

        def run(x):
            return h(x, [4, 4])
    """)


def test_rl003_clean_tuple_static_arg():
    assert "RL003" not in rules_of("""
        import jax

        def g(x, shape):
            return x.reshape(shape)

        h = jax.jit(g, static_argnums=(1,))

        def run(x):
            return h(x, (4, 4))
    """)


# ---------------------------------------------------------------------------
# RL004 — frozen-config mutation / dict-mutation of carried state
# ---------------------------------------------------------------------------

def test_rl004_flags_frozen_dataclass_mutation():
    assert "RL004" in rules_of("""
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Cfg:
            lr: float = 0.1

        def tune(cfg: Cfg):
            cfg.lr = 0.2
            return cfg
    """)


def test_rl004_clean_with_replace():
    assert "RL004" not in rules_of("""
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Cfg:
            lr: float = 0.1

        def tune(cfg: Cfg):
            cfg = dataclasses.replace(cfg, lr=0.2)
            return cfg
    """)


def test_rl004_flags_dict_mutation_of_traced_state():
    assert "RL004" in rules_of("""
        import jax

        @jax.jit
        def step(state, batch):
            state["x"] = state["x"] + batch
            return state
    """)


def test_rl004_clean_rebuilt_state():
    assert "RL004" not in rules_of("""
        import jax

        @jax.jit
        def step(state, batch):
            return {**state, "x": state["x"] + batch}
    """)


# ---------------------------------------------------------------------------
# RL005 — donation hazards
# ---------------------------------------------------------------------------

def test_rl005_flags_read_after_donate():
    assert "RL005" in rules_of("""
        import jax

        def train(state, batch):
            return state, 0.0

        fit = jax.jit(train, donate_argnums=(0,))

        def run(state, batch):
            new_state, loss = fit(state, batch)
            return state["x"], new_state
    """)


def test_rl005_clean_same_statement_rebind():
    assert "RL005" not in rules_of("""
        import jax

        def train(state, batch):
            return state, 0.0

        fit = jax.jit(train, donate_argnums=(0,))

        def run(state, batch):
            state, loss = fit(state, batch)
            return state["x"]
    """)


# ---------------------------------------------------------------------------
# baseline plumbing
# ---------------------------------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    src = textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            return float(x) + 1.0
    """)
    findings = lint.lint_source(src)
    assert findings
    path = tmp_path / "baseline.json"
    lint.write_baseline(str(path), findings)
    baseline = lint.load_baseline(str(path))
    assert not lint.new_findings(findings, baseline)
    # a fresh finding on a different line still surfaces
    extra = lint.Finding("RL002", "fixture.py", 99, "msg", "other_code()")
    assert lint.new_findings(findings + [extra], baseline) == [extra]


# ---------------------------------------------------------------------------
# runtime sanitizers
# ---------------------------------------------------------------------------

def test_check_finite_is_identity_when_unarmed():
    # un-functionalized checkify.check would die at trace time under plain
    # jit — outside debug_checks() the guard must emit nothing
    @jax.jit
    def f(x):
        return runtime.check_finite(x, "t") * 2.0

    out = f(jnp.array([1.0, jnp.inf]))
    assert jnp.isinf(out[1])  # passed through untouched


def test_checkify_catches_nan_in_emu_channel():
    cfg = photonics.PhotonicConfig(noise_std=0.0, mrr=mrr.MRRConfig.ideal())
    a = jnp.ones((4, 8)).at[0, 0].set(jnp.nan)
    b = jnp.ones((3, 8))
    body, _ = runtime.instrument(
        lambda x, y: channel.emulated_matmul(x, y, cfg, None),
        "emu", errors=checkify.user_checks)
    err, _ = jax.jit(body)(a, b)
    with pytest.raises(Exception, match="non-finite"):
        err.throw()


def test_checkify_passes_finite_emu_channel():
    cfg = photonics.PhotonicConfig(noise_std=0.0, mrr=mrr.MRRConfig.ideal())
    body, _ = runtime.instrument(
        lambda x, y: channel.emulated_matmul(x, y, cfg, None),
        "emu", errors=checkify.user_checks)
    err, out = jax.jit(body)(jnp.ones((4, 8)), jnp.ones((3, 8)))
    err.throw()  # no error
    assert out.shape == (4, 3)


def test_recompile_sentinel_raises_on_retrace():
    sentinel = runtime.RecompileSentinel("f", warmup=1)

    @jax.jit
    @sentinel.wrap
    def f(x):
        return x + 1

    f(jnp.ones((3,)))
    f(jnp.ones((3,)))  # cache hit: no new trace
    assert sentinel.traces == 1
    with pytest.raises(runtime.RecompileError):
        f(jnp.ones((4,)))  # new shape -> retrace


def _batch(model, n=8):
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    return {"x": jax.random.normal(kx, (n, model.in_dim)),
            "y": jax.random.randint(ky, (n,), 0, model.n_classes)}


def test_debug_session_fit_smoke():
    s = api.build_session(arch="mnist_mlp", algo="dfa", backend="emu",
                          hardware="emu_onchip", smoke=True,
                          log_every=10**9, debug_checks=True)
    batch = _batch(s.model)
    state, metrics = s.fit(lambda i: batch, total_steps=2, verbose=False)
    assert jnp.isfinite(jax.device_get(metrics["loss"]))
    assert s.trainer._sentinels["fit_step"].traces == 1


def test_debug_trainer_catches_nan_batch():
    s = api.build_session(arch="mnist_mlp", algo="dfa", smoke=True,
                          log_every=10**9, debug_checks=True)
    batch = _batch(s.model)
    batch["x"] = batch["x"].at[0, 0].set(jnp.nan)
    state = s.init_state()
    with pytest.raises(Exception, match="(?i)nan|non-finite"):
        s.step(state, batch)


def test_debug_trainer_catches_retrace():
    s = api.build_session(arch="mnist_mlp", algo="dfa", smoke=True,
                          log_every=10**9, debug_checks=True)
    state = s.init_state()
    state, _ = s.step(state, _batch(s.model, 8))
    with pytest.raises(runtime.RecompileError):
        s.step(state, _batch(s.model, 4))  # batch-shape change -> retrace


def test_debug_checks_off_is_default_and_unwrapped():
    cfg = trainer_lib.TrainerConfig()
    assert cfg.debug_checks is False
    s = api.build_session(arch="mnist_mlp", algo="dfa", smoke=True,
                          log_every=10**9)
    assert s.trainer._sentinels == {}
