"""Training substrate: optimizers vs hand math, schedules, trainer loop,
noise-robustness ordering (paper Fig. 5 claim, reduced scale)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dfa, photonics
from repro.data import mnist, pipeline
from repro.models.mlp import MLPClassifier
from repro.train import SGDM, AdamW, Trainer, TrainerConfig, schedule


def test_sgdm_matches_manual():
    opt = SGDM(lr=0.1, momentum=0.9)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    s = opt.init(p)
    p1, s1, _ = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(p1["w"]), [1 - 0.05, 2 + 0.1])
    p2, s2, _ = opt.update(g, s1, p1)
    # m2 = 0.9*m1 + g
    m2 = 0.9 * np.array([0.5, -1.0]) + np.array([0.5, -1.0])
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(p1["w"]) - 0.1 * m2,
                               rtol=1e-6)


def test_adamw_first_step_is_lr_sized():
    opt = AdamW(lr=1e-3, weight_decay=0.0, clip_norm=None)
    p = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([10.0])}
    s = opt.init(p)
    p1, _, _ = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(p1["w"]), [-1e-3], rtol=1e-3)


def test_clip_by_global_norm():
    from repro.train.optimizer import clip_by_global_norm

    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    total = jnp.sqrt(clipped["a"] ** 2 + clipped["b"] ** 2)
    assert float(total[0]) == pytest.approx(1.0)


def test_schedules():
    s = schedule.warmup_cosine(1.0, 10, 110, final_frac=0.1)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0)
    assert float(s(jnp.int32(110))) == pytest.approx(0.1, abs=1e-3)
    lin = schedule.linear_decay(2.0, 100)
    assert float(lin(jnp.int32(50))) == pytest.approx(1.0)


@pytest.fixture(scope="module")
def digits():
    data = mnist.load((2048, 512), seed=0)
    return data


@pytest.mark.slow
def test_dfa_training_improves_accuracy(digits):
    xtr, ytr = digits["train"]
    xte, yte = digits["test"]
    pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=0)
    model = MLPClassifier(hidden=(128, 128))
    tr = Trainer(model, TrainerConfig(
        algo="dfa", optimizer=SGDM(lr=0.01, momentum=0.9), log_every=10**9))
    state, _ = tr.fit(pipe.batch, total_steps=96, verbose=False)
    ev = tr.evaluate(state, pipe.eval_batches(xte, yte, 256))
    assert ev["accuracy"] > 0.6  # far above 10% chance after 3 epochs


@pytest.mark.slow
def test_noise_robustness_ordering(digits):
    """Paper Fig. 5: clean >= off-chip-BPD >= on-chip-BPD (with slack for
    short-run variance)."""
    xtr, ytr = digits["train"]
    xte, yte = digits["test"]
    pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=0)
    accs = {}
    for preset in ["ideal", "onchip_bpd"]:
        model = MLPClassifier(hidden=(128, 128))
        tr = Trainer(model, TrainerConfig(
            algo="dfa", dfa=dfa.DFAConfig(photonics=photonics.preset(preset)),
            optimizer=SGDM(lr=0.01, momentum=0.9), log_every=10**9))
        state, _ = tr.fit(pipe.batch, total_steps=96, verbose=False)
        accs[preset] = tr.evaluate(state, pipe.eval_batches(xte, yte, 256))["accuracy"]
    assert accs["ideal"] >= accs["onchip_bpd"] - 0.02
    assert accs["onchip_bpd"] > 0.5  # noisy hardware still trains


@pytest.mark.slow
def test_bp_baseline_trains(digits):
    xtr, ytr = digits["train"]
    pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=0)
    model = MLPClassifier(hidden=(64,))
    tr = Trainer(model, TrainerConfig(algo="bp", optimizer=SGDM(lr=0.05), log_every=10**9))
    state0 = tr.init_state()
    _, m0 = tr.step(state0, pipe.batch(0))
    state, m = tr.fit(pipe.batch, total_steps=64, verbose=False)
    assert float(m["loss"]) < float(m0["loss"])


def test_microbatch_accumulation_matches_full_batch():
    """grad(batch) == mean of grads(microbatches) for DFA with fixed rng."""
    model = MLPClassifier(in_dim=8, hidden=(16,), n_classes=4)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    cfg_t1 = TrainerConfig(algo="dfa", optimizer=SGDM(lr=0.0), microbatches=1, seed=3)
    cfg_t4 = TrainerConfig(algo="dfa", optimizer=SGDM(lr=0.0), microbatches=4, seed=3)
    batch = {"x": jax.random.normal(key, (32, 8)),
             "y": jax.random.randint(key, (32,), 0, 4)}
    t1, t4 = Trainer(model, cfg_t1), Trainer(model, cfg_t4)
    s1, s4 = t1.init_state(), t4.init_state()
    _, m1 = t1.step(s1, batch)
    _, m4 = t4.step(s4, batch)
    # CE means over different partitions agree
    assert abs(float(m1["ce_loss"]) - float(m4["ce_loss"])) < 1e-5


def test_straggler_deadline_raises():
    model = MLPClassifier(in_dim=8, hidden=(16,), n_classes=4)
    tr = Trainer(model, TrainerConfig(step_deadline_s=0.0))
    state = tr.init_state()
    batch = {"x": jnp.zeros((4, 8)), "y": jnp.zeros((4,), jnp.int32)}
    with pytest.raises(TimeoutError):
        tr.step(state, batch)
