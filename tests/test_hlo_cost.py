"""Trip-count-aware HLO cost walker (the roofline's data source)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import hlo_cost


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = hlo_cost.analyze(_compile_text(lambda a, b: a @ b, x, w))
    assert c.flops == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    def loop(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = hlo_cost.analyze(_compile_text(loop, x, w))
    assert c.flops == 10 * 2 * 128**3


def test_nested_scan():
    def nested(x, w):
        def outer(co, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, co, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = hlo_cost.analyze(_compile_text(nested, x, w))
    assert c.flops == 15 * 2 * 64**3


def test_xla_cost_analysis_undercounts_loops():
    """The reason this walker exists: XLA counts while bodies once."""
    def loop(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(loop).lower(x, w).compile()
    from repro.launch.analysis import cost_analysis_dict

    xla_flops = cost_analysis_dict(compiled).get("flops", 0)  # list on jax<0.5
    walker = hlo_cost.analyze(compiled.as_text()).flops
    assert xla_flops < walker / 5  # XLA sees ~1/10 of the real flops


def test_mem_bytes_positive_and_reasonable():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = hlo_cost.analyze(_compile_text(lambda a, b: a @ b, x, w))
    assert c.mem_bytes >= 3 * 256 * 256 * 4  # two operands + output


def test_collective_parse_smoke():
    text = """
ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %ag = f32[16,8]{1,0} all-gather(%p), replica_groups={{0,1}}, dimensions={0}
  ROOT %slice.1 = f32[8,8]{1,0} slice(%ag), slice={[0:8], [0:8]}
}
"""
    c = hlo_cost.analyze(text)
    assert c.coll_bytes["all-gather"] == 8 * 8 * 4
    assert c.coll_count["all-gather"] == 1
