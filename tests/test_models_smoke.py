"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED same-family config and runs one forward +
one DFA train step on CPU, asserting output shapes and no NaNs; decoder
archs also run one serve step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dfa

B, S = 2, 16


def _batch(name, key):
    toks = {"tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}
    if name == "mnist_mlp":
        return {"x": jax.random.normal(key, (B, 64)),
                "y": jnp.zeros((B,), jnp.int32)}
    if name == "whisper-small":
        return {"frames": jax.random.normal(key, (B, 32, 48)), **toks}
    if name == "internvl2-2b":
        return {"patch_embeds": jax.random.normal(key, (B, 8, 32)), **toks}
    return toks


@pytest.mark.parametrize("name", configs.list_archs())
def test_smoke_forward_and_dfa_step(name):
    arch = configs.get(name)
    model = arch.make_smoke()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(name, key)

    # forward loss
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))

    # one DFA train step with the paper's off-chip-BPD noise
    from repro.core import photonics

    cfg = dfa.DFAConfig(photonics=photonics.preset("offchip_bpd"))
    fb = dfa.init_feedback(model, key, cfg)
    (loss2, m2), grads = jax.jit(dfa.value_and_grad(model, cfg))(
        params, fb, batch, jax.random.PRNGKey(1))
    assert np.isfinite(float(loss2))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, "no gradients produced"
    for g in leaves:
        assert not bool(jnp.any(jnp.isnan(g))), "NaN gradient"
    # params and grads are structurally identical
    assert jax.tree_util.tree_structure(grads) == jax.tree_util.tree_structure(params)

    # sgd update changes the parameters
    new = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g, params, grads)
    diff = sum(float(jnp.sum(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(new), leaves))
    assert diff >= 0.0


@pytest.mark.parametrize("name", [n for n in configs.ASSIGNED])
def test_smoke_decode_step(name):
    arch = configs.get(name)
    model = arch.make_smoke()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tok = jnp.zeros((B, 1), jnp.int32)
    cl = jnp.zeros((B,), jnp.int32) + 3
    caches = model.init_caches(B, 16)
    if name == "whisper-small":
        enc = model.encode(params, jax.random.normal(key, (B, 32, 48)))
        logits, new_caches = model.decode_step(params, tok, enc, caches, cl)
    else:
        logits, new_caches = model.decode_step(params, tok, caches, cl)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree_util.tree_structure(new_caches) == jax.tree_util.tree_structure(caches)


def test_registry_complete():
    assert len(configs.ASSIGNED) == 10
    assert "mnist_mlp" in configs.list_archs()
    fams = {configs.get(n).family for n in configs.ASSIGNED}
    assert fams == {"dense", "moe", "ssm", "vlm", "hybrid", "audio"}
    # sub-quadratic flags per the assignment
    assert configs.get("mamba2-130m").sub_quadratic
    assert configs.get("recurrentgemma-9b").sub_quadratic
    assert not configs.get("granite-8b").sub_quadratic


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published dimensions (checked via
    eval_shape — no allocation)."""
    specs = {
        "qwen1.5-0.5b": dict(n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
                             d_ff=2816, vocab_size=151936, qkv_bias=True),
        "qwen3-1.7b": dict(n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
                           d_ff=6144, vocab_size=151936, qk_norm=True),
        "granite-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                           d_ff=14336, vocab_size=49152),
        "minicpm3-4b": dict(n_layers=62, d_model=2560, n_heads=40, d_ff=6400,
                            vocab_size=73448),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, vocab_size=151936),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                                n_kv_heads=8, vocab_size=163840),
        "internvl2-2b": dict(n_layers=24, d_model=2048, d_ff=8192, vocab_size=92553),
    }
    for name, want in specs.items():
        cfg = configs.get(name).make_model(jnp.bfloat16).cfg
        for k, v in want.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)
    moe = configs.get("qwen2-moe-a2.7b").make_model(jnp.bfloat16).cfg.moe
    assert (moe.n_experts, moe.top_k, moe.n_shared_experts) == (60, 4, 4)
    kimi = configs.get("kimi-k2-1t-a32b").make_model(jnp.bfloat16).cfg.moe
    assert (kimi.n_experts, kimi.top_k) == (384, 8)
    rg = configs.get("recurrentgemma-9b").make_model(jnp.bfloat16).cfg
    assert (rg.n_layers, rg.d_model, rg.d_ff, rg.vocab_size, rg.window) == \
        (38, 4096, 12288, 256000, 2048)
    wh = configs.get("whisper-small").make_model(jnp.bfloat16).cfg
    assert (wh.n_enc_layers, wh.n_dec_layers, wh.d_model, wh.vocab_size) == \
        (12, 12, 768, 51865)
    mb = configs.get("mamba2-130m").make_model(jnp.bfloat16).cfg
    assert (mb.n_layers, mb.d_model, mb.vocab_size, mb.d_state) == (24, 768, 50280, 128)


def test_kimi_total_params_about_1t():
    model = configs.get("kimi-k2-1t-a32b").make_model(jnp.bfloat16)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    import numpy as np

    total = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(shapes))
    assert 0.9e12 < total < 1.3e12  # the paper-table "1T" entry
    from repro.launch.analysis import active_param_count

    active = active_param_count(shapes, model)
    assert 25e9 < active < 45e9  # "A32B"
