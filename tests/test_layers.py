"""nn layers: flash-vs-reference attention, decode parity for every
temporal mixer, MoE routing invariants, rotary properties."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.nn.attention import flash_attention, reference_attention


def _pos(b, s):
    return jnp.broadcast_to(jnp.arange(s)[None], (b, s))


@pytest.mark.parametrize("sq,skv,h,kvh,d", [
    (128, 128, 4, 4, 32),
    (256, 256, 4, 2, 16),   # GQA
    (64, 192, 2, 2, 8),     # cross-length
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_reference(sq, skv, h, kvh, d, causal):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, skv, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, skv, kvh, d))
    qp, kp = _pos(2, sq), _pos(2, skv)
    ref = reference_attention(q, k, v, q_pos=qp, kv_pos=kp, causal=causal)
    out = flash_attention(q, k, v, q_pos=qp, kv_pos=kp, causal=causal,
                          q_chunk=64, k_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_local_window_matches_reference():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 256, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 16))
    qp = _pos(1, 256)
    ref = reference_attention(q, k, v, q_pos=qp, kv_pos=qp, causal=True, window=64)
    out = flash_attention(q, k, v, q_pos=qp, kv_pos=qp, causal=True, window=64,
                          q_chunk=64, k_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("mixer", ["attn", "mla", "mamba", "rglru"])
def test_decode_parity(mixer):
    """Incremental decode == full parallel forward for every mixer."""
    key = jax.random.PRNGKey(0)
    T = 12
    if mixer == "attn":
        mod = nn.Attention(d_model=32, n_heads=4, n_kv_heads=2)
    elif mixer == "mla":
        mod = nn.MLAttention(d_model=32, n_heads=2, q_lora_rank=16,
                             kv_lora_rank=8, qk_nope_dim=8, qk_rope_dim=4,
                             v_head_dim=8)
    elif mixer == "mamba":
        mod = nn.Mamba2Block(d_model=32, d_state=16, head_dim=16, chunk=4)
    else:
        mod = nn.RGLRUBlock(d_model=32, d_rnn=48)
    p = mod.init(key)
    x = jax.random.normal(key, (2, T, 32))
    full = mod(p, x)
    cache = mod.init_cache(2, T)
    outs = []
    cl = jnp.zeros((2,), jnp.int32)
    for t in range(T):
        o, cache = mod.decode(p, x[:, t : t + 1], cache, cl + t)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=1e-4, atol=2e-5)


def test_windowed_ring_cache_decode_matches_reference():
    """Ring-buffer (window) cache == full-cache attention with window mask."""
    key = jax.random.PRNGKey(1)
    T, W = 32, 8
    ring = nn.Attention(d_model=16, n_heads=2, n_kv_heads=1, window=W)
    p = ring.init(key)
    x = jax.random.normal(key, (1, T, 16))
    full = ring(p, x)
    cache = ring.init_cache(1, W)  # ring buffer of window size
    assert cache["k"].shape[1] == W
    outs = []
    cl = jnp.zeros((1,), jnp.int32)
    for t in range(T):
        o, cache = ring.decode(p, x[:, t : t + 1], cache, cl + t)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=1e-4, atol=2e-5)


def test_moe_routing_invariants():
    moe = nn.MoE(d_model=16, d_ff_expert=32, n_experts=8, top_k=2,
                 capacity_factor=4.0)
    p = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    combine, dispatch, aux = moe._route(p, x.reshape(32, 16))
    # no drops at high capacity
    assert float(aux["dropped_frac"]) == 0.0
    # each token dispatched to exactly top_k slots
    assert np.allclose(np.asarray(dispatch.sum(axis=(1, 2))), 2.0)
    # combine weights sum to ~1 per token (norm_topk_prob)
    assert np.allclose(np.asarray(combine.sum(axis=(1, 2))), 1.0, atol=1e-5)
    # per-expert load never exceeds capacity
    cap = dispatch.shape[-1] * 0 + dispatch.sum(axis=(0, 2)).max()
    assert float(cap) <= 4.0 * 2 * 32 / 8 + 1e-6


def test_moe_group_scan_consistent_with_single_group():
    """Group-scanned MoE == single-group MoE when capacity is ample."""
    kwargs = dict(d_model=16, d_ff_expert=32, n_experts=4, top_k=2,
                  capacity_factor=8.0)
    p = nn.MoE(group_size=4096, **kwargs).init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 16))
    y1, _ = nn.MoE(group_size=4096, **kwargs)(p, x)   # single group (T=128)
    y2, _ = nn.MoE(group_size=32, **kwargs)(p, x)      # 4 seq-groups
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)


@hypothesis.given(s=st.integers(2, 33), d=st.sampled_from([8, 16, 32]))
@hypothesis.settings(max_examples=20, deadline=None)
def test_rotary_preserves_norm_and_relative_phase(s, d):
    from repro.nn.embeddings import apply_rotary, rotary_angles

    key = jax.random.PRNGKey(s * 100 + d)
    x = jax.random.normal(key, (1, s, 2, d))
    pos = _pos(1, s)
    cos, sin = rotary_angles(pos, d)
    y = apply_rotary(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)
    # relative property: <q_m, k_n> depends only on m-n
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, d))
    def dot_at(m, n):
        cm, sm = rotary_angles(jnp.array([[m]]), d)
        cn, sn = rotary_angles(jnp.array([[n]]), d)
        qm = apply_rotary(q, cm, sm)
        kn = apply_rotary(k, cn, sn)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-3


def test_ssd_chunked_equals_unchunked():
    """Mamba2 SSD: chunked scan == different chunking (state-space duality)."""
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (2, 32, 16))
    m1 = nn.Mamba2Block(d_model=16, d_state=8, head_dim=8, chunk=4)
    m2 = nn.Mamba2Block(d_model=16, d_state=8, head_dim=8, chunk=16)
    p = m1.init(key)
    np.testing.assert_allclose(np.asarray(m1(p, u)), np.asarray(m2(p, u)),
                               rtol=1e-4, atol=1e-5)
