"""Energy/speed model (paper §5, Eqs. 2–4, Fig. 6)."""

import pytest

from repro.core import energy


def test_eq2_ops_headline():
    cfg = energy.EnergyConfig()
    assert energy.ops_per_second(50, 20, cfg) == pytest.approx(20e12)


def test_energy_per_op_headline_heaters():
    cfg = energy.EnergyConfig(trimming=False)
    e = energy.energy_per_op(50, 20, cfg) * 1e12
    assert e == pytest.approx(1.0, abs=0.05)  # paper: 1.0 pJ


def test_energy_per_op_headline_trimmed():
    cfg = energy.EnergyConfig(trimming=True)
    e = energy.energy_per_op(50, 20, cfg) * 1e12
    assert e == pytest.approx(0.28, abs=0.02)  # paper: 0.28 pJ


def test_compute_density_headline():
    cfg = energy.EnergyConfig()
    assert energy.compute_density_tops_mm2(50, 20, cfg) == pytest.approx(5.78, abs=0.05)


def test_laser_power_floor_regimes():
    cfg = energy.EnergyConfig()
    # capacitance-limited at the paper's operating point
    shot = 2.0 ** (2 * cfg.n_bits + 1)
    cap = cfg.c_pd * cfg.v_d / energy.ELEMENTARY_CHARGE
    assert cap > shot
    hi_bits = energy.EnergyConfig(n_bits=8)
    assert energy.laser_power(50, hi_bits) > energy.laser_power(50, cfg)


def test_fig6_energy_decreases_with_cells():
    cfg = energy.EnergyConfig(trimming=True)
    curve = energy.fig6_curve(cfg, cells=[100, 400, 1000, 4000, 10000])
    es = [r["e_op_pj"] for r in curve]
    assert all(a >= b for a, b in zip(es, es[1:]))  # monotone ↓ (Fig. 6 shape)


def test_fig6_heater_above_trimming():
    heat = energy.fig6_curve(energy.EnergyConfig(trimming=False), cells=[1000, 4000])
    trim = energy.fig6_curve(energy.EnergyConfig(trimming=True), cells=[1000, 4000])
    for h, t in zip(heat, trim):
        assert h["e_op_pj"] > t["e_op_pj"]


def test_optimal_dims_respect_constraint():
    cfg = energy.EnergyConfig()
    m, n, _ = energy.optimal_bank_dims(1000, cfg)
    assert m * n == 1000 and m >= 5 and n >= 5


def test_dfa_backward_cost_paper_mlp():
    """Paper's 784×800×800×10 MLP backward on a 50×20 bank."""
    cfg = energy.EnergyConfig()
    r = energy.dfa_backward_cost([800, 800], 10, cfg)
    assert r["cycles"] == 32  # 2 layers × ceil(800/50)×ceil(10/20)
    assert r["seconds"] == pytest.approx(3.2e-9)
    assert r["tops"] == pytest.approx(10.0)  # half the bank is idle (N=10<20)
