"""Multi-wavelength bus scale-out (PhotonicConfig.n_buses): scheduling
math, single-bus bit-exactness with the PR 3 emu path, multi-bus ref
equivalence, inter-bus crosstalk, bus-shaped drift state through the
Trainer, the energy model's per-bus terms — plus the degenerate-bits
fake-quant fixes and the step-0 recalibration skip."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algos, api
from repro.core import energy, photonics
from repro.hardware import calibrate, channel, drift, mrr

IDEAL = mrr.MRRConfig.ideal()


# ---------------------------------------------------------------------------
# degenerate-bits fake-quant (the NaN fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [1, 2])
def test_fake_quant_low_bits_finite_and_idempotent(bits):
    """bits=1 used to divide by levels=0 and return NaN; both 1 and 2 bits
    now quantise to the ternary grid {-amax, 0, +amax} and are idempotent."""
    x = jnp.array([-1.7, -0.9, -0.2, 0.0, 0.3, 0.8, 1.7])
    q = photonics.fake_quant(x, bits)
    assert np.all(np.isfinite(np.asarray(q)))
    amax = float(jnp.max(jnp.abs(x)))
    assert set(np.round(np.unique(np.asarray(q)), 5)) <= {-amax, 0.0, amax}
    np.testing.assert_array_equal(np.asarray(photonics.fake_quant(q, bits)),
                                  np.asarray(q))


def test_fake_quant_one_bit_through_error_compress_config():
    """The Fig. 5 ablation path: a 1-bit input/weight encoding no longer
    poisons the projection with NaN."""
    cfg = photonics.PhotonicConfig(input_bits=1, weight_bits=1)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (4, 12))
    b = jax.random.normal(jax.random.fold_in(key, 1), (6, 12))
    out = photonics.photonic_matmul(a, b, cfg)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("heater_bits", [0, 1])
def test_quantize_command_degenerate_heater_bits(heater_bits):
    """Same guard on the heater DAC: 1 bit means on/off {0, delta_max},
    never a zero-level division."""
    cfg = dataclasses.replace(IDEAL, heater_bits=heater_bits, delta_max=10.0)
    d = calibrate.quantize_command(jnp.linspace(0.0, 10.0, 33), cfg)
    assert np.all(np.isfinite(np.asarray(d)))
    assert set(np.unique(np.asarray(d))) <= {0.0, 10.0}


# ---------------------------------------------------------------------------
# step-0 recalibration skip
# ---------------------------------------------------------------------------

def test_advance_skips_recalibration_at_step_zero():
    cfg = photonics.PhotonicConfig(mrr=mrr.MRRConfig(
        drift_sigma=0.5, drift_tau=10.0, cal_noise=0.0))
    state = drift.init_state(cfg)
    key = jax.random.PRNGKey(0)
    s0 = calibrate.advance(state, cfg, 0, key, recalibrate_every=1)
    # drift advanced, but a fresh chip is not re-swept before any history
    assert float(jnp.abs(s0["drift"]).max()) > 0.0
    np.testing.assert_array_equal(np.asarray(s0["cal"]),
                                  np.zeros_like(s0["cal"]))
    s1 = calibrate.advance(s0, cfg, 1, jax.random.fold_in(key, 1),
                           recalibrate_every=1)
    np.testing.assert_array_equal(np.asarray(s1["cal"]),
                                  np.asarray(s1["drift"]))


# ---------------------------------------------------------------------------
# GeMM scheduling across buses
# ---------------------------------------------------------------------------

def test_bus_scheduling_divides_contraction_cycles():
    cfg1 = photonics.PhotonicConfig()              # 50×20, 1 bus
    cfg4 = dataclasses.replace(cfg1, n_buses=4)
    assert photonics.n_contraction_panels(80, cfg4) == 4  # noise count
    assert photonics.n_bank_passes(80, cfg1) == 4
    assert photonics.n_bank_passes(80, cfg4) == 1          # 4 panels, 4 buses
    assert photonics.n_bank_passes(100, cfg4) == 2         # 5 panels -> 2 cyc
    assert photonics.gemm_cycles(800, 80, cfg1) == 64
    assert photonics.gemm_cycles(800, 80, cfg4) == 16
    # the paper's MLP tap is one panel: buses cannot help
    assert photonics.gemm_cycles(800, 10, cfg4) == 16


def test_noise_accumulation_is_bus_invariant():
    """Every contraction panel fires one BPD read wherever it runs, so the
    accumulated σ counts panels, not bus-parallel cycles."""
    cfg1 = photonics.PhotonicConfig(noise_std=0.1)
    cfg4 = dataclasses.replace(cfg1, n_buses=4)
    assert photonics.noise_sigma_total(80, 1.0, 1.0, cfg1) == pytest.approx(
        photonics.noise_sigma_total(80, 1.0, 1.0, cfg4))


def test_energy_cost_routes_through_gemm_cycles():
    """Satellite: dfa_backward_cost no longer re-implements the tiling —
    its schedule length IS photonics.gemm_cycles, at every bus count."""
    for n_buses in (1, 2, 3, 8):
        ecfg = energy.EnergyConfig(n_buses=n_buses)
        pcfg = photonics.PhotonicConfig(bank_rows=50, bank_cols=20,
                                        n_buses=n_buses)
        r = energy.dfa_backward_cost([800, 800, 333], 96, ecfg)
        assert r["cycles"] == sum(
            photonics.gemm_cycles(d, 96, pcfg) for d in [800, 800, 333])


def test_energy_per_bus_terms():
    """Eq. 2/4 with B buses: throughput and power both scale by B, so the
    ideal (fully scheduled) E_op is bus-invariant."""
    e1 = energy.EnergyConfig(n_buses=1)
    e4 = energy.EnergyConfig(n_buses=4)
    assert energy.ops_per_second(50, 20, e4) == pytest.approx(
        4 * energy.ops_per_second(50, 20, e1))
    assert energy.total_power(50, 20, e4) == pytest.approx(
        4 * energy.total_power(50, 20, e1))
    assert energy.energy_per_op(50, 20, e4) == pytest.approx(
        energy.energy_per_op(50, 20, e1))
    # a real schedule pays quantization: idle buses still burn power
    r1 = energy.dfa_backward_cost([800] * 4, 896, e1)
    r4 = energy.dfa_backward_cost([800] * 4, 896, e4)
    assert r4["cycles"] < r1["cycles"]
    assert r4["pj_per_mac"] >= r1["pj_per_mac"] * 0.999


# ---------------------------------------------------------------------------
# single-bus bit-exactness with the PR 3 emu path
# ---------------------------------------------------------------------------

def _legacy_bank_product(a_n, b_n, cfg, key=None, residual=None):
    """Verbatim re-implementation of the pre-bus (PR 3) signal chain:
    (T,K)x(M,K) tiled to (nm, rows, nk, cols) panels of ONE physical bank,
    per-pass noise/ADC, digital accumulation over the contraction axis."""
    device = cfg.mrr or mrr.MRRConfig()
    t = a_n.shape[0]
    m = b_n.shape[0]
    rows, cols = cfg.bank_rows, cfg.bank_cols

    def pad(x, mult, axis):
        rem = (-x.shape[axis]) % mult
        if rem == 0:
            return x
        width = [(0, 0)] * x.ndim
        width[axis] = (0, rem)
        return jnp.pad(x, width)

    a_p = pad(a_n, cols, 1)
    nk = a_p.shape[1] // cols
    a_t = a_p.reshape(t, nk, cols)
    b_p = pad(pad(b_n, rows, 0), cols, 1)
    b_t = b_p.reshape(b_p.shape[0] // rows, rows, nk, cols)
    delta_cmd = calibrate.command_deltas(b_t, device)
    delta_eff = delta_cmd + mrr.crosstalk_leak(delta_cmd, device)
    if residual is not None:
        delta_eff = delta_eff + residual[..., :, None, :]
    w_eff = mrr.ring_weight(delta_eff, device.gamma)
    p = jnp.einsum("tjc,irjc->tirj", a_t, w_eff)
    sigma = cfg.noise_std if cfg.noise_convention == "absolute" else \
        cfg.noise_std * cfg.bank_cols
    if sigma > 0.0 or device.shot_noise > 0.0:
        k_th, k_sh = jax.random.split(key)
        noise = jnp.zeros_like(p)
        if sigma > 0.0:
            noise += sigma * jax.random.normal(k_th, p.shape, p.dtype)
        if device.shot_noise > 0.0:
            noise += (device.shot_noise * jnp.sqrt(jnp.abs(p))
                      * jax.random.normal(k_sh, p.shape, p.dtype))
        p = p + noise
    if device.adc_bits is not None:
        p = photonics.fake_quant(p, device.adc_bits, amax=float(cfg.bank_cols))
    out = jnp.sum(p, axis=-1)
    return out.reshape(t, -1)[:, :m]


def test_single_bus_bit_exact_with_legacy_emu_path():
    """n_buses=1 reproduces the pre-bus emulation bit for bit, with every
    nonideality on: read+shot noise, output ADC, drift residual."""
    key = jax.random.PRNGKey(0)
    device = mrr.MRRConfig(adc_bits=8, shot_noise=0.01)
    cfg = photonics.PhotonicConfig(noise_std=0.098, mrr=device)
    a = jax.random.uniform(key, (7, 33), minval=-1, maxval=1)
    b = jax.random.uniform(jax.random.fold_in(key, 1), (61, 33),
                           minval=-1, maxval=1)
    res = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (50, 20))
    nk = jax.random.fold_in(key, 3)
    legacy = _legacy_bank_product(a, b, cfg, key=nk, residual=res)
    new = channel.bank_product(a, b, cfg, key=nk, residual=res[None])
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))


@pytest.mark.parametrize("algo", algos.list_algos())
def test_single_bus_algorithms_bit_exact_with_legacy(algo, monkeypatch):
    """Satellite: every registered algorithm's noisy emu loss/grads at
    n_buses=1 match the pre-bus signal chain bit for bit (the second run
    swaps ``bank_product`` for the PR 3 re-implementation)."""
    def cell():
        hw = photonics.PhotonicConfig(
            noise_std=0.098,
            mrr=mrr.MRRConfig(adc_bits=10, drift_sigma=0.0, cal_noise=0.0))
        session = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                                    hardware=hw, backend="emu",
                                    log_every=10**9)
        key = jax.random.PRNGKey(0)
        state = session.init_state(key)
        batch = {"x": jax.random.normal(key, (16, 64)),
                 "y": jax.random.randint(key, (16,), 0, 10)}
        return session.value_and_grad()(
            state["params"], state["fb"], batch, jax.random.PRNGKey(1))

    (l_new, _), g_new = cell()
    monkeypatch.setattr(
        channel, "bank_product",
        lambda a_n, b_n, cfg, key=None, *, residual=None:
        _legacy_bank_product(a_n, b_n, cfg, key=key, residual=residual))
    (l_old, _), g_old = cell()
    assert float(l_new) == float(l_old)
    for x, y in zip(jax.tree_util.tree_leaves(g_new),
                    jax.tree_util.tree_leaves(g_old)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# multi-bus equivalence with ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_buses,k_dim", [(2, 33), (3, 80), (5, 61)])
def test_multibus_noiseless_matches_exact(n_buses, k_dim):
    """Noiseless multi-bus scheduling (including idle-bus padding in the
    last cycle) is exact to f32 tolerance."""
    cfg = photonics.PhotonicConfig(noise_std=0.0, mrr=IDEAL, n_buses=n_buses)
    key = jax.random.PRNGKey(n_buses)
    a = jax.random.normal(key, (9, k_dim))
    b = jax.random.normal(jax.random.fold_in(key, 1), (73, k_dim))
    out = channel.emulated_matmul(a, b, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b.T),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("algo", algos.list_algos())
def test_multibus_noiseless_matches_ref_for_every_algorithm(algo):
    s_ref = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                              hardware="ideal", backend="ref", log_every=10**9)
    s_bus = api.build_session(
        arch="mnist_mlp", smoke=True, algo=algo,
        hardware=photonics.PhotonicConfig(noise_std=0.0, mrr=IDEAL),
        backend="emu", n_buses=3, log_every=10**9)
    key = jax.random.PRNGKey(0)
    state = s_ref.init_state(key)
    batch = {"x": jax.random.normal(key, (16, 64)),
             "y": jax.random.randint(key, (16,), 0, 10)}
    (l_ref, _), g_ref = s_ref.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    (l_bus, _), g_bus = s_bus.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    np.testing.assert_allclose(float(l_ref), float(l_bus), rtol=1e-6)
    for x, y in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_bus)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


def test_multibus_noise_statistics_match_ref():
    """Idle buses in the last parallel cycle are noise-masked, so the
    accumulated noise still counts panels — matching ref's single draw
    (3 buses × 2 cycles schedule 6 slots, but K=80 is only 4 panels)."""
    cfg = photonics.PhotonicConfig(noise_std=0.1, mrr=IDEAL, n_buses=3)
    key = jax.random.PRNGKey(6)
    a = jax.random.uniform(key, (512, 80), minval=-1, maxval=1)
    b = jax.random.uniform(jax.random.fold_in(key, 1), (100, 80),
                           minval=-1, maxval=1)
    out = channel.emulated_matmul(a, b, cfg, key=jax.random.fold_in(key, 2))
    err = np.asarray(out - a @ b.T)
    s = float(jnp.max(jnp.abs(a)) * jnp.max(jnp.abs(b)))
    expect = photonics.noise_sigma_total(80, 1.0, 1.0, cfg) * s
    assert abs(err.std() / expect - 1.0) < 0.05


# ---------------------------------------------------------------------------
# inter-bus crosstalk
# ---------------------------------------------------------------------------

def test_inter_bus_crosstalk_perturbs_and_compensation_recovers():
    key = jax.random.PRNGKey(1)
    w = jax.random.uniform(key, (1, 3, 10, 1, 8), minval=-0.9, maxval=0.9)
    xt = dataclasses.replace(IDEAL, bus_crosstalk=0.02,
                             compensate_crosstalk=False)
    xt_comp = dataclasses.replace(xt, compensate_crosstalk=True, ct_iters=3)

    def realized(cfg):
        d = calibrate.command_deltas(w, cfg)
        d = d + mrr.crosstalk_leak(d, cfg)
        return mrr.ring_weight(d, cfg.gamma)

    err_raw = float(jnp.abs(realized(xt) - w).max())
    err_comp = float(jnp.abs(realized(xt_comp) - w).max())
    assert err_raw > 1e-3  # adjacent buses really do couple
    assert err_comp < err_raw / 5  # Jacobi pre-inversion recovers it


def test_single_bus_layouts_see_no_inter_bus_term():
    """bus_crosstalk is inert when the layout has no bus axis (bare grids,
    4-D panel stacks) and when there is only one bus."""
    cfg = dataclasses.replace(IDEAL, bus_crosstalk=0.05)
    bare = jnp.ones((5, 4))
    np.testing.assert_array_equal(
        np.asarray(mrr.crosstalk_leak(bare, cfg)), np.zeros((5, 4)))
    one_bus = jnp.ones((2, 1, 5, 3, 4))
    np.testing.assert_array_equal(
        np.asarray(mrr.crosstalk_leak(one_bus, cfg)),
        np.zeros_like(np.asarray(one_bus)))


# ---------------------------------------------------------------------------
# bus-shaped hardware state through the Trainer
# ---------------------------------------------------------------------------

def _batch(model, key, n=16):
    return {"x": jax.random.normal(key, (n, model.in_dim)),
            "y": jax.random.randint(key, (n,), 0, model.n_classes)}


def test_bus_state_threads_through_fit():
    session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                hardware="emu_onchip", backend="emu",
                                n_buses=2, recalibrate_every=2,
                                log_every=10**9)
    init = session.init_state()
    assert init["hw"]["drift"].shape == (2, 50, 20)
    batch = _batch(session.model, jax.random.PRNGKey(0))
    state, metrics = session.fit(lambda step: batch, total_steps=4,
                                 verbose=False)
    assert state["hw"]["drift"].shape == (2, 50, 20)
    assert float(jnp.abs(state["hw"]["drift"]).max()) > 0.0
    # buses drift independently: the two banks' paths differ
    d = np.asarray(state["hw"]["drift"])
    assert np.abs(d[0] - d[1]).max() > 0.0
    assert np.isfinite(float(metrics["loss"]))
    assert metrics["hw_residual_rms"] <= metrics["hw_drift_rms"] * 2.0


def test_bus_state_checkpoints_and_replays(tmp_path):
    """The (n_buses, rows, cols) hardware state saves/restores through the
    Trainer's checkpoint path and replays bit-for-bit."""
    def build(ckpt_dir):
        return api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                 hardware="emu_onchip", backend="emu",
                                 n_buses=2, recalibrate_every=2,
                                 ckpt_dir=ckpt_dir, ckpt_every=2,
                                 log_every=10**9)

    s_full = build(str(tmp_path / "a"))
    batch = _batch(s_full.model, jax.random.PRNGKey(3))
    state_full, _ = s_full.fit(lambda step: batch, total_steps=4,
                               verbose=False)
    # same run, interrupted at step 2 then resumed from the checkpoint
    s_half = build(str(tmp_path / "b"))
    s_half.fit(lambda step: batch, total_steps=2, verbose=False)
    s_resume = build(str(tmp_path / "b"))
    restored, start = s_resume.trainer.restore_or_init()
    assert start == 2
    assert restored["hw"]["drift"].shape == (2, 50, 20)
    state_resumed, _ = s_resume.fit(lambda step: batch, total_steps=4,
                                    verbose=False)
    np.testing.assert_array_equal(np.asarray(state_full["hw"]["drift"]),
                                  np.asarray(state_resumed["hw"]["drift"]))
    for a, b in zip(jax.tree_util.tree_leaves(state_full["params"]),
                    jax.tree_util.tree_leaves(state_resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# api knob + BENCH_bus_scaling schema
# ---------------------------------------------------------------------------

def test_build_session_n_buses_override():
    session = api.build_session(arch="mnist_mlp", smoke=True, n_buses=3,
                                log_every=10**9)
    assert session.config.dfa.photonics.n_buses == 3
    assert photonics.preset("offchip_bpd").n_buses == 1  # presets untouched


def test_bus_scaling_bench_schema(tmp_path):
    from benchmarks import bus_scaling

    rows = bus_scaling.run(bus_counts=(1, 2), steps=2, train_n=256,
                           test_n=128, hidden=(16,))
    assert [r["n_buses"] for r in rows] == [1, 2]
    path = bus_scaling.write_report(rows, str(tmp_path))
    assert path.endswith("BENCH_bus_scaling.json")
    from repro.bench import load_bench

    report = load_bench(path)  # raises on schema drift
    for k in ("acc_b1", "acc_b2", "cycles_b1", "pj_per_mac_b2",
              "cycle_speedup", "acc_spread_pts"):
        assert k in report["metrics"]


# ---------------------------------------------------------------------------
# bus yield / failure (dead rings, failed buses)
# ---------------------------------------------------------------------------

def test_active_buses_and_schedule_stretch():
    cfg = photonics.PhotonicConfig(n_buses=4, failed_buses=(1, 3))
    assert photonics.active_buses(cfg) == 2
    assert photonics.alive_bus_indices(cfg) == (0, 2)
    healthy = photonics.PhotonicConfig(n_buses=4)
    # panels reroute onto the 2 survivors: the schedule stretches to the
    # 2-bus length, never crashes
    assert photonics.n_bank_passes(200, cfg) == photonics.n_bank_passes(
        200, photonics.PhotonicConfig(n_buses=2))
    assert photonics.gemm_cycles(100, 200, cfg) > photonics.gemm_cycles(
        100, 200, healthy)
    with pytest.raises(ValueError, match="all .* buses failed"):
        photonics.active_buses(
            photonics.PhotonicConfig(n_buses=2, failed_buses=(0, 1)))


def test_failed_bus_matmul_matches_alive_bus_count():
    """The emu product on a chip with a dead bus equals the product on a
    healthy chip with the surviving bus count (same rerouted schedule)."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (6, 61))
    b = jax.random.normal(jax.random.fold_in(key, 1), (40, 61))
    failed = photonics.PhotonicConfig(n_buses=3, failed_buses=(1,), mrr=IDEAL)
    alive = photonics.PhotonicConfig(n_buses=2, mrr=IDEAL)
    out_failed = channel.emulated_matmul(a, b, failed)
    out_alive = channel.emulated_matmul(a, b, alive)
    np.testing.assert_allclose(np.asarray(out_failed), np.asarray(out_alive),
                               rtol=1e-5)
    # and the bus-tiled layout only spans the survivors
    a_t, b_t, _ = channel.tile_operands(a, b, failed)
    assert a_t.shape[1] == 2 and b_t.shape[1] == 2


def test_failed_bus_selects_matching_drift_state():
    """Carried drift state keeps the physical (n_buses, rows, cols) shape;
    the signal chain reads the alive banks' rows only."""
    cfg = photonics.PhotonicConfig(n_buses=3, failed_buses=(0,), mrr=IDEAL)
    key = jax.random.PRNGKey(2)
    a = jax.random.normal(key, (4, 45))
    b = jax.random.normal(jax.random.fold_in(key, 1), (30, 45))
    state = drift.init_state(cfg)
    # big residual on the DEAD bus only: must not perturb the output
    state["drift"] = state["drift"].at[0].set(3.0)
    with drift.use_state(state):
        perturbed = channel.emulated_matmul(a, b, cfg)
    clean = channel.emulated_matmul(a, b, cfg)
    np.testing.assert_allclose(np.asarray(perturbed), np.asarray(clean),
                               rtol=1e-6)


def test_dead_rings_degrade_not_crash():
    """Fabrication yield: dead rings zero their weights — the projection
    stays finite and close-ish to exact, degrading with the dead rate."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (8, 40))
    b = jax.random.normal(jax.random.fold_in(key, 1), (50, 40))
    exact = a @ b.T
    errs = []
    for rate in (0.0, 0.02, 0.2):
        cfg = photonics.PhotonicConfig(
            n_buses=2, mrr=dataclasses.replace(IDEAL, dead_ring_rate=rate))
        out = np.asarray(channel.emulated_matmul(a, b, cfg))
        assert np.all(np.isfinite(out))
        errs.append(np.abs(out - np.asarray(exact)).max())
    assert errs[0] == pytest.approx(0.0, abs=1e-4)  # rate 0: no mask
    assert errs[0] <= errs[1] <= errs[2]
    assert errs[2] > errs[1]  # a 20% dead chip is visibly worse


def test_dead_ring_mask_deterministic_chip_property():
    device = dataclasses.replace(IDEAL, dead_ring_rate=0.1, yield_seed=7)
    m1 = np.asarray(mrr.dead_ring_mask(device, (2, 50, 20)))
    m2 = np.asarray(mrr.dead_ring_mask(device, (2, 50, 20)))
    np.testing.assert_array_equal(m1, m2)
    other = np.asarray(mrr.dead_ring_mask(
        dataclasses.replace(device, yield_seed=8), (2, 50, 20)))
    assert np.abs(m1 - other).max() > 0  # a different chip
    assert 0.8 < m1.mean() < 0.98  # ~10% dead


def test_training_degrades_gracefully_with_yield_faults():
    """Acceptance: a chip with a failed bus AND dead rings still trains —
    loss decreases and stays finite instead of crashing."""
    device = mrr.MRRConfig(adc_bits=10, drift_sigma=0.0, cal_noise=0.0,
                           dead_ring_rate=0.05)
    hw = photonics.PhotonicConfig(n_buses=3, failed_buses=(1,),
                                  noise_std=0.019, mrr=device)
    session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                hardware=hw, backend="emu", log_every=10**9)
    batch = _batch(session.model, jax.random.PRNGKey(5), n=32)
    state = session.init_state()
    (loss0, _), _ = session.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(0))
    state, metrics = session.fit(lambda step: batch, total_steps=12,
                                 verbose=False)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) < float(loss0)


def test_failed_bus_crosstalk_respects_physical_topology():
    """Inter-bus thermal coupling follows the PHYSICAL bank stack: a dead
    (undriven) bank between two survivors separates them, so a degraded
    3-bus chip is NOT the same device as a healthy 2-bus chip — unless
    the dead bank sits at the end of the stack, where it shields nothing."""
    device = dataclasses.replace(IDEAL, bus_crosstalk=0.05)
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (5, 45))
    b = jax.random.normal(jax.random.fold_in(key, 1), (30, 45))

    def out(n_buses, failed=()):
        cfg = photonics.PhotonicConfig(n_buses=n_buses, failed_buses=failed,
                                       mrr=device)
        return np.asarray(channel.emulated_matmul(a, b, cfg))

    # dead middle bank: survivors 0 and 2 are separated -> different from
    # a healthy 2-bus chip whose banks are adjacent
    assert np.abs(out(3, (1,)) - out(2)).max() > 1e-6
    # dead END bank: survivors 0 and 1 keep their adjacency -> identical
    np.testing.assert_allclose(out(3, (2,)), out(2), rtol=1e-6)
