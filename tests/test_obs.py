"""repro.obs: metrics registry + sinks, Chrome-trace recording/export,
hardware health monitoring, the disabled-observer fast path, and the
end-to-end wiring into fit / the serve engine / the simulators."""

from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro import api, configs, obs, sim
from repro.core import photonics
from repro.hardware.mrr import MRRConfig
from repro.obs.hwmon import DEAD_RING_FACTOR, HardwareMonitor
from repro.obs.metrics import Histogram, JsonlSink, MemorySink, Registry
from repro.obs.trace import HOST_PID, TraceRecorder
from repro.serve import Engine, Request
from repro.sim.autotune import expected_drift_sigma


# ---------------------------------------------------------------------------
# metrics: instruments, percentiles, sinks, the batched drain
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy():
    """The bounded-window histogram uses numpy's default (linear
    interpolation) percentile method — cross-check on awkward sizes."""
    rng = np.random.default_rng(0)
    for n in (1, 2, 7, 100, 999):
        xs = rng.normal(size=n)
        h = Histogram("h", window=2048)
        for x in xs:
            h.observe(float(x))
        for q in (0, 25, 50, 90, 99, 100):
            assert h.percentile(q) == pytest.approx(
                float(np.percentile(xs, q)), rel=1e-12, abs=1e-12)
    with pytest.raises(ValueError):
        Histogram("empty").percentile(50)


def test_histogram_window_bounds_memory():
    h = Histogram("h", window=8)
    for i in range(100):
        h.observe(float(i))
    assert len(h) == 8
    assert h.percentile(0) == 92.0  # only the last window remains


def test_registry_drain_is_one_transfer_and_handles_host_values():
    """``drain`` accepts a mix of device arrays and plain floats and
    returns pure host floats (the jit-safe one-device_get contract)."""
    dev = {"a": jax.numpy.float32(1.5), "b": 2.0, "c": np.float64(3.0)}
    host = Registry.drain(dev)
    assert host == {"a": 1.5, "b": 2.0, "c": 3.0}
    assert all(type(v) is float for v in host.values())


def test_registry_record_fans_out_to_sinks(tmp_path):
    path = str(tmp_path / "m.jsonl")
    reg = Registry([MemorySink(4), JsonlSink(path)])
    reg.record(3, {"loss": jax.numpy.float32(0.25), "lr": 1e-3})
    reg.counter("steps").inc()
    reg.close()
    mem = reg.sinks[0].rows
    assert len(mem) == 1 and mem[0]["step"] == 3
    assert mem[0]["metrics"]["loss"] == 0.25
    rows = [json.loads(ln) for ln in open(path)]
    assert rows[0]["metrics"] == mem[0]["metrics"]
    snap = reg.snapshot()
    assert snap["steps"] == 1.0 and snap["loss"] == 0.25


def test_memory_sink_is_a_bounded_ring():
    reg = Registry([MemorySink(3)])
    for s in range(10):
        reg.emit(s, {"x": float(s)})
    assert [r["step"] for r in reg.sinks[0].rows] == [7, 8, 9]


# ---------------------------------------------------------------------------
# trace: span nesting, event schema, export round-trip
# ---------------------------------------------------------------------------

def test_trace_span_nesting_and_roundtrip(tmp_path):
    rec = TraceRecorder()
    with rec.span("outer", step=1):
        with rec.span("inner"):
            pass
        rec.instant("mark", note="hi")
    rec.counter("load", {"q": 3})
    path = obs.export.write(rec, str(tmp_path / "t.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    by_name = {e["name"]: e for e in evs}
    inner, outer = by_name["inner"], by_name["outer"]
    assert inner["ph"] == outer["ph"] == "X"
    # LIFO close order: inner is recorded first and nests inside outer
    assert evs.index(inner) < evs.index(outer)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"] == {"step": 1}
    assert by_name["mark"]["ph"] == "i" and by_name["mark"]["s"] == "t"
    assert by_name["load"]["ph"] == "C" and by_name["load"]["args"]["q"] == 3.0


def test_trace_events_carry_required_chrome_fields():
    """Every emitted event has the fields the Perfetto importer needs."""
    rec = TraceRecorder()
    with rec.span("s"):
        pass
    rec.instant("i")
    rec.counter("c", {"v": 1})
    rec.async_begin("a", 7)
    rec.async_instant("m", 7)
    rec.async_end("a", 7)
    rec.name_process(5, "p")
    rec.name_thread(5, 1, "t")
    for ev in rec.events:
        assert {"ph", "name", "pid"} <= set(ev)
        if ev["ph"] != "M":
            assert "ts" in ev
        if ev["ph"] == "X":
            assert "dur" in ev
        if ev["ph"] in "bne":
            assert ev["id"] == 7
    # metadata names are deduplicated
    n_meta = len([e for e in rec.events if e["ph"] == "M"])
    rec.name_process(5, "p")
    rec.name_thread(5, 1, "t")
    assert len([e for e in rec.events if e["ph"] == "M"]) == n_meta


# ---------------------------------------------------------------------------
# hwmon: OU prediction, derived gauges, edge-triggered alerts
# ---------------------------------------------------------------------------

def _mon(**kw):
    dev = MRRConfig()  # drift ON by default
    kw.setdefault("recalibrate_every", 16)
    return HardwareMonitor(dev, **kw), dev


def test_hwmon_gauges_and_expected_sigma():
    mon, dev = _mon()
    exp = expected_drift_sigma(dev, 16)
    out = mon.sample(0, {"hw_residual_rms": exp, "hw_drift_rms": 0.04,
                         "hw_dead_rings": 2.0})
    assert out["hw_expected_sigma"] == pytest.approx(exp)
    assert out["hw_residual_vs_expected"] == pytest.approx(1.0)
    assert out["hw_effective_bits"] == pytest.approx(
        photonics.sigma_to_resolution(exp))
    assert out["hw_dead_rings"] == 2.0
    # rows without hardware scalars produce no gauges (e.g. pure-emu runs)
    assert mon.sample(1, {"loss": 0.5}) == {}


def test_hwmon_alert_is_edge_triggered():
    """One alert per budget crossing: below→above fires, staying above
    does not re-fire, and recovery re-arms the trigger."""
    mon, _ = _mon(drift_budget=0.03)
    seq = [0.01, 0.02, 0.05, 0.06, 0.07, 0.02, 0.01, 0.04]
    for step, resid in enumerate(seq):
        mon.sample(step, {"hw_residual_rms": resid})
    assert [a.step for a in mon.alerts] == [2, 7]
    a = mon.alerts[0]
    assert a.kind == "drift_budget" and a.value == 0.05 and a.budget == 0.03
    assert "exceeds" in a.message


def test_hwmon_default_budget_and_dead_ring_threshold():
    mon, dev = _mon()
    assert mon.drift_budget == pytest.approx(0.5 * dev.drift_sigma)
    assert mon.dead_ring_threshold == pytest.approx(
        DEAD_RING_FACTOR * dev.drift_sigma)


# ---------------------------------------------------------------------------
# the disabled-observer fast path
# ---------------------------------------------------------------------------

def test_null_observer_allocates_nothing():
    null = obs.resolve(None)
    assert null is obs.NULL and not null.enabled
    # every span call hands back the one shared context manager
    assert null.span("a") is null.span("b", x=1) is obs.NullObserver._NULL_CTX
    with null.span("a"):
        pass
    null.event("e")
    null.counter("c", {"v": 1})
    assert null.log_step(0, {"loss": 1.0}) == {}
    assert null.alerts == []
    null.close()


def test_resolve_contract():
    assert obs.resolve(False) is obs.NULL
    assert isinstance(obs.resolve(True), obs.Observer)
    o = obs.Observer()
    assert obs.resolve(o) is o


# ---------------------------------------------------------------------------
# observer log_step: drain + hwmon merge + alert surfacing
# ---------------------------------------------------------------------------

def test_observer_log_step_merges_hwmon_and_emits_alert_instants():
    mon, _ = _mon(drift_budget=0.03)
    o = obs.Observer(hwmon=mon)
    host = o.log_step(1, {"loss": jax.numpy.float32(0.5),
                          "hw_residual_rms": 0.05})
    assert host["loss"] == 0.5
    assert "hw_effective_bits" in host and "hw_expected_sigma" in host
    # the hwmon gauges reach the metrics sinks, not just the trace
    row = o.metrics.sinks[0].rows[-1]
    assert "hw_effective_bits" in row["metrics"]
    warns = [e for e in o.trace.events
             if e["ph"] == "i" and e["name"].startswith("WARN:")]
    assert len(warns) == 1 and warns[0]["args"]["budget"] == 0.03
    assert o.metrics.counter("hwmon_alerts").value == 1.0
    # staying over budget adds no second instant (edge trigger)
    o.log_step(2, {"hw_residual_rms": 0.06})
    warns = [e for e in o.trace.events if e["name"].startswith("WARN:")]
    assert len(warns) == 1


# ---------------------------------------------------------------------------
# wiring: Session.fit, the serve engine, the simulators
# ---------------------------------------------------------------------------

def test_fit_with_observer_records_steps_and_hw_gauges(tmp_path):
    session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                hardware="emu_offchip", backend="emu",
                                recalibrate_every=4, log_every=2)
    observer = session.observe(
        metrics_path=str(tmp_path / "m.jsonl"),
        trace_path=str(tmp_path / "t.json"))
    x = np.random.default_rng(0).normal(
        size=(8, session.model.in_dim)).astype(np.float32)
    y = np.zeros((8,), np.int32)
    session.fit(lambda s: {"x": x, "y": y}, total_steps=8, verbose=False)
    path = observer.close()
    doc = json.load(open(path))
    steps = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "step"]
    assert len(steps) == 8
    recals = [e for e in doc["traceEvents"] if e["name"] == "recalibration"]
    assert {e["args"]["step"] for e in recals} == {4}
    rows = [json.loads(ln) for ln in open(tmp_path / "m.jsonl")]
    assert [r["step"] for r in rows] == [2, 4, 6, 8]  # log_every=2
    assert all("hw_effective_bits" in r["metrics"] for r in rows)
    assert all("loss" in r["metrics"] for r in rows)


def test_fit_without_observer_unchanged():
    """observer=None keeps the seed behaviour: same losses, no trace."""
    def run(observer):
        session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                    log_every=4)
        x = np.random.default_rng(1).normal(
            size=(8, session.model.in_dim)).astype(np.float32)
        y = np.zeros((8,), np.int32)
        _, metrics = session.fit(lambda s: {"x": x, "y": y}, total_steps=4,
                                 verbose=False, observer=observer)
        return Registry.drain(metrics)
    a, b = run(None), run(obs.Observer())
    assert a.keys() == b.keys()
    assert a["loss"] == pytest.approx(b["loss"])


def test_engine_observer_emits_request_lifecycle_tracks():
    model = configs.get("qwen1.5-0.5b").make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    o = obs.Observer()
    eng = Engine(model, params, batch_slots=2, max_len=32, observer=o)
    reqs = [Request(prompt=[i + 1], max_new=3) for i in range(3)]
    eng.run(reqs)
    evs = o.trace.events
    begins = [e for e in evs if e["ph"] == "b"]
    ends = [e for e in evs if e["ph"] == "e"]
    # per request: one request-track + QUEUED + PREFILL + DECODE begins,
    # all of them closed
    assert len(begins) == len(ends) == 3 * 4
    firsts = [e for e in evs if e["ph"] == "n" and e["name"] == "FIRST_TOKEN"]
    assert len(firsts) == 3
    # phases of one request share its id and appear in lifecycle order
    rid = begins[0]["id"]
    names = [e["name"] for e in evs
             if e.get("id") == rid and e["ph"] in "bne"]
    assert names.index("QUEUED") < names.index("PREFILL") < \
        names.index("DECODE")
    spans = {e["name"] for e in evs if e["ph"] == "X"}
    assert {"prefill_tick", "decode_tick"} <= spans


def test_pipeline_trace_matches_report_occupancy(tmp_path):
    pcfg = photonics.PhotonicConfig(n_buses=2)
    work = [sim.Gemm("g0", t=4, m=64, k=48), sim.Gemm("g1", t=4, m=32, k=48)]
    rec = obs.TraceRecorder()
    report = sim.simulate(work, pcfg, include_weight_update=False, trace=rec)
    evs = [e for e in rec.events if e["ph"] == "X"]
    assert len(evs) == len(report.events)
    # per-stage track durations sum to the busy time occupancy came from
    alive_wall_us = report.n_buses * report.wall_clock_s * 1e6
    for stage, occ in report.occupancy.items():
        dur = sum(e["dur"] for e in evs if e["args"]["stage"] == stage)
        assert dur == pytest.approx(occ * alive_wall_us, rel=1e-9, abs=1e-9)
    # path form writes a loadable file
    path = str(tmp_path / "pipe.json")
    sim.simulate(work, pcfg, include_weight_update=False, trace=path)
    doc = json.load(open(path))
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert pids == {obs.export.SIM_PIPELINE_PID}


def test_serving_trace_rounds_and_requests(tmp_path):
    model = api.build_model("mnist_mlp")
    svc = sim.service_model(model, photonics.PhotonicConfig())
    reqs = [sim.RequestSpec(arrival_s=0.0, prompt_len=9, decode_len=5)]
    path = str(tmp_path / "serve.json")
    rep = sim.simulate_serving(reqs, svc, batch_slots=4, prefill_chunk=4,
                               trace=path)
    evs = json.load(open(path))["traceEvents"]
    rounds = [e for e in evs if e["ph"] == "X"]
    assert len(rounds) == rep.rounds
    assert sum(e["dur"] for e in rounds) == pytest.approx(
        rep.makespan_s * 1e6, rel=1e-9)
    assert len([e for e in evs if e["ph"] == "b"]) == 1
    assert len([e for e in evs if e["ph"] == "e"]) == 1
    firsts = [e for e in evs if e["ph"] == "n" and e["name"] == "FIRST_TOKEN"]
    # first token lands at the end of the last prefill round
    assert firsts[0]["ts"] == pytest.approx(
        (svc.round_s(4) * 2 + svc.round_s(1)) * 1e6, rel=1e-9)
    assert HOST_PID not in {e["pid"] for e in evs}


# ---------------------------------------------------------------------------
# summarize CLI round-trip
# ---------------------------------------------------------------------------

def test_summarize_roundtrip(tmp_path, capsys):
    path = str(tmp_path / "m.jsonl")
    reg = Registry([JsonlSink(path)])
    for s in range(10):
        reg.emit(s, {"loss": 1.0 / (s + 1), "steps_per_s": 100.0 + s})
    reg.close()
    from repro.obs import summarize
    rc = summarize.main([path])
    assert rc == 0
    out = capsys.readouterr().out
    assert "loss" in out and "steps_per_s" in out
