"""Device-level MRR emulation (repro.hardware): Lorentzian ring physics,
thermal crosstalk + compensation, OU resonance drift, in-situ calibration,
the "emu" PhotonicBackend, and the Trainer's carried hardware state."""

import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algos, api
from repro.core import photonics
from repro.hardware import calibrate, channel, drift, mrr

IDEAL = mrr.MRRConfig.ideal()


def _emu_ideal_cfg(**kw):
    return photonics.PhotonicConfig(noise_std=0.0, mrr=IDEAL, **kw)


# ---------------------------------------------------------------------------
# ring physics
# ---------------------------------------------------------------------------

def test_ring_weight_landmarks():
    """Lorentzian BPD transfer: -1 on resonance, 0 at one half-width,
    asymptotically +1, strictly monotone in |detuning|."""
    np.testing.assert_allclose(float(mrr.ring_weight(0.0, 1.0)), -1.0)
    np.testing.assert_allclose(float(mrr.ring_weight(1.0, 1.0)), 0.0, atol=1e-7)
    assert float(mrr.ring_weight(1e4, 1.0)) > 0.999999
    d = jnp.linspace(0.0, 50.0, 512)
    w = np.asarray(mrr.ring_weight(d, 1.3))
    assert np.all(np.diff(w) > 0)
    assert np.all((w >= -1.0) & (w < 1.0))


@hypothesis.given(w=st.floats(-1.0, 0.999), gamma=st.floats(0.1, 5.0))
@hypothesis.settings(max_examples=50, deadline=None)
def test_inscription_round_trip(w, gamma):
    """inscribe is the exact inverse of ring_weight on the reachable range."""
    cfg = dataclasses.replace(IDEAL, gamma=gamma)
    w2 = mrr.ring_weight(mrr.inscribe(jnp.float32(w), cfg), gamma)
    assert abs(float(w2) - w) < 1e-5  # f32 transport


def test_unreachable_weight_clips_to_ceiling():
    cfg = mrr.MRRConfig()  # delta_max = 100γ
    d = mrr.inscribe(jnp.float32(1.0), cfg)
    assert np.isfinite(float(d))
    w_back = float(mrr.ring_weight(d, cfg.gamma))
    assert abs(w_back - mrr.w_ceiling(cfg)) < 1e-6
    assert 1.0 - w_back < 3e-4  # ≥ ~12 bits of inscription range


def test_heater_dac_quantizes_commands():
    cfg = dataclasses.replace(IDEAL, heater_bits=6, delta_max=10.0)
    w = jax.random.uniform(jax.random.PRNGKey(0), (500,), minval=-1, maxval=0.9)
    d = np.asarray(calibrate.command_deltas(w, cfg))
    assert len(np.unique(d)) <= 2**6
    np.testing.assert_allclose(
        d, np.round(d / 10.0 * 63) / 63 * 10.0, rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# crosstalk
# ---------------------------------------------------------------------------

def test_crosstalk_perturbs_and_compensation_recovers():
    key = jax.random.PRNGKey(1)
    w = jax.random.uniform(key, (50, 20), minval=-0.95, maxval=0.95)
    xt = dataclasses.replace(IDEAL, crosstalk=0.01, compensate_crosstalk=False)
    xt_comp = dataclasses.replace(xt, compensate_crosstalk=True, ct_iters=3)

    def realized(cfg):
        d = calibrate.command_deltas(w, cfg, row_axis=-2, col_axis=-1)
        d = d + mrr.crosstalk_leak(d, cfg, row_axis=-2, col_axis=-1)
        return mrr.ring_weight(d, cfg.gamma)

    err_raw = float(jnp.abs(realized(xt) - w).max())
    err_comp = float(jnp.abs(realized(xt_comp) - w).max())
    assert err_raw > 1e-3  # the leak is a real perturbation
    assert err_comp < err_raw / 5  # Jacobi pre-inversion recovers it


def test_neighbor_sum_edges_are_zero_padded():
    x = jnp.ones((3, 4))
    n = np.asarray(mrr.neighbor_sum(x, row_axis=0, col_axis=1))
    assert n[0, 0] == 2.0 and n[1, 1] == 4.0 and n[0, 1] == 3.0


def test_grid_axes_infer_bare_and_tiled_layouts():
    """The documented bare (rows, cols) layout works with default axes all
    the way through the inscription path (crosstalk on)."""
    w = jax.random.uniform(jax.random.PRNGKey(8), (5, 4),
                           minval=-0.9, maxval=0.9)
    cfg = photonics.PhotonicConfig(mrr=mrr.MRRConfig())  # crosstalk != 0
    realized = channel.realized_weights(w, cfg)
    assert realized.shape == w.shape
    assert float(jnp.abs(realized - w).max()) < 0.05
    tiled = w.reshape(1, 5, 1, 4)
    np.testing.assert_allclose(
        np.asarray(channel.realized_weights(tiled, cfg))[0, :, 0, :],
        np.asarray(realized), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# drift + calibration state
# ---------------------------------------------------------------------------

def test_ou_process_is_stationary():
    key = jax.random.PRNGKey(2)
    x = jnp.zeros((50, 20))
    for i in range(400):
        x = drift.ou_step(x, jax.random.fold_in(key, i), sigma=0.3, tau=40.0)
    assert 0.2 < float(jnp.std(x)) < 0.4  # relaxed to N(0, σ²)


def test_advance_recalibration_tracks_drift():
    cfg = photonics.PhotonicConfig(mrr=mrr.MRRConfig(
        drift_sigma=0.5, drift_tau=20.0, cal_noise=0.0))
    key = jax.random.PRNGKey(3)
    st_recal = drift.init_state(cfg)
    st_free = drift.init_state(cfg)
    for i in range(100):
        k = jax.random.fold_in(key, i)
        st_recal = calibrate.advance(st_recal, cfg, i, k, recalibrate_every=1)
        st_free = calibrate.advance(st_free, cfg, i, k, recalibrate_every=0)
    # same OU path in both; perfect every-step calibration zeroes the
    # residual while the free-running bank carries the full drift
    np.testing.assert_allclose(np.asarray(st_recal["drift"]),
                               np.asarray(st_free["drift"]), rtol=1e-6)
    assert float(jnp.abs(drift.residual(st_recal)).max()) < 1e-6
    assert float(jnp.std(drift.residual(st_free))) > 0.2


def test_active_state_context_scopes_the_drift():
    cfg = photonics.PhotonicConfig(mrr=mrr.MRRConfig())
    state = drift.init_state(cfg)
    state["drift"] = state["drift"] + 1.0
    key = jax.random.PRNGKey(4)
    a = jax.random.normal(key, (4, 10))
    b = jax.random.normal(jax.random.fold_in(key, 1), (8, 10))
    clean = channel.emulated_matmul(a, b, _emu_ideal_cfg())
    with drift.use_state(state):
        drifted = channel.emulated_matmul(a, b, _emu_ideal_cfg())
    after = channel.emulated_matmul(a, b, _emu_ideal_cfg())
    assert drift.active_state() is None
    assert float(jnp.abs(drifted - clean).max()) > 1e-3
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(after))


# ---------------------------------------------------------------------------
# the emu backend: registration + equivalence with ref
# ---------------------------------------------------------------------------

def test_emu_backend_registered_and_stateful():
    be = photonics.get_backend("emu")
    assert be.name == "emu"
    assert be.stateful_hardware
    assert not photonics.get_backend("ref").stateful_hardware
    for name in ("emu_ideal", "emu_offchip", "emu_onchip"):
        assert photonics.preset(name).mrr is not None


def test_emu_matmul_matches_ref_noiseless():
    key = jax.random.PRNGKey(5)
    e = jax.random.normal(key, (3, 7, 33))
    b = jax.random.normal(jax.random.fold_in(key, 1), (61, 33))
    out_ref = photonics.photonic_project(e, b, photonics.preset("ideal"),
                                         backend="ref")
    out_emu = photonics.photonic_project(e, b, _emu_ideal_cfg(), backend="emu")
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_emu),
                               rtol=1e-5, atol=1e-5)


def test_emu_noise_statistics_match_ref():
    """Per-pass BPD noise accumulated over panels == the reference path's
    single draw, statistically (documented noise tolerance: 3%)."""
    cfg = photonics.PhotonicConfig(noise_std=0.098, mrr=IDEAL)
    key = jax.random.PRNGKey(6)
    a = jax.random.uniform(key, (512, 10), minval=-1, maxval=1)
    b = jax.random.uniform(jax.random.fold_in(key, 1), (800, 10),
                           minval=-1, maxval=1)
    out = channel.emulated_matmul(a, b, cfg, key=jax.random.fold_in(key, 2))
    err = np.asarray(out - a @ b.T)
    s = float(jnp.max(jnp.abs(a)) * jnp.max(jnp.abs(b)))
    assert abs(err.std() / (0.098 * s) - 1.0) < 0.03


def test_emu_noise_accumulates_over_contraction_passes():
    cfg = photonics.PhotonicConfig(noise_std=0.1, mrr=IDEAL)
    key = jax.random.PRNGKey(7)
    a = jax.random.uniform(key, (256, 80), minval=-1, maxval=1)  # 4 passes
    b = jax.random.uniform(jax.random.fold_in(key, 1), (100, 80),
                           minval=-1, maxval=1)
    out = channel.emulated_matmul(a, b, cfg, key=jax.random.fold_in(key, 2))
    err = np.asarray(out - a @ b.T)
    s = float(jnp.max(jnp.abs(a)) * jnp.max(jnp.abs(b)))
    expect = photonics.noise_sigma_total(80, 1.0, 1.0, cfg) * s
    assert abs(err.std() / expect - 1.0) < 0.05


@pytest.mark.parametrize("algo", algos.list_algos())
def test_emu_equivalent_to_ref_for_every_algorithm(algo):
    """Satellite: zero drift/crosstalk emu == ref for every registered
    algorithm's value_and_grad (losses identical, grads to f32 tolerance)."""
    s_ref = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                              hardware="ideal", backend="ref", log_every=10**9)
    s_emu = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                              hardware=_emu_ideal_cfg(), backend="emu",
                              log_every=10**9)
    key = jax.random.PRNGKey(0)
    state = s_ref.init_state(key)
    batch = {"x": jax.random.normal(key, (16, 64)),
             "y": jax.random.randint(key, (16,), 0, 10)}
    (l_ref, _), g_ref = s_ref.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    (l_emu, _), g_emu = s_emu.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    np.testing.assert_allclose(float(l_ref), float(l_emu), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_emu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Trainer integration: carried hardware state
# ---------------------------------------------------------------------------

def _batch(model, key, n=16):
    return {"x": jax.random.normal(key, (n, model.in_dim)),
            "y": jax.random.randint(key, (n,), 0, model.n_classes)}


def test_fit_threads_and_advances_hardware_state():
    session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                hardware="emu_onchip", backend="emu",
                                recalibrate_every=2, log_every=10**9)
    cfg = session.config
    assert cfg.recalibrate_every == 2
    batch = _batch(session.model, jax.random.PRNGKey(0))
    init = session.init_state()
    assert set(init["hw"]) == {"drift", "cal"}
    # the paper's physical bank, one bus: (n_buses, rows, cols)
    assert init["hw"]["drift"].shape == (1, 50, 20)
    state, metrics = session.fit(lambda step: batch, total_steps=4,
                                 verbose=False)
    assert float(jnp.abs(state["hw"]["drift"]).max()) > 0.0
    assert np.isfinite(float(metrics["loss"]))
    assert metrics["hw_drift_rms"] > 0.0
    # recalibrated 2 steps ago at most: residual ≤ drift magnitude
    assert metrics["hw_residual_rms"] <= metrics["hw_drift_rms"] * 2.0


def test_default_session_enables_drift_and_recalibration_for_emu():
    session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                backend="emu", log_every=10**9)
    assert session.config.dfa.photonics.mrr is not None
    assert session.config.dfa.photonics.mrr.stateful
    assert session.config.recalibrate_every == 500


def test_non_stateful_backends_carry_no_hw_state():
    session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                hardware="emu_onchip", backend="ref",
                                log_every=10**9)
    assert "hw" not in session.init_state()
    assert session.config.recalibrate_every == 0


def test_fit_replay_is_deterministic_with_hardware_state():
    """(seed, step)-derived drift: two identical fits agree bit-for-bit —
    the restart-safety contract extends to the hardware state."""
    def fit_once():
        session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                    hardware="emu_onchip", backend="emu",
                                    recalibrate_every=2, log_every=10**9)
        batch = _batch(session.model, jax.random.PRNGKey(3))
        return session.fit(lambda step: batch, total_steps=3, verbose=False)

    s1, m1 = fit_once()
    s2, m2 = fit_once()
    np.testing.assert_array_equal(np.asarray(s1["hw"]["drift"]),
                                  np.asarray(s2["hw"]["drift"]))
    assert float(m1["loss"]) == float(m2["loss"])


# ---------------------------------------------------------------------------
# the drift-recovery study + BENCH_hardware schema
# ---------------------------------------------------------------------------

def test_drift_recovery_bench_schema(tmp_path):
    from benchmarks import drift_recovery

    rows = drift_recovery.run(steps=4, train_n=256, test_n=128, hidden=(16,))
    assert {r["variant"] for r in rows} == {
        "ref", "emu_static", "emu_drift", "emu_drift_recal"}
    path = drift_recovery.write_report(rows, str(tmp_path))
    assert path.endswith("BENCH_hardware.json")
    from repro.bench import load_bench

    report = load_bench(path)  # raises on schema drift
    assert "acc_emu_drift_recal" in report["metrics"]


@pytest.mark.slow
def test_emu_dfa_trains_mnist_within_2pct_of_ref():
    """Acceptance: build_session(algo="dfa", backend="emu") — default
    drift + in-situ calibration — within 2 accuracy points of "ref"."""
    from repro.data import mnist, pipeline
    from repro.models.mlp import MLPClassifier

    data = mnist.load((8192, 2048), seed=0)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    acc = {}
    for backend in ("ref", "emu"):
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=0)
        session = api.build_session(arch=MLPClassifier(hidden=(128, 128)),
                                    algo="dfa", backend=backend,
                                    log_every=10**9)
        state, _ = session.fit(pipe.batch, total_steps=512, verbose=False)
        ev = session.evaluate(state, pipe.eval_batches(xte, yte, 256))
        acc[backend] = 100 * ev["accuracy"]
    assert abs(acc["emu"] - acc["ref"]) < 2.0, acc
