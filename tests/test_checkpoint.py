"""Fault tolerance: atomic checkpoints, keep-k, bit-exact resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import mnist, pipeline
from repro.models.mlp import MLPClassifier
from repro.train import SGDM, Trainer, TrainerConfig
from repro.train import checkpoint as ckpt
from repro.utils.tree import tree_allclose


def test_save_load_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.array([1, 2], jnp.int32)}}
    path = str(tmp_path / "t.msgpack")
    ckpt.save(path, tree, step=7)
    loaded, step = ckpt.load(path, template=tree)
    assert step == 7
    assert tree_allclose(tree, loaded)


def test_dtype_cast_on_restore(tmp_path):
    tree = {"w": jnp.ones((4,), jnp.float32)}
    path = str(tmp_path / "t.msgpack")
    ckpt.save(path, tree)
    template = {"w": jnp.ones((4,), jnp.bfloat16)}
    loaded, _ = ckpt.load(path, template=template)
    assert loaded["w"].dtype == jnp.bfloat16


def test_manager_keep_k(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(1)}
    for s in [10, 20, 30, 40]:
        mgr.save(s, tree)
    assert mgr.all_steps() == [30, 40]
    assert mgr.latest_step() == 40


def test_atomic_write_never_leaves_partial(tmp_path):
    path = str(tmp_path / "c.msgpack")
    ckpt.save(path, {"x": jnp.zeros(1000)})
    assert not os.path.exists(path + ".tmp")


def test_crash_resume_is_bit_exact(tmp_path):
    """Train 6 steps straight vs train 3 + 'crash' + resume 3 — identical."""
    data = mnist.load((512, 128), seed=0)
    xtr, ytr = data["train"]
    pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=32, seed=0)
    model = MLPClassifier(hidden=(32,))

    def make(dirname, every):
        return Trainer(model, TrainerConfig(
            algo="dfa", optimizer=SGDM(lr=0.01, momentum=0.9), seed=5,
            ckpt_dir=str(tmp_path / dirname), ckpt_every=every,
            log_every=10**9))

    # straight run
    tr_a = make("a", every=100)
    state_a, _ = tr_a.fit(pipe.batch, total_steps=6, verbose=False)

    # interrupted run: 3 steps, checkpoint, new Trainer resumes to 6
    tr_b1 = make("b", every=3)
    tr_b1.fit(pipe.batch, total_steps=3, verbose=False)
    tr_b2 = make("b", every=3)
    state_b, _ = tr_b2.fit(pipe.batch, total_steps=6, verbose=False)

    assert int(state_a["step"]) == int(state_b["step"]) == 6
    assert tree_allclose(state_a["params"], state_b["params"], rtol=1e-6, atol=1e-7)
    assert tree_allclose(state_a["opt"]["mom"], state_b["opt"]["mom"], rtol=1e-6, atol=1e-7)


def test_elastic_restore_across_dtype_and_template(tmp_path):
    """Checkpoints are logical arrays: restoring into a template with
    different device placement/dtype works (elastic-restart contract)."""
    model = MLPClassifier(in_dim=8, hidden=(16,), n_classes=4)
    params = model.init(jax.random.PRNGKey(0))
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, {"params": params})
    # template with bf16 leaves
    template = {"params": jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.bfloat16), params)}
    restored, step = mgr.restore(template)
    assert step == 1
    got = restored["params"]["h0"]["w"]
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(params["h0"]["w"], np.float32),
        rtol=1e-2)
