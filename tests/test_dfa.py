"""DFA engine correctness: exact Eq. 1 reproduction, exact head grads,
alignment diagnostics, error compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dfa, photonics
from repro.core.feedback import FeedbackConfig, make_feedback
from repro.models.mlp import MLPClassifier


@pytest.fixture(scope="module")
def setup():
    model = MLPClassifier(in_dim=20, hidden=(32, 24), n_classes=5)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    cfg = dfa.DFAConfig()
    fb = dfa.init_feedback(model, jax.random.PRNGKey(7), cfg)
    batch = {
        "x": jax.random.normal(key, (16, 20)),
        "y": jax.random.randint(key, (16,), 0, 5),
    }
    return model, params, cfg, fb, batch


def test_dfa_matches_paper_eq1(setup):
    """Engine gradients == hand-derived δ(k) = B(k)e ⊙ g'(a(k)) (Eq. 1)."""
    model, params, cfg, fb, batch = setup
    (loss, _), grads = dfa.value_and_grad(model, cfg)(
        params, fb, batch, jax.random.PRNGKey(1))

    W1, b1 = params["h0"]["w"][0], params["h0"]["b"][0]
    W2, b2 = params["h1"]["w"][0], params["h1"]["b"][0]
    Wo, bo = params["head"]["w"], params["head"]["b"]
    x = batch["x"]
    a1 = x @ W1 + b1
    h1 = jnp.maximum(a1, 0)
    a2 = h1 @ W2 + b2
    h2 = jnp.maximum(a2, 0)
    p = jax.nn.softmax(h2 @ Wo + bo)
    e = (p - jax.nn.one_hot(batch["y"], 5)) / x.shape[0]
    d1 = (e @ fb["h0"][0].T) * (a1 > 0)
    d2 = (e @ fb["h1"][0].T) * (a2 > 0)

    np.testing.assert_allclose(grads["h0"]["w"][0], x.T @ d1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(grads["h0"]["b"][0], d1.sum(0), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(grads["h1"]["w"][0], h1.T @ d2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(grads["h1"]["b"][0], d2.sum(0), rtol=1e-5, atol=1e-6)
    # output layer: exact update with e (paper: "W(l) is updated using e")
    np.testing.assert_allclose(grads["head"]["w"], h2.T @ e, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(grads["head"]["b"], e.sum(0), rtol=1e-5, atol=1e-6)


def test_head_gradient_exactly_matches_backprop(setup):
    model, params, cfg, fb, batch = setup
    (_, _), dfa_g = dfa.value_and_grad(model, cfg)(params, fb, batch, jax.random.PRNGKey(1))
    (_, _), bp_g = dfa.bp_value_and_grad(model)(params, fb, batch, None)
    align = dfa.grad_alignment(dfa_g, bp_g)
    np.testing.assert_allclose(float(align["head"]), 1.0, atol=1e-5)


def test_loss_value_identical_dfa_vs_bp(setup):
    model, params, cfg, fb, batch = setup
    (ld, _), _ = dfa.value_and_grad(model, cfg)(params, fb, batch, jax.random.PRNGKey(1))
    (lb, _), _ = dfa.bp_value_and_grad(model)(params, fb, batch, None)
    np.testing.assert_allclose(float(ld), float(lb), rtol=1e-6)


def test_photonic_noise_perturbs_hidden_but_not_head(setup):
    model, params, _, fb, batch = setup
    noisy = dfa.DFAConfig(photonics=photonics.preset("onchip_bpd"))
    clean = dfa.DFAConfig()
    (_, _), gn = dfa.value_and_grad(model, noisy)(params, fb, batch, jax.random.PRNGKey(2))
    (_, _), gc = dfa.value_and_grad(model, clean)(params, fb, batch, jax.random.PRNGKey(2))
    assert np.abs(np.asarray(gn["h0"]["w"] - gc["h0"]["w"])).max() > 1e-4
    # head path is digital (exact) in the architecture
    np.testing.assert_allclose(gn["head"]["w"], gc["head"]["w"], rtol=1e-6)


def test_alignment_improves_with_training(setup):
    """Feedback-alignment signature: cos(DFA, BP) of hidden layers grows
    during early training (align-then-memorise, paper ref [29])."""
    model, params, cfg, fb, batch = setup
    vg = jax.jit(dfa.value_and_grad(model, cfg))
    bp = jax.jit(dfa.bp_value_and_grad(model))

    def cos_now(p):
        (_, _), gd = vg(p, fb, batch, jax.random.PRNGKey(0))
        (_, _), gb = bp(p, fb, batch, None)
        a = dfa.grad_alignment(gd, gb)
        return float(a["h1"])

    before = cos_now(params)
    p = params
    for i in range(60):
        (_, _), g = vg(p, fb, batch, jax.random.PRNGKey(i))
        p = jax.tree_util.tree_map(lambda w, gw: w - 0.05 * gw, p, g)
    after = cos_now(p)
    assert after > before
    assert after > 0.05  # positively aligned (random would be ~0 ± 1/√n)


def test_error_compression_modes():
    e = jax.random.normal(jax.random.PRNGKey(0), (64, 10))
    t = dfa.compress_error(e, "ternary")
    vals = np.unique(np.round(np.asarray(jnp.abs(t)), 6))
    assert len(vals) <= 2  # {0, scale}
    q = dfa.compress_error(e, "int8")
    assert np.abs(np.asarray(q - e)).max() < np.abs(np.asarray(e)).max() / 64
    np.testing.assert_array_equal(np.asarray(dfa.compress_error(e, "none")), np.asarray(e))


def test_ternary_error_still_trains(setup):
    """Paper ref [48]: ternarised error gives competitive training signal."""
    model, params, _, fb, batch = setup
    cfg = dfa.DFAConfig(error_compress="ternary")
    vg = jax.jit(dfa.value_and_grad(model, cfg))
    p = params
    (l0, _), _ = vg(p, fb, batch, jax.random.PRNGKey(0))
    for i in range(80):
        (_, _), g = vg(p, fb, batch, jax.random.PRNGKey(i))
        p = jax.tree_util.tree_map(lambda w, gw: w - 0.05 * gw, p, g)
    (l1, _), _ = vg(p, fb, batch, jax.random.PRNGKey(99))
    assert float(l1) < float(l0) * 0.7


def test_feedback_shapes_and_scaling():
    cfg = FeedbackConfig()
    b = make_feedback(jax.random.PRNGKey(0), 4, 256, 32, cfg)
    assert b.shape == (4, 256, 32)
    # default scale 1/sqrt(d_out): ||B e|| ≈ ||e||
    e = jax.random.normal(jax.random.PRNGKey(1), (32,))
    ratio = float(jnp.linalg.norm(b[0] @ e) / jnp.linalg.norm(e))
    assert 0.5 < ratio < 2.0
    shared = make_feedback(jax.random.PRNGKey(0), 4, 256, 32, FeedbackConfig(shared=True))
    assert shared.shape == (1, 256, 32)
    tern = make_feedback(jax.random.PRNGKey(0), 1, 64, 16, FeedbackConfig(ternary=True))
    assert set(np.unique(np.sign(np.asarray(tern)))) <= {-1.0, 0.0, 1.0}
