"""Diagnostics plane: alignment probe analytic anchors, noise-budget
attribution closure, anomaly detection, and the crash-safe JSONL / hwmon
guard satellites."""

from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro import api, obs
from repro.core import photonics
from repro.hardware import channel, drift, mrr
from repro.obs import summarize
from repro.obs.anomaly import AnomalyDetector
from repro.obs.attribution import noise_budget
from repro.obs.introspect import AlignmentProbe
from repro.obs.metrics import JsonlSink
from repro.utils import prng


def _batch(model, n=32, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return {"x": jax.random.normal(kx, (n, model.in_dim)),
            "y": jax.random.randint(ky, (n,), 0, model.n_classes)}


def _session(**kw):
    kw.setdefault("arch", "mnist_mlp")
    kw.setdefault("smoke", True)
    kw.setdefault("algo", "dfa")
    kw.setdefault("log_every", 10**9)
    return api.build_session(**kw)


# ---------------------------------------------------------------------------
# alignment probe: analytic anchors
# ---------------------------------------------------------------------------

def test_probe_emits_per_layer_and_global_alignment():
    s = _session(hardware="ideal", backend="ref")
    state = s.trainer.init_state()
    m = jax.device_get(AlignmentProbe(s.trainer).probe(state, _batch(s.model)))
    segs = [spec.name for spec in s.model.segment_specs()]
    for name in segs + ["head"]:
        assert f"align_{name}" in m
        assert f"gnorm_dfa_{name}" in m and f"gnorm_bp_{name}" in m
        assert f"upd_ratio_{name}" in m and m[f"upd_ratio_{name}"] >= 0
    assert "align_global" in m
    # DFA's head gradient IS the exact BP gradient (Eq. 1 trains the head
    # directly on the true error) — alignment exactly 1 by construction
    assert m["align_head"] == pytest.approx(1.0, abs=1e-5)
    # the MLP's parameter-free embed segment must not produce a 0/0 row
    assert "align_embed" not in m


def test_feedback_equal_to_head_weights_gives_unit_alignment():
    # B = W makes the DFA delta e·Wᵀ — exactly BP's cotangent at the last
    # hidden layer — so with ideal photonics the last segment's gradient
    # equals BP's and its alignment is identically 1 (ISSUE anchor).
    s = _session(hardware="ideal", backend="ref")
    state = s.trainer.init_state()
    last = s.model.segment_specs()[-1].name
    state["fb"] = dict(state["fb"],
                       **{last: state["params"]["head"]["w"][None]})
    m = jax.device_get(AlignmentProbe(s.trainer).probe(state, _batch(s.model)))
    assert m[f"align_{last}"] == pytest.approx(1.0, abs=1e-5)


def test_random_feedback_alignment_is_small_at_init():
    # a fresh random bank is an arbitrary direction in a ~10^3-dim space:
    # |cos| concentrates at O(1/sqrt(n)), far from the trained regime
    s = _session(hardware="ideal", backend="ref")
    state = s.trainer.init_state()
    m = jax.device_get(AlignmentProbe(s.trainer).probe(state, _batch(s.model)))
    for name in (spec.name for spec in s.model.segment_specs()):
        assert abs(m[f"align_{name}"]) < 0.5


@pytest.mark.slow
def test_alignment_increases_over_short_fit():
    # the paper's central training claim: feedback alignment grows as the
    # network adapts its forward weights to the fixed feedback bank
    from repro.data import mnist

    s = _session(hardware="ideal", backend="ref", probe_every=150,
                 prefetch=0)
    data = mnist.load(seed=0)
    xtr, ytr = data["train"]
    xtr = xtr[:, : s.model.in_dim]
    from repro.data import pipeline

    pipe = pipeline.ArrayClassification(xtr, ytr, 64, 0)
    ob = obs.Observer()
    s.fit(pipe.batch, total_steps=301, verbose=False, observer=ob)
    rows = [r for r in ob.metrics.sinks[0].rows
            if "align_global" in r["metrics"]]
    assert len(rows) >= 3
    first = rows[0]["metrics"]["align_global"]
    last = rows[-1]["metrics"]["align_global"]
    assert last > first + 0.05, (first, last)


def test_probe_on_and_off_training_states_are_bitwise_identical():
    # the probe re-derives its keys from (seed, step) and never donates:
    # training must not see it (utils.prng.consume discipline, RL001)
    batch = _batch(api.build_model("mnist_mlp", smoke=True))

    def final_state(probe_every):
        s = _session(hardware="emu_offchip", backend="emu",
                     recalibrate_every=3, probe_every=probe_every)
        state, _ = s.fit(lambda i: batch, total_steps=6, verbose=False)
        return jax.device_get(state)

    plain, probed = final_state(None), final_state(2)
    for a, b in zip(jax.tree_util.tree_leaves(plain),
                    jax.tree_util.tree_leaves(probed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fit_probe_rows_land_in_observer_at_cadence():
    s = _session(hardware="emu_onchip", backend="emu", probe_every=2,
                 recalibrate_every=4)
    ob = obs.Observer()
    batch = _batch(s.model, n=16)
    s.fit(lambda i: batch, total_steps=5, verbose=False, observer=ob)
    probe_rows = [r for r in ob.metrics.sinks[0].rows
                  if "align_global" in r["metrics"]]
    assert [r["step"] for r in probe_rows] == [0, 2, 4]
    # emu sessions fold the noise budget into the same probe row
    m = probe_rows[-1]["metrics"]
    assert "nb_total_var" in m and "nb_closure" in m


def test_probe_every_without_observer_gets_inmemory_observer():
    # probe rows need a sink even when the caller passed no observer; the
    # fit must still run and return finite metrics
    s = _session(hardware="ideal", backend="ref", probe_every=2)
    batch = _batch(s.model, n=8)
    state, metrics = s.fit(lambda i: batch, total_steps=3, verbose=False)
    assert np.isfinite(float(jax.device_get(metrics["loss"])))


# ---------------------------------------------------------------------------
# noise-budget attribution
# ---------------------------------------------------------------------------

def _onchip_cfg():
    cfg = photonics.PRESETS["emu_onchip"]
    assert cfg.mrr is not None
    return cfg


def test_noise_budget_closure_within_tolerance_on_emu_onchip():
    cfg = _onchip_cfg()
    key = jax.random.PRNGKey(3)
    ka, kb, kd, kn = jax.random.split(key, 4)
    e = 0.3 * jax.random.normal(ka, (64, 10))
    b = jax.random.normal(kb, (32, 10)) / np.sqrt(10)
    hw = drift.init_state(cfg, kd)
    hw = dict(hw, drift=0.02 * jax.random.normal(kd, hw["drift"].shape))
    m = jax.device_get(noise_budget(e, b, cfg, kn,
                                    residual=drift.residual(hw)))
    # components sum to the observed error power within 10% (ISSUE
    # acceptance) — the closure gauge is the noise-model consistency test
    assert m["nb_closure"] == pytest.approx(1.0, abs=0.1)
    # sampled thermal error matches photonics.noise_sigma_total's
    # analytic accounting (channel.py vs core/photonics.py cross-check)
    assert m["nb_thermal_vs_analytic"] == pytest.approx(1.0, abs=0.15)
    # on-chip BPD noise dominates this regime
    assert m["nb_thermal_var"] > m["nb_adc_var"] > 0
    assert m["nb_total_var"] > 0


def test_noise_budget_all_sources_emitted_and_drift_attributed():
    cfg = _onchip_cfg()
    key = jax.random.PRNGKey(5)
    e = 0.3 * jax.random.normal(key, (32, 10))
    b = jax.random.normal(jax.random.fold_in(key, 1), (16, 10)) / 3.0
    resid = 0.05 * jax.random.normal(jax.random.fold_in(key, 2),
                                     (1, cfg.bank_rows, cfg.bank_cols))
    m = jax.device_get(noise_budget(e, b, cfg, jax.random.fold_in(key, 3),
                                    residual=resid))
    for src in channel.NOISE_SOURCES:
        assert f"nb_{src}_var" in m
    assert m["nb_drift_var"] > 0
    # sources the device doesn't have measure exactly zero
    assert m["nb_shot_var"] == 0.0
    assert m["nb_dead_rings_var"] == 0.0


def test_ideal_twin_preserves_geometry_and_kills_noise():
    cfg = _onchip_cfg()
    twin = channel.ideal_twin(cfg)
    assert (twin.bank_rows, twin.bank_cols, twin.n_buses) == (
        cfg.bank_rows, cfg.bank_cols, cfg.n_buses)
    assert twin.noise_std == 0.0 and twin.input_bits is None
    assert twin.mrr.adc_bits is None and not twin.mrr.stateful
    # the twin's product is the plain matmul to f32 tolerance
    e = 0.3 * jax.random.normal(jax.random.PRNGKey(0), (8, 10))
    b = jax.random.normal(jax.random.PRNGKey(1), (6, 10)) / 3.0
    out = channel.emulated_matmul(e, b, twin, kernel="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(e @ b.T),
                               rtol=0, atol=1e-4)


def test_isolate_source_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown noise source"):
        channel.isolate_source(_onchip_cfg(), "gremlins")


def test_isolate_source_turns_on_exactly_one_source():
    cfg = _onchip_cfg()
    thermal = channel.isolate_source(cfg, "thermal")
    assert thermal.noise_std == cfg.noise_std
    assert thermal.mrr.adc_bits is None
    adc = channel.isolate_source(cfg, "adc")
    assert adc.noise_std == 0.0
    assert adc.mrr.adc_bits == cfg.mrr.adc_bits


# ---------------------------------------------------------------------------
# anomaly detection
# ---------------------------------------------------------------------------

def test_anomaly_detector_is_edge_triggered_and_rearms():
    det = AnomalyDetector(watch=("loss",), warmup=4, k=6.0)
    for i in range(10):
        assert det.observe(i, {"loss": 1.0 + 0.01 * (i % 2)}) == []
    # a spike fires exactly once while it persists...
    assert len(det.observe(10, {"loss": 50.0})) == 1
    assert det.observe(11, {"loss": 50.0}) == []
    # ...and after enough in-band rows the detector re-arms.  (stats keep
    # updating out-of-band, so the center converges to the new level)
    fired = []
    for i in range(12, 40):
        fired += det.observe(i, {"loss": 1.0})
    fired += det.observe(40, {"loss": 50.0})
    assert len(det.alerts) >= 2


def test_anomaly_detector_nonfinite_always_alerts():
    det = AnomalyDetector(watch=("loss",), warmup=4)
    det.observe(0, {"loss": 1.0})
    alerts = det.observe(1, {"loss": float("nan")})
    assert len(alerts) == 1 and "non-finite" in alerts[0].message


def test_anomaly_detector_skips_unwatched_and_missing_keys():
    det = AnomalyDetector(watch=("loss",), warmup=0)
    assert det.observe(0, {"accuracy": 0.5}) == []
    assert det.observe(1, {}) == []


def test_observer_surfaces_anomaly_as_instant_counter_and_flag():
    ob = obs.Observer(anomaly=AnomalyDetector(watch=("loss",), warmup=2,
                                              k=6.0))
    for i in range(8):
        ob.log_step(i, {"loss": 1.0})
    host = ob.log_step(8, {"loss": 99.0})
    assert host.get("anomaly_loss") == 1.0
    assert ob.metrics.snapshot()["anomaly_alerts"] == 1.0
    names = [e["name"] for e in ob.trace.events if e["ph"] == "i"]
    assert "WARN:anomaly:loss" in names
    assert any(isinstance(a, obs.AnomalyAlert) for a in ob.alerts)


# ---------------------------------------------------------------------------
# satellites: hwmon guards + crash-safe JSONL
# ---------------------------------------------------------------------------

def test_ref_backend_fit_with_observer_logs_no_hw_keys():
    s = _session(hardware="onchip_bpd", backend="ref", log_every=2)
    ob = s.observe()
    assert ob.hwmon is None
    batch = _batch(s.model, n=8)
    s.fit(lambda i: batch, total_steps=4, verbose=False, observer=ob)
    keys = {k for r in ob.metrics.sinks[0].rows for k in r["metrics"]}
    assert not any(k.startswith("hw_") for k in keys), sorted(keys)


def test_emu_ideal_fit_with_observer_logs_no_hw_keys():
    # drift-free device: hw state exists but is identically zero — the
    # trainer must not emit vacuous hw gauges, nor for_session a monitor
    s = _session(hardware="emu_ideal", backend="emu", log_every=2)
    ob = s.observe()
    assert ob.hwmon is None
    batch = _batch(s.model, n=8)
    s.fit(lambda i: batch, total_steps=4, verbose=False, observer=ob)
    keys = {k for r in ob.metrics.sinks[0].rows for k in r["metrics"]}
    assert not any(k.startswith("hw_") for k in keys), sorted(keys)


def test_jsonl_sink_truncates_torn_tail_on_reopen(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"t": 1.0, "step": 1, "metrics": {"a": 1.0}})
                + "\n")
        f.write('{"t": 2.0, "step"')  # torn mid-write by a kill
    sink = JsonlSink(path)
    sink.write({"t": 3.0, "step": 2, "metrics": {"a": 2.0}})
    sink.close()
    rows = summarize.read_rows(path)
    assert [r["step"] for r in rows] == [1, 2]


def test_jsonl_sink_reopen_noops_on_clean_and_empty_files(tmp_path):
    path = str(tmp_path / "m.jsonl")
    JsonlSink(path).close()  # missing -> created empty
    sink = JsonlSink(path)   # empty -> untouched
    sink.write({"t": 1.0, "step": 1, "metrics": {}})
    sink.close()
    assert len(summarize.read_rows(path)) == 1
    JsonlSink(path).close()  # clean newline-terminated file -> untouched
    assert len(summarize.read_rows(path)) == 1


def test_interrupted_fit_leaves_parseable_jsonl(tmp_path):
    path = str(tmp_path / "run.jsonl")
    s = _session(hardware="emu_offchip", backend="emu", log_every=1,
                 prefetch=0)
    ob = s.observe(metrics_path=path)
    batch = _batch(s.model, n=8)

    def data_fn(step):
        if step == 3:
            raise RuntimeError("boom")
        return batch

    with pytest.raises(RuntimeError, match="boom"):
        s.fit(data_fn, total_steps=10, verbose=False, observer=ob)
    rows = summarize.read_rows(path)  # parses cleanly or the test fails
    assert [r["step"] for r in rows] == [1, 2, 3]
    assert all("loss" in r["metrics"] for r in rows)


def test_read_rows_rejects_mid_file_corruption(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write('{"t": 1.0, "step": 1, "metrics": {}}\n')
        f.write("{torn\n")
        f.write('{"t": 2.0, "step": 2, "metrics": {}}\n')
    with pytest.raises(ValueError, match="corrupt JSONL"):
        summarize.read_rows(path)


# ---------------------------------------------------------------------------
# PRNG discipline: the probe's key streams never collide with training's
# ---------------------------------------------------------------------------

def test_probe_key_stream_is_disjoint_from_training_streams():
    step = 7
    train_keys = {tuple(np.asarray(prng.step_key(0, step, name)))
                  for name in ("noise", "hardware", "data")}
    probe_key = tuple(np.asarray(prng.step_key(0, step, "probe-nb")))
    assert probe_key not in train_keys
