import importlib.util
import os
import sys


def _install_hypothesis_stub():
    """The container image lacks hypothesis; substitute the minimal stub
    (tests/_hypothesis_stub.py) so property tests still run as seeded
    random-example batches.  No-op when real hypothesis is installed."""
    if importlib.util.find_spec("hypothesis") is not None:
        return
    path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (subprocess compile) tests")
