"""Fused emu kernel (kernels.emu_matmul) — drop-in equivalence with the
unfused ``channel.bank_product`` chain, the pallas↔xla bit-stream contract,
``noise_sigma_total`` accounting, the ``emu_kernel`` seam (env/flag/session
resolution), and the fused path through full training sessions."""

import dataclasses
import os

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import photonics
from repro.hardware import channel, drift, mrr
from repro.kernels import emu_matmul

KEY = jax.random.PRNGKey(7)


def _operands(t, m, k, cfg, seed=0):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, (t, k), jnp.float32)
    b = jax.random.normal(kb, (m, k), jnp.float32)
    a_n, b_n, _sa, _sb = photonics.normalise_operands(a, b, cfg)
    return a_n, b_n


def _quiet(n_buses=1, failed_buses=(), dead=0.0, adc_bits=8):
    """A noiseless device config (drift off, σ=0): fused and unfused paths
    must agree to f32 tolerance, not just statistically."""
    return photonics.PhotonicConfig(
        noise_std=0.0, n_buses=n_buses, failed_buses=failed_buses,
        mrr=mrr.MRRConfig(adc_bits=adc_bits, drift_sigma=0.0,
                          dead_ring_rate=dead))


# ---------------------------------------------------------------------------
# noiseless bit-tolerance vs the unfused chain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize(
    "t,m,k,n_buses", [
        (4, 50, 20, 1),     # exactly one bank panel
        (7, 61, 83, 2),     # ragged in every dimension
        (5, 61, 83, 5),     # panels not divisible by buses (idle slots)
        (16, 130, 260, 4),  # multi-tile rows and cycles
    ])
def test_fused_matches_unfused_noiseless(impl, t, m, k, n_buses):
    cfg = _quiet(n_buses=n_buses)
    a_n, b_n = _operands(t, m, k, cfg)
    ref = channel.bank_product(a_n, b_n, cfg, None)
    out = emu_matmul.fused_bank_product(a_n, b_n, cfg, None, impl=impl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_matches_unfused_failed_bus_and_dead_rings():
    cfg = _quiet(n_buses=3, failed_buses=(1,), dead=0.05)
    a_n, b_n = _operands(9, 120, 130, cfg)
    ref = channel.bank_product(a_n, b_n, cfg, None)
    for impl in ("xla", "pallas"):
        out = emu_matmul.fused_bank_product(a_n, b_n, cfg, None, impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_fused_matches_unfused_with_carried_drift_state():
    """A nonzero carried residual perturbs the detunings identically on
    both paths (drift σ stays 0 so the comparison is deterministic)."""
    cfg = _quiet(n_buses=2)
    a_n, b_n = _operands(6, 77, 95, cfg)
    state = drift.init_state(cfg)
    state["drift"] = 0.08 * jax.random.normal(KEY, state["drift"].shape)
    residual = drift.residual(state)
    ref = channel.bank_product(a_n, b_n, cfg, None, residual=residual)
    for impl in ("xla", "pallas"):
        out = emu_matmul.fused_bank_product(a_n, b_n, cfg, None,
                                            residual=residual, impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_fused_no_adc_path():
    cfg = _quiet(n_buses=2, adc_bits=None)
    a_n, b_n = _operands(3, 55, 44, cfg)
    ref = channel.bank_product(a_n, b_n, cfg, None)
    out = emu_matmul.fused_bank_product(a_n, b_n, cfg, None, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@hypothesis.given(
    t=st.integers(1, 17), m=st.integers(1, 140), k=st.integers(1, 150),
    n_buses=st.integers(1, 5), adc_bits=st.sampled_from([None, 4, 8]),
    dead=st.sampled_from([0.0, 0.1]),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_fused_equivalence_fuzz(t, m, k, n_buses, adc_bits, dead):
    """Property: fused-xla ≡ unfused over random shapes, bus counts, ADC
    widths and dead-ring masks (noiseless)."""
    cfg = _quiet(n_buses=n_buses, adc_bits=adc_bits, dead=dead)
    a_n, b_n = _operands(t, m, k, cfg, seed=t * 977 + m * 31 + k)
    ref = channel.bank_product(a_n, b_n, cfg, None)
    out = emu_matmul.fused_bank_product(a_n, b_n, cfg, None, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# noise: pallas↔xla bit-stream contract + σ accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shot", [0.0, 0.05])
def test_pallas_and_xla_share_the_noise_stream(shot):
    """Both impls draw from the same (key, slot, element) counters, so the
    noisy outputs agree to accumulation-order tolerance — not merely in
    distribution."""
    cfg = photonics.PhotonicConfig(
        noise_std=0.202, n_buses=2,
        mrr=mrr.MRRConfig(adc_bits=8, drift_sigma=0.0, shot_noise=shot))
    a_n, b_n = _operands(9, 73, 100, cfg)
    x = emu_matmul.fused_bank_product(a_n, b_n, cfg, KEY, impl="xla")
    p = emu_matmul.fused_bank_product(a_n, b_n, cfg, KEY, impl="pallas")
    np.testing.assert_allclose(np.asarray(x), np.asarray(p),
                               rtol=1e-5, atol=1e-5)


def test_fused_noise_requires_key():
    cfg = photonics.PhotonicConfig(noise_std=0.1, n_buses=1,
                                   mrr=mrr.MRRConfig(drift_sigma=0.0))
    a_n, b_n = _operands(2, 10, 20, cfg)
    with pytest.raises(ValueError, match="PRNG key"):
        emu_matmul.fused_bank_product(a_n, b_n, cfg, None, impl="xla")


def test_fused_noise_matches_sigma_accounting():
    """Accumulated fused-path noise must follow ``noise_sigma_total``'s
    real-panel accounting (idle padded slots draw nothing)."""
    cfg = photonics.PhotonicConfig(
        noise_std=0.202, n_buses=4,
        mrr=mrr.MRRConfig(adc_bits=None, drift_sigma=0.0))
    k_dim = 1024
    a_n, b_n = _operands(16, 64, k_dim, cfg)
    clean = emu_matmul.fused_bank_product(
        a_n, b_n, dataclasses.replace(cfg, noise_std=0.0), None, impl="xla")
    f = jax.jit(lambda kk: emu_matmul.fused_bank_product(
        a_n, b_n, cfg, kk, impl="xla"))
    devs = jnp.stack([f(jax.random.fold_in(KEY, i)) - clean
                      for i in range(48)])
    # operands are normalised, so expected σ uses unit scales
    expected = photonics.noise_sigma_total(k_dim, 1.0, 1.0, cfg)
    assert abs(float(jnp.std(devs)) / expected - 1.0) < 0.05
    assert abs(float(jnp.mean(devs))) < 0.05 * expected


def test_counter_gaussian_moments():
    """The Irwin–Hall(4) draw: exact mean/unit variance, symmetric, and
    the designed mild kurtosis deficit (2.7 vs 3)."""
    c0 = jax.lax.broadcasted_iota(jnp.uint32, (1 << 19,), 0)
    z = emu_matmul.counter_gaussian(jnp.uint32(3), jnp.uint32(5), c0,
                                    jnp.uint32(11))
    assert abs(float(z.mean())) < 5e-3
    assert abs(float(z.std()) - 1.0) < 5e-3
    assert abs(float(jnp.mean(z ** 3))) < 2e-2
    assert abs(float(jnp.mean(z ** 4)) - 2.7) < 5e-2


def test_shot_stream_is_distinct():
    """Thermal and shot draws come from disjoint counter streams."""
    c0 = jax.lax.broadcasted_iota(jnp.uint32, (4096,), 0)
    z1 = emu_matmul.counter_gaussian(jnp.uint32(3), jnp.uint32(5), c0,
                                     jnp.uint32(0))
    z2 = emu_matmul.counter_gaussian(
        jnp.uint32(3), jnp.uint32(5),
        c0 ^ jnp.uint32(emu_matmul._SHOT_STREAM), jnp.uint32(0))
    corr = float(jnp.corrcoef(z1, z2)[0, 1])
    assert abs(corr) < 0.05


# ---------------------------------------------------------------------------
# the emu_kernel seam: resolution, env override, session plumbing
# ---------------------------------------------------------------------------

def test_resolve_emu_kernel_specs():
    assert channel.resolve_emu_kernel("ref") == "ref"
    assert channel.resolve_emu_kernel("xla") == "xla"
    assert channel.resolve_emu_kernel("pallas") == "pallas"
    with pytest.raises(ValueError, match="unknown emu kernel"):
        channel.resolve_emu_kernel("cuda")


def test_resolve_emu_kernel_env(monkeypatch):
    monkeypatch.setenv("REPRO_EMU_KERNEL", "xla")
    assert channel.resolve_emu_kernel(None) == "xla"
    assert channel.resolve_emu_kernel("auto") == "xla"
    # explicit spec wins over the environment
    assert channel.resolve_emu_kernel("ref") == "ref"
    monkeypatch.setenv("REPRO_EMU_KERNEL", "")
    # empty string is "unset", not an unknown spec
    assert channel.resolve_emu_kernel(None) in ("ref", "pallas")


def test_emulated_matmul_kernel_seam():
    cfg = _quiet(n_buses=2)
    a = jax.random.normal(KEY, (5, 70), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (33, 70), jnp.float32)
    ref = channel.emulated_matmul(a, b, cfg, kernel="ref")
    out = channel.emulated_matmul(a, b, cfg, kernel="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_build_session_emu_kernel_requires_emu_backend():
    with pytest.raises(ValueError, match="requires backend='emu'"):
        api.build_session(arch="mnist_mlp", smoke=True, emu_kernel="xla")
    with pytest.raises(ValueError, match="unknown emu kernel"):
        api.build_session(arch="mnist_mlp", smoke=True, backend="emu",
                          hardware="emu_ideal", emu_kernel="bogus")


@pytest.mark.parametrize("algo", ["bp", "dfa", "dfa-fused", "dfa-layerwise"])
def test_session_fused_matches_ref_all_algorithms(algo):
    """One train step per algorithm on a noiseless emu device: the fused
    session must land on the same loss as the unfused one."""
    hw = _quiet(n_buses=2)
    losses = {}
    for kern in ("ref", "xla"):
        session = api.build_session(arch="mnist_mlp", algo=algo, smoke=True,
                                    backend="emu", hardware=hw,
                                    emu_kernel=kern, recalibrate_every=0,
                                    log_every=10 ** 9)
        key = jax.random.PRNGKey(0)
        batch = {
            "x": jax.random.normal(key, (8, session.model.in_dim)),
            "y": jax.random.randint(key, (8,), 0, session.model.n_classes),
        }
        _state, metrics = session.fit(lambda step: batch, total_steps=1,
                                      verbose=False)
        losses[kern] = float(metrics["loss"])
    assert losses["xla"] == pytest.approx(losses["ref"], rel=1e-4)


def test_trainer_fit_smoke_fused_drifting_device():
    """Two steps of the full drifting-device loop (noise + OU drift +
    in-situ recalibration) through the fused kernel: finite loss, carried
    hardware state."""
    session = api.build_session(arch="mnist_mlp", algo="dfa", smoke=True,
                                backend="emu", hardware="emu_onchip",
                                emu_kernel="xla", recalibrate_every=1,
                                log_every=10 ** 9)
    key = jax.random.PRNGKey(0)
    batch = {
        "x": jax.random.normal(key, (8, session.model.in_dim)),
        "y": jax.random.randint(key, (8,), 0, session.model.n_classes),
    }
    _state, metrics = session.fit(lambda step: batch, total_steps=2,
                                  verbose=False)
    assert np.isfinite(float(metrics["loss"]))


def test_backend_field_routes_kernel(monkeypatch):
    """EmulatedMRRBackend.emu_kernel reaches emulated_matmul: patching the
    fused entry point must intercept the projection."""
    calls = []
    real = emu_matmul.fused_bank_product

    def spy(*args, **kwargs):
        calls.append(kwargs.get("impl"))
        return real(*args, **kwargs)

    monkeypatch.setattr(emu_matmul, "fused_bank_product", spy)
    cfg = _quiet(n_buses=1)
    a = jax.random.normal(KEY, (3, 40), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (21, 40), jnp.float32)
    backend = photonics.EmulatedMRRBackend(emu_kernel="xla")
    backend.matmul(a, b, cfg, key=None)
    assert calls == ["xla"]
