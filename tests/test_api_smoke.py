"""Registry rot check: every registered algorithm must complete a real
``build_session(...).fit`` step on the smoke mnist_mlp arch.  A new
registry entry that can't train fails here the moment it is registered
(benchmarks/run.py --smoke is the CLI twin of this test)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algos, api


def _batch(model, key, n=16):
    return {"x": jax.random.normal(key, (n, model.in_dim)),
            "y": jax.random.randint(key, (n,), 0, model.n_classes)}


@pytest.mark.parametrize("algo", algos.list_algos())
def test_every_registered_algorithm_fits_one_step(algo):
    session = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                                hardware="ideal", log_every=10**9)
    batch = _batch(session.model, jax.random.PRNGKey(0))
    state, metrics = session.fit(lambda step: batch, total_steps=1,
                                 verbose=False)
    assert int(state["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    # the step actually moved the parameters
    init = session.init_state()
    moved = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(init["params"])))
    assert moved > 0.0


@pytest.mark.parametrize("algo", ["dfa", "dfa-layerwise"])
def test_dfa_family_reduces_loss_over_a_few_steps(algo):
    session = api.build_session(arch="mnist_mlp", smoke=True, algo=algo,
                                hardware="ideal", log_every=10**9)
    key = jax.random.PRNGKey(1)
    batch = _batch(session.model, key, n=64)
    state = session.init_state()
    _, m0 = session.step(state, batch)
    for _ in range(30):
        state, metrics = session.step(state, batch)
    assert float(metrics["loss"]) < float(m0["loss"])


def test_fused_step_available_for_every_algorithm():
    """fused_step falls back to compose-with-optimizer when not overridden;
    dfa-fused provides the real fused path.  All must run one step."""
    from repro.train.optimizer import SGDM

    for name in algos.list_algos():
        session = api.build_session(arch="mnist_mlp", smoke=True, algo=name,
                                    optimizer=SGDM(lr=0.01, momentum=0.9))
        state = session.init_state()
        batch = _batch(session.model, jax.random.PRNGKey(2), n=8)
        step = jax.jit(session.fused_step())
        new_params, new_opt, loss = step(
            state["params"], state["fb"], state["opt"], batch,
            jax.random.PRNGKey(3))
        assert np.isfinite(float(loss))
        assert (jax.tree_util.tree_structure(new_params)
                == jax.tree_util.tree_structure(state["params"]))
