"""The pluggable API surface: algorithm registry round-trips, the
repro.api facade, and the PhotonicBackend execution seam."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algos, api
from repro.algos.dfa import grad_alignment
from repro.core import photonics


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_exposes_builtin_algorithms():
    names = algos.list_algos()
    for required in ("bp", "dfa", "dfa-fused", "dfa-layerwise"):
        assert required in names


def test_registry_round_trip():
    for name in algos.list_algos():
        algo = algos.get(name)
        assert isinstance(algo, algos.Algorithm)
        assert algo.name == name


def test_registry_unknown_name_raises_keyerror():
    with pytest.raises(KeyError):
        algos.get("equilibrium-propagation")


def test_register_custom_algorithm_and_session():
    class Custom(algos.Algorithm):
        name = "test-custom-zero"

        def value_and_grad(self, model, cfg):
            def fn(params, fb, batch, rng):
                loss, metrics = model.loss(params, batch)
                zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
                return (loss, {**metrics, "loss": loss}), zeros

            return fn

    algos.register(Custom())
    try:
        assert "test-custom-zero" in algos.list_algos()
        session = api.build_session(arch="mnist_mlp", smoke=True,
                                    algo="test-custom-zero")
        state = session.init_state()
        batch = {"x": jnp.zeros((4, 64)), "y": jnp.zeros((4,), jnp.int32)}
        state2, metrics = session.step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
    finally:
        algos.base._REGISTRY.pop("test-custom-zero", None)


# ---------------------------------------------------------------------------
# bp vs dfa through the facade (ideal hardware)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mlp_setup():
    session_dfa = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                    hardware="ideal")
    session_bp = api.build_session(arch="mnist_mlp", smoke=True, algo="bp",
                                   hardware="ideal")
    key = jax.random.PRNGKey(0)
    state = session_dfa.init_state(key)
    batch = {"x": jax.random.normal(key, (16, 64)),
             "y": jax.random.randint(key, (16,), 0, 10)}
    return session_dfa, session_bp, state, batch


def test_dfa_vs_bp_loss_identical_under_ideal(mlp_setup):
    s_dfa, s_bp, state, batch = mlp_setup
    (ld, _), _ = s_dfa.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    (lb, _), _ = s_bp.value_and_grad()(
        state["params"], state["fb"], batch, None)
    np.testing.assert_allclose(float(ld), float(lb), rtol=1e-6)


def test_dfa_vs_bp_head_gradients_agree_under_ideal(mlp_setup):
    """Head grads are exact in DFA — cosine(head) == 1 vs backprop."""
    s_dfa, s_bp, state, batch = mlp_setup
    (_, _), gd = s_dfa.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    (_, _), gb = s_bp.value_and_grad()(
        state["params"], state["fb"], batch, None)
    align = grad_alignment(gd, gb)
    np.testing.assert_allclose(float(align["head"]), 1.0, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(gd["head"]["w"]), np.asarray(gb["head"]["w"]),
        rtol=1e-5, atol=1e-6)


def test_layerwise_differs_from_dfa_but_trains_head_exactly(mlp_setup):
    s_dfa, _, state, batch = mlp_setup
    s_lw = api.build_session(arch="mnist_mlp", smoke=True,
                             algo="dfa-layerwise", hardware="ideal")
    (_, _), gd = s_dfa.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    (_, _), gl = s_lw.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(1))
    # same head path (exact), different hidden-layer credit assignment
    np.testing.assert_allclose(np.asarray(gl["head"]["w"]),
                               np.asarray(gd["head"]["w"]), rtol=1e-6)
    assert np.abs(np.asarray(gl["h0"]["w"] - gd["h0"]["w"])).max() > 1e-6


# ---------------------------------------------------------------------------
# PhotonicBackend seam
# ---------------------------------------------------------------------------

def test_backend_registry_and_unknown_name():
    assert photonics.get_backend("ref").name == "ref"
    assert photonics.get_backend("pallas").name == "pallas"
    inst = photonics.PallasBackend(interpret=True)
    assert photonics.get_backend(inst) is inst
    with pytest.raises(KeyError):
        photonics.get_backend("interferometer")


@pytest.mark.parametrize("preset", ["ideal", "digital"])
def test_ref_vs_pallas_backend_equivalent_noiseless(preset):
    cfg = photonics.preset(preset)
    key = jax.random.PRNGKey(3)
    e = jax.random.normal(key, (5, 7, 10))
    b = jax.random.normal(jax.random.fold_in(key, 1), (64, 10))
    out_ref = photonics.photonic_project(e, b, cfg, backend="ref")
    out_pal = photonics.photonic_project(
        e, b, cfg, backend=photonics.PallasBackend(interpret=True))
    assert out_ref.shape == out_pal.shape == (5, 7, 64)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_pal),
                               rtol=2e-5, atol=2e-5)


def test_ref_vs_pallas_backend_equivalent_quantized():
    """Normalise/fake-quant/rescale is shared — identical through both."""
    cfg = photonics.PhotonicConfig(noise_std=0.0, weight_bits=6, input_bits=8)
    key = jax.random.PRNGKey(4)
    e = jax.random.normal(key, (32, 24))
    b = jax.random.normal(jax.random.fold_in(key, 1), (48, 24))
    out_ref = photonics.photonic_project(e, b, cfg, backend="ref")
    out_pal = photonics.photonic_project(
        e, b, cfg, backend=photonics.PallasBackend(interpret=True))
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_pal),
                               rtol=1e-5, atol=1e-5)


def test_dfa_engine_through_explicit_backend():
    """cfg.backend threads through the engine to the projection."""
    session = api.build_session(arch="mnist_mlp", smoke=True, algo="dfa",
                                hardware="offchip_bpd", backend="ref")
    key = jax.random.PRNGKey(0)
    state = session.init_state(key)
    batch = {"x": jax.random.normal(key, (8, 64)),
             "y": jax.random.randint(key, (8,), 0, 10)}
    (loss, _), grads = session.value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree_util.tree_leaves(grads))
