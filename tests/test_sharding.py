"""Sharding rules + a reduced-scale distributed lower/compile (subprocess
with 8 forced host devices — the mini version of the production dry-run)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from jax.sharding import PartitionSpec as P

from repro.dist import sharding


def test_spec_for_path_rules():
    assert sharding.spec_for_path("blocks/attn/q/w")[0] == P(sharding.FSDP, "model")
    assert sharding.spec_for_path("blocks/ffn/gate/w")[0] == P(sharding.FSDP, "model")
    assert sharding.spec_for_path("blocks/ffn/experts/gate/w")[0] == P("model", sharding.FSDP, None)
    assert sharding.spec_for_path("embed/tok/table")[0] == P("model", sharding.FSDP)
    assert sharding.spec_for_path("blocks/norm1/scale")[0] == P()


def test_fit_spec_pads_stacked_layer_axis():
    assert sharding._fit_spec(P("model", "data"), 3) == P(None, "model", "data")
    assert sharding._fit_spec(P("model"), 0) == P()


def test_annotate_noop_without_mesh():
    x = jnp.zeros((4, 4, 4))
    y = sharding.annotate(x, "act_btd")
    assert y is x


def test_unshard_fsdp_noop_without_mesh():
    tree = {"attn": {"q": {"w": jnp.zeros((8, 8))}}}
    out = sharding.unshard_fsdp(tree)
    assert out["attn"]["q"]["w"] is tree["attn"]["q"]["w"]


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro import algos, configs
    from repro.algos.dfa import DFAConfig
    from repro.dist import sharding
    from repro.train.optimizer import SGDM

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    arch = configs.get("qwen3-1.7b")
    model = arch.make_smoke()
    cfg = DFAConfig()
    opt = SGDM(lr=0.01)
    algo = algos.get("dfa")
    vg = algo.value_and_grad(model, cfg)

    def train_step(params, fb, opt_state, batch, seed):
        rng = jax.random.PRNGKey(seed)
        (loss, _), grads = vg(params, fb, batch, rng)
        new_p, new_o, _ = opt.update(grads, opt_state, params)
        return new_p, new_o, loss

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    fb_s = jax.eval_shape(lambda k: algo.init_extra_state(model, k, cfg), jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(opt.init, params_s)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    p_sh = sharding.make_param_shardings(mesh, params_s)
    f_sh = sharding.make_param_shardings(mesh, fb_s, sharding.FEEDBACK_RULES)
    o_sh = sharding.make_param_shardings(mesh, opt_s)
    b_sh = sharding.make_batch_shardings(mesh, batch)
    with sharding.use_mesh(mesh):
        fn = jax.jit(train_step,
                     in_shardings=(p_sh, f_sh, o_sh, b_sh, sharding.replicated(mesh)),
                     out_shardings=(p_sh, o_sh, sharding.replicated(mesh)))
        compiled = fn.lower(params_s, fb_s, opt_s, batch,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    print(json.dumps({"ok": True, "flops": float(ca.get("flops", 0))}))
""")


@pytest.mark.slow
def test_distributed_train_step_compiles_on_8_devices():
    """Mini dry-run: DFA train step lowers+compiles on a (2,2,2) mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"]


def test_param_shardings_divisibility_fallback():
    """Odd vocab (73448) must not be sharded 16-ways — fallback engages."""
    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    leaf = jax.ShapeDtypeStruct((73448, 64), jnp.float32)
    sh = sharding.make_param_shardings(mesh, {"embed": {"tok": {"table": leaf}}})
    spec = sh["embed"]["tok"]["table"].spec
    assert len(spec) == 2  # well-formed; axes sized 1 in this mini mesh
