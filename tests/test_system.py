"""End-to-end behaviour tests for the paper's system: the full photonic-DFA
pipeline (train with measured hardware noise → evaluate → serve)."""

import pytest

from repro import configs
from repro.core import dfa, energy, photonics
from repro.data import mnist, pipeline, tokens
from repro.models.mlp import MLPClassifier
from repro.train import SGDM, Trainer, TrainerConfig


@pytest.mark.slow
def test_paper_pipeline_end_to_end(tmp_path):
    """The paper's experiment at reduced scale: train the MLP with off-chip
    BPD noise injected into every B(k)e product, checkpoint, resume, eval."""
    data = mnist.load((2048, 256), seed=0)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=0)
    model = MLPClassifier(hidden=(128, 128))
    tr = Trainer(model, TrainerConfig(
        algo="dfa",
        dfa=dfa.DFAConfig(photonics=photonics.preset("offchip_bpd")),
        optimizer=SGDM(lr=0.01, momentum=0.9),
        ckpt_dir=str(tmp_path), ckpt_every=32, log_every=10**9))
    state, _ = tr.fit(pipe.batch, total_steps=128, verbose=False)
    ev = tr.evaluate(state, pipe.eval_batches(xte, yte, 128))
    assert ev["accuracy"] > 0.4
    # the checkpoint directory holds a usable snapshot
    assert tr.ckpt.latest_step() == 128


@pytest.mark.slow
def test_lm_dfa_reduces_loss_on_markov_stream():
    """A reduced LM (qwen-family smoke) learns the synthetic successor
    structure with DFA — the 'beyond-paper' training path."""
    model = configs.get("qwen1.5-0.5b").make_smoke()
    gen = tokens.MarkovTokens(vocab_size=128, seq_len=32, batch_size=8, seed=0)
    tr = Trainer(model, TrainerConfig(
        algo="dfa", optimizer=SGDM(lr=0.1, momentum=0.9), log_every=10**9))
    state = tr.init_state()
    _, m0 = tr.step(state, gen.batch(0))
    state, _ = tr.fit(gen.batch, total_steps=30, verbose=False)
    _, m1 = tr.step(state, gen.batch(99))
    assert float(m1["ce_loss"]) < float(m0["ce_loss"])


@pytest.mark.slow
def test_dfa_vs_bp_comparable_at_small_scale():
    """Paper §1: DFA yields performance comparable to backprop."""
    data = mnist.load((1024, 256), seed=1)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=0)
    accs = {}
    for algo in ["dfa", "bp"]:
        model = MLPClassifier(hidden=(128,))
        tr = Trainer(model, TrainerConfig(
            algo=algo, optimizer=SGDM(lr=0.02, momentum=0.9), log_every=10**9))
        state, _ = tr.fit(pipe.batch, total_steps=64, verbose=False)
        accs[algo] = tr.evaluate(state, pipe.eval_batches(xte, yte, 128))["accuracy"]
    assert accs["dfa"] > accs["bp"] - 0.15


def test_energy_model_consistent_with_gemm_compiler():
    """OPS from Eq. 2 at full utilisation bounds the GeMM-scheduled rate."""
    cfg = energy.EnergyConfig()
    r = energy.dfa_backward_cost([800, 800], 10, cfg)
    peak = energy.ops_per_second(50, 20, cfg)
    assert r["tops"] * 1e12 <= peak + 1e-9


def test_serving_after_training_roundtrip():
    from repro.serve import Engine, Request

    model = configs.get("mamba2-130m").make_smoke()
    gen = tokens.MarkovTokens(vocab_size=128, seq_len=32, batch_size=8, seed=0)
    tr = Trainer(model, TrainerConfig(algo="dfa", optimizer=SGDM(lr=0.05), log_every=10**9))
    state, _ = tr.fit(gen.batch, total_steps=20, verbose=False)
    eng = Engine(model, state["params"], batch_slots=2, max_len=48)
    reqs = [Request(prompt=[5, (5 * 31 + 7) % 128], max_new=8)]
    eng.run(reqs)
    assert len(reqs[0].out) == 8
