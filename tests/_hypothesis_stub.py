"""Minimal stand-in for the ``hypothesis`` API surface these tests use.

The container image does not ship hypothesis; conftest.py installs this
module into sys.modules (as ``hypothesis`` / ``hypothesis.strategies``)
ONLY when the real package is missing, so environments that do have
hypothesis keep full shrinking/replay behaviour.

Property tests degrade gracefully: each ``@given`` runs a deterministic,
per-test-seeded batch of random examples (capped at 10 for wall-time) with
no shrinking on failure — the drawn kwargs appear in the assertion
traceback instead.
"""

from __future__ import annotations

import functools
import inspect
import random
import types

_MAX_EXAMPLES_CAP = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [elements.example(r) for _ in range(r.randint(min_size, max_size))])


def tuples(*elems):
    return _Strategy(lambda r: tuple(e.example(r) for e in elems))


class settings:  # noqa: N801 — mirrors hypothesis.settings
    def __init__(self, max_examples=10, deadline=None, **_kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(**strategies):
    def deco(fn):
        sig = inspect.signature(fn)
        fixture_params = [p for name, p in sig.parameters.items()
                          if name not in strategies]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            s = getattr(wrapper, "_stub_settings", None)
            n = min(s.max_examples if s else _MAX_EXAMPLES_CAP, _MAX_EXAMPLES_CAP)
            rng = random.Random(fn.__module__ + "." + fn.__qualname__)
            for _ in range(n):
                drawn = {k: v.example(rng) for k, v in strategies.items()}
                fn(*args, **kwargs, **drawn)

        # hide strategy kwargs from pytest so only real fixtures are injected
        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        return wrapper

    return deco


strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "sampled_from", "floats", "booleans", "lists", "tuples"):
    setattr(strategies, _name, globals()[_name])
