"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(ref.py), in Pallas interpret mode, plus hypothesis property tests."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import photonics
from repro.kernels import ops, ref

IDEAL = photonics.PhotonicConfig(noise_std=0.0)
NOISY = photonics.PhotonicConfig(noise_std=0.098)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape)
    return x.astype(dtype)


SHAPES = [
    (4, 8, 16),      # tiny, sub-block
    (64, 10, 800),   # the paper's MLP projection (e 10-dim -> 800)
    (128, 128, 128), # exactly one block
    (200, 300, 257), # ragged (exercises padding)
    (256, 512, 384), # multi-block
]


@pytest.mark.parametrize("t,k,m", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_photonic_matmul_noiseless_matches_ref(t, k, m, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(t * 7 + k))
    a = _rand(ka, (t, k), dtype)
    b = _rand(kb, (m, k), dtype)
    out = ops.photonic_matmul(a, b, IDEAL, interpret=True)
    expect = ref.photonic_matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol * np.abs(np.asarray(expect)).max() + 1e-6)


@pytest.mark.parametrize("t,k,m", SHAPES[:3])
def test_dfa_gradient_fused_mask(t, k, m):
    key = jax.random.PRNGKey(0)
    a = _rand(key, (t, k), jnp.float32)
    b = _rand(jax.random.fold_in(key, 1), (m, k), jnp.float32)
    mask = (jax.random.normal(jax.random.fold_in(key, 2), (t, m)) > 0).astype(jnp.float32)
    out = ops.dfa_gradient(a, b, mask, IDEAL, interpret=True)
    expect = ref.dfa_gradient_ref(a, b, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=1e-4)


def test_noise_statistics_match_model():
    """Injected noise std equals σ·s_a·s_b·sqrt(ceil(K/bank_cols))."""
    key = jax.random.PRNGKey(3)
    t, k, m = 256, 40, 512  # 40 cols = 2 bank passes at bank_cols=20
    a = _rand(key, (t, k), jnp.float32)
    b = _rand(jax.random.fold_in(key, 1), (m, k), jnp.float32)
    out = ops.photonic_matmul(a, b, NOISY, key=key, interpret=True)
    err = np.asarray(out - a @ b.T)
    s = float(jnp.max(jnp.abs(a)) * jnp.max(jnp.abs(b)))
    expect_std = 0.098 * s * np.sqrt(2)
    assert abs(err.std() / expect_std - 1.0) < 0.05
    assert abs(err.mean()) < 3 * expect_std / np.sqrt(err.size)


def test_noise_reproducible_by_key():
    key = jax.random.PRNGKey(4)
    a = _rand(key, (32, 16), jnp.float32)
    b = _rand(jax.random.fold_in(key, 1), (64, 16), jnp.float32)
    o1 = ops.photonic_matmul(a, b, NOISY, key=key, interpret=True)
    o2 = ops.photonic_matmul(a, b, NOISY, key=key, interpret=True)
    o3 = ops.photonic_matmul(a, b, NOISY, key=jax.random.PRNGKey(9), interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert np.abs(np.asarray(o1 - o3)).max() > 0


def test_prng_mode_compiles_in_interpret():
    """TPU in-kernel PRNG path: structural validation (zero-bit noise in the
    interpreter ⇒ output equals the exact product)."""
    key = jax.random.PRNGKey(5)
    a = _rand(key, (64, 32), jnp.float32)
    b = _rand(jax.random.fold_in(key, 1), (128, 32), jnp.float32)
    out = ops.photonic_matmul(a, b, NOISY, key=key, noise_mode="prng", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b.T), rtol=1e-4, atol=1e-4)


def test_quantization_matches_core_path():
    cfg = photonics.PhotonicConfig(noise_std=0.0, weight_bits=6, input_bits=8)
    key = jax.random.PRNGKey(6)
    a = _rand(key, (32, 24), jnp.float32)
    b = _rand(jax.random.fold_in(key, 1), (48, 24), jnp.float32)
    out_k = ops.photonic_matmul(a, b, cfg, interpret=True)
    out_c = photonics.photonic_matmul(a, b, cfg)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_c), rtol=1e-5, atol=1e-5)


@hypothesis.given(
    t=st.integers(1, 64), k=st.integers(1, 96), m=st.integers(1, 96),
    bt=st.sampled_from([8, 32, 128]), bk=st.sampled_from([16, 64, 512]),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_block_shape_invariance(t, k, m, bt, bk):
    """Kernel output is invariant to BlockSpec tiling (noiseless)."""
    key = jax.random.PRNGKey(t * 1000 + k * 10 + m)
    a = _rand(key, (t, k), jnp.float32)
    b = _rand(jax.random.fold_in(key, 1), (m, k), jnp.float32)
    o1 = ops.photonic_matmul(a, b, IDEAL, interpret=True, block_t=bt, block_k=bk)
    o2 = ops.photonic_matmul(a, b, IDEAL, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


def test_vmem_budget_helper():
    from repro.kernels.photonic_matmul import vmem_bytes

    assert vmem_bytes(128, 128, 512) < 16 * 2**20  # fits v5e VMEM
