"""Quickstart: the paper's algorithm in six steps, through ``repro.api``.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's MLP, binds one cell of the algorithm × hardware ×
backend matrix (DFA × off-chip-BPD noise × auto backend) into a Session,
takes a few training steps, and shows the energy model.
"""

import jax
import jax.numpy as jnp

from repro import algos, api
from repro.core import energy
from repro.data import mnist

# 1. one cell of the matrix: the paper's 784x800x800x10 ReLU MLP, trained
#    with DFA on the off-chip BPD circuit (sigma=0.098 -> 4.35 bits)
session = api.build_session(arch="mnist_mlp", algo="dfa",
                            hardware="offchip_bpd", backend="auto")
hw = session.config.dfa.photonics
print(f"algorithms registered: {algos.list_algos()}")
print(f"hardware: sigma={hw.noise_std} -> {hw.effective_bits:.2f} effective bits")

# 2. training state: params + the fixed random feedback B(k) inscribed on
#    the MRR weight bank (the algorithm's extra state)
state = session.init_state(jax.random.PRNGKey(0))
print("feedback shapes:", {k: tuple(v.shape) for k, v in state["fb"].items()})

# 3. data (procedural digits unless REPRO_MNIST_DIR points at IDX files)
data = mnist.load((4096, 512))
print("data source:", data["source"])
xtr, ytr = data["train"]

# 4. DFA training steps: delta(k) = B(k)e (+ analog noise) ⊙ local vjp —
#    session.step is the jit'd trainer step (forward, photonic backward,
#    SGD-momentum update)
for i in range(20):
    batch = {"x": jnp.asarray(xtr[i * 64:(i + 1) * 64]),
             "y": jnp.asarray(ytr[i * 64:(i + 1) * 64])}
    state, metrics = session.step(state, batch)
    if i % 5 == 0:
        print(f"step {i:3d} loss={float(metrics['loss']):.4f} "
              f"acc={float(metrics['accuracy']):.3f}")

# 5. the raw gradient function is one call away when you need it
#    (same registry entry the trainer uses)
vg = session.value_and_grad()
(loss, _), grads = vg(state["params"], state["fb"], batch, jax.random.PRNGKey(99))
print(f"value_and_grad: loss={float(loss):.4f}, grad trees: {sorted(grads)}")

# 6. what the chip would cost: the GeMM compiler's schedule on a 50x20 bank
r = energy.dfa_backward_cost([800, 800], 10, energy.EnergyConfig())
print(f"photonic backward pass: {r['cycles']} cycles = {r['seconds']*1e9:.1f} ns, "
      f"{r['pj_per_mac']:.2f} pJ/MAC")
