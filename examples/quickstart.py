"""Quickstart: the paper's algorithm in six steps.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's MLP, wires the photonic DFA engine with the measured
off-chip-BPD noise, takes a few training steps, and shows the energy model.
"""

import jax
import jax.numpy as jnp

from repro.core import dfa, energy, photonics
from repro.data import mnist
from repro.models.mlp import MLPClassifier

# 1. the paper's network: 784x800x800x10 ReLU MLP
model = MLPClassifier()
params = model.init(jax.random.PRNGKey(0))

# 2. the photonic hardware: off-chip BPD circuit (sigma=0.098 -> 4.35 bits)
cfg = dfa.DFAConfig(photonics=photonics.preset("offchip_bpd"))
print(f"hardware: sigma={cfg.photonics.noise_std} -> "
      f"{cfg.photonics.effective_bits:.2f} effective bits")

# 3. fixed random feedback B(k) — inscribed on the MRR weight bank
fb = dfa.init_feedback(model, jax.random.PRNGKey(7), cfg)
print("feedback shapes:", {k: tuple(v.shape) for k, v in fb.items()})

# 4. data (procedural digits unless REPRO_MNIST_DIR points at IDX files)
data = mnist.load((4096, 512))
print("data source:", data["source"])
xtr, ytr = data["train"]

# 5. DFA training steps: delta(k) = B(k)e (+ analog noise) ⊙ local vjp
step = jax.jit(dfa.value_and_grad(model, cfg))
for i in range(20):
    batch = {"x": jnp.asarray(xtr[i * 64:(i + 1) * 64]),
             "y": jnp.asarray(ytr[i * 64:(i + 1) * 64])}
    (loss, metrics), grads = step(params, fb, batch, jax.random.PRNGKey(i))
    params = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g, params, grads)
    if i % 5 == 0:
        print(f"step {i:3d} loss={float(loss):.4f} acc={float(metrics['accuracy']):.3f}")

# 6. what the chip would cost: the GeMM compiler's schedule on a 50x20 bank
r = energy.dfa_backward_cost([800, 800], 10, energy.EnergyConfig())
print(f"photonic backward pass: {r['cycles']} cycles = {r['seconds']*1e9:.1f} ns, "
      f"{r['pj_per_mac']:.2f} pJ/MAC")
