"""End-to-end driver (deliverable b): train a ~100M-param LM with DFA for a
few hundred steps — the beyond-paper path (block-granular DFA per Launay
et al., the paper's ref [28]) — with fault-tolerant checkpointing.

    PYTHONPATH=src python examples/train_lm_dfa.py --steps 300

Default model: a ~100M-param qwen-family decoder (12L × d512 on a 8k vocab);
data: the deterministic Markov token stream.  Interrupt it and re-run: the
trainer resumes bit-exactly from the last snapshot.
"""

import argparse

import jax.numpy as jnp

from repro import algos, api
from repro.core import photonics
from repro.data import tokens
from repro.models.transformer import TransformerConfig, TransformerLM
from repro.train import SGDM
from repro.utils.tree import param_count


def make_model(dtype=jnp.float32) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="lm100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=2048, vocab_size=8192, head_dim=64, qk_norm=True, dtype=dtype,
    ))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preset", default="offchip_bpd", choices=list(photonics.PRESETS))
    ap.add_argument("--algo", default="dfa", choices=algos.list_algos())
    ap.add_argument("--ckpt-dir", default="runs/lm_dfa")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = make_model()
    n = param_count(model.param_shapes())
    print(f"[model] {model.cfg.name}: {n/1e6:.1f}M params, "
          f"algo={args.algo}, photonics={args.preset}")

    gen = tokens.MarkovTokens(model.cfg.vocab_size, args.seq, args.batch, args.seed)
    session = api.build_session(
        arch=model, algo=args.algo, hardware=args.preset,
        optimizer=SGDM(lr=0.05, momentum=0.9),
        seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=20, log_path=f"{args.ckpt_dir}/metrics.csv")
    state, metrics = session.fit(gen.batch, total_steps=args.steps)
    print(f"[done] step={int(state['step'])} "
          f"ce={float(metrics['ce_loss']):.4f} "
          f"(vs ln(V)={jnp.log(model.cfg.vocab_size):.2f} at random)")


if __name__ == "__main__":
    main()
