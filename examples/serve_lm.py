"""Batched serving example: train a small LM briefly with DFA, then serve
batched requests through the continuous-batching engine and verify the
model has learned the stream's successor structure.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro import api
from repro.data import tokens
from repro.models.transformer import TransformerConfig, TransformerLM
from repro.serve import Engine, Request
from repro.train import SGDM

VOCAB = 128
A, B = 31, 7  # the stream's successor rule: next = (A*t + B) mod V


def main():
    model = TransformerLM(TransformerConfig(
        name="serve-demo", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab_size=VOCAB, head_dim=32))
    gen = tokens.MarkovTokens(VOCAB, seq_len=64, batch_size=16, seed=0,
                              p_follow=0.95, a=A, b=B)
    session = api.build_session(
        arch=model, algo="dfa", hardware="ideal",
        optimizer=SGDM(lr=0.05, momentum=0.9), log_every=50)
    print("[train] 600 DFA steps on the Markov stream…")
    state, _ = session.fit(gen.batch, total_steps=600)

    eng = Engine(model, state["params"], batch_slots=4, max_len=96)
    prompts = [[s, (A * s + B) % VOCAB, (A * ((A * s + B) % VOCAB) + B) % VOCAB]
               for s in (3, 17, 101, 90, 77, 44)]
    reqs = [Request(prompt=p, max_new=8) for p in prompts]
    done, ticks = eng.run(reqs)
    print(f"[serve] {len(done)} requests in {ticks} ticks "
          f"({len(done)} > slots=4: continuous batching)")
    correct = total = 0
    for r in done:
        t = r.prompt[-1]
        want = []
        for _ in range(len(r.out)):
            t = (A * t + B) % VOCAB
            want.append(t)
        hits = sum(int(a == b) for a, b in zip(r.out, want))
        correct += hits
        total += len(want)
        print(f"  prompt={r.prompt} -> {r.out} (chain-follow {hits}/{len(want)})")
    print(f"[eval] successor-rule follow rate: {correct}/{total} "
          f"({100*correct/max(total,1):.0f}% — random would be ~0%)")


if __name__ == "__main__":
    main()
