"""The paper's §4 experiment, end to end (Fig. 5b):

train the 784×800×800×10 MLP with DFA where every B(k)·e inner product
carries the measured analog noise of the three hardware presets, then
compare test accuracies.

    PYTHONPATH=src python examples/mnist_dfa_photonic.py [--steps 1500]

With real MNIST (REPRO_MNIST_DIR set) and --steps 14000 (~15 epochs) this
reproduces the paper's 98.10 / 97.41 / 96.33 % ordering; on the default
procedural-digit corpus the ordering and gap structure are the claim.
"""

import argparse

from repro import api
from repro.core import photonics
from repro.data import mnist, pipeline
from repro.train import SGDM

PAPER = {"ideal": 98.10, "offchip_bpd": 97.41, "onchip_bpd": 96.33}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1024)
    ap.add_argument("--train-n", type=int, default=16384)
    ap.add_argument("--test-n", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    data = mnist.load((args.train_n, args.test_n), seed=args.seed)
    print(f"[data] source={data['source']} train={len(data['train'][0])}")
    xtr, ytr = data["train"]
    xte, yte = data["test"]

    results = {}
    for preset in ["ideal", "offchip_bpd", "onchip_bpd"]:
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=args.seed)
        session = api.build_session(
            arch="mnist_mlp",  # the paper's exact architecture
            algo="dfa", hardware=preset,
            optimizer=SGDM(lr=0.01, momentum=0.9),  # the paper's optimizer
            seed=args.seed, log_every=max(1, args.steps // 8))
        print(f"[train] preset={preset} "
              f"(sigma={photonics.preset(preset).noise_std}, "
              f"{photonics.preset(preset).effective_bits:.2f} bits)")
        state, _ = session.fit(pipe.batch, total_steps=args.steps, verbose=True)
        ev = session.evaluate(state, pipe.eval_batches(xte, yte, 256))
        results[preset] = 100 * ev["accuracy"]

    print("\npreset          test_acc%   paper%(MNIST)")
    for preset, acc in results.items():
        print(f"{preset:14s} {acc:8.2f}   {PAPER[preset]:8.2f}")
    ok = results["ideal"] >= results["offchip_bpd"] - 0.5 >= results["onchip_bpd"] - 1.0
    print("\nnoise-robustness ordering reproduced:", ok)


if __name__ == "__main__":
    main()
