"""Paper §3/§5: GeMM-compiler schedules on the photonic weight bank — cycles,
wall time, energy and effective TOPS of the DFA backward pass for the
paper's MLP and for the assigned LM architectures' feedback projections."""

from __future__ import annotations

import jax.numpy as jnp

from repro import configs
from repro.core import energy


def run(bank=(50, 20)):
    cfg = energy.EnergyConfig()
    m, n = bank
    rows = []
    # the paper's MLP: 2 hidden layers of 800, error dim 10
    r = energy.dfa_backward_cost([800, 800], 10, cfg, bank_m=m, bank_n=n)
    rows.append({"model": "mnist_mlp(paper)", **r})
    # LM architectures: per-layer injection dim = d_model, tap dim = d_model
    for name in ["qwen1.5-0.5b", "granite-8b", "kimi-k2-1t-a32b"]:
        model = configs.get(name).make_model(jnp.bfloat16)
        c = model.cfg
        layers = [c.d_model] * c.n_layers
        r = energy.dfa_backward_cost(layers, c.d_model, cfg, bank_m=m, bank_n=n)
        rows.append({"model": name, **r})
    return rows


def main():
    print("gemm_cycles: model,cycles,seconds,pj_per_mac,tops")
    for r in run():
        print(f"{r['model']},{r['cycles']},{r['seconds']:.3e},"
              f"{r['pj_per_mac']:.3f},{r['tops']:.2f}")


if __name__ == "__main__":
    main()
