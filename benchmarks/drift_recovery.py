"""Hardware-in-the-loop study: DFA accuracy under MRR resonance drift,
with and without in-situ recalibration.

Four cells, identical model/optimizer/data, differing only in the device:

  ref              — abstract σ-per-MAC noise model (the paper's protocol)
  emu_static       — device-level bank, drift OFF (backend-equivalence
                     baseline: should match ``ref`` closely)
  emu_drift        — drifting bank, NEVER recalibrated (the failure mode)
  emu_drift_recal  — same drifting bank, periodic calibration sweeps

Drift parameters are accelerated (large σ, short τ) so the degradation and
the recovery are visible in a CI-sized run; the *mechanism* — the residual
between sweeps grows as σ·sqrt(1 - exp(-2Δt/τ)) — is cadence-invariant.

Emits ``BENCH_hardware.json`` (schema repro.bench/v1) with the headline
metrics; ``benchmarks/run.py --bench`` runs this study so CI records the
hardware trajectory alongside throughput.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import api
from repro.core import photonics
from repro.data import mnist, pipeline
from repro.hardware.mrr import MRRConfig
from repro.models.mlp import MLPClassifier
from repro.train import SGDM

# Accelerated drift: stationary detuning std 2.5·γ reached in ~τ=32 steps —
# rings wander across their resonance fast enough that the feedback matrix
# decorrelates before DFA's alignment can track it (slow drift is nearly
# free: the network just re-aligns to the drifted B).
FAST_DRIFT = dict(drift_sigma=2.5, drift_tau=32.0, cal_noise=0.01)


def variants(recal_every: int):
    base = photonics.preset("offchip_bpd")  # measured σ = 0.098
    emu = dataclasses.replace(base, mrr=MRRConfig(**FAST_DRIFT))
    return [
        ("ref", dict(hardware=base, backend="ref"), 0),
        ("emu_static",
         dict(hardware=dataclasses.replace(base, mrr=MRRConfig.ideal()),
              backend="emu"), 0),
        ("emu_drift", dict(hardware=emu, backend="emu"), 0),
        ("emu_drift_recal", dict(hardware=emu, backend="emu"), recal_every),
    ]


def run(steps: int = 192, train_n: int = 4096, test_n: int = 1024,
        batch: int = 64, hidden=(100,), recal_every: int = 8, seed: int = 0):
    data = mnist.load((train_n, test_n), seed=seed)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    rows = []
    for name, hw_kw, recal in variants(recal_every):
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=batch,
                                            seed=seed)
        session = api.build_session(
            arch=MLPClassifier(hidden=hidden), algo="dfa",
            optimizer=SGDM(lr=0.01, momentum=0.9), seed=seed,
            recalibrate_every=recal, log_every=10**9, **hw_kw)
        state, metrics = session.fit(pipe.batch, total_steps=steps,
                                     verbose=False)
        ev = session.evaluate(
            state, pipe.eval_batches(xte, yte, min(256, len(xte))))
        row = {"variant": name, "recalibrate_every": recal,
               "test_accuracy": 100 * ev["accuracy"],
               "source": data["source"]}
        # one batched transfer per variant (a full training run each), not
        # one float() per metric
        keep = ("hw_drift_rms", "hw_residual_rms")
        hw = jax.device_get(  # lint: disable=RL002
            {k: metrics[k] for k in keep if k in metrics})
        row.update({k: float(v) for k, v in hw.items()})  # lint: disable=RL002
        rows.append(row)
    return rows


def bench_metrics(rows) -> dict:
    acc = {r["variant"]: r["test_accuracy"] for r in rows}
    return {
        "acc_ref": acc["ref"],
        "acc_emu_static": acc["emu_static"],
        "acc_emu_drift": acc["emu_drift"],
        "acc_emu_drift_recal": acc["emu_drift_recal"],
        # backend fidelity: device emulation vs abstract model, drift off
        "emu_vs_ref_gap_pts": abs(acc["emu_static"] - acc["ref"]),
        # what drift costs, and how much calibration claws back
        "drift_cost_pts": acc["emu_static"] - acc["emu_drift"],
        "recal_recovery_pts": acc["emu_drift_recal"] - acc["emu_drift"],
    }


def write_report(rows, out_dir: str = ".") -> str:
    from repro.bench import write_bench

    return write_bench("hardware", bench_metrics(rows),
                       meta={"rows": rows, "fast_drift": FAST_DRIFT},
                       out_dir=out_dir)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=192)
    ap.add_argument("--recal-every", type=int, default=8)
    ap.add_argument("--bench-dir", default=None, metavar="DIR",
                    help="also write BENCH_hardware.json into DIR")
    args = ap.parse_args()
    print("drift_recovery: variant,recal_every,test_acc_%,residual_rms")
    rows = run(steps=args.steps, recal_every=args.recal_every)
    for r in rows:
        print(f"{r['variant']},{r['recalibrate_every']},"
              f"{r['test_accuracy']:.2f},{r.get('hw_residual_rms', 0):.4f}")
    if args.bench_dir is not None:
        print(f"[bench] wrote {write_report(rows, args.bench_dir)}")


if __name__ == "__main__":
    main()
