"""Paper Fig. 5(b): validation accuracy during DFA training with the
measured hardware noise (clean / off-chip BPD / on-chip BPD).

Paper protocol: 784×800×800×10 ReLU MLP, CE loss, SGD lr=0.01 momentum=0.9,
batch 64, Gaussian noise of the measured magnitude on every B(k)·e inner
product; inference and updates full-precision.  Paper results (real MNIST):
98.10 / 97.41 / 96.33 %.  Without MNIST IDX files in the container the
default corpus is procedural digits (data/mnist.py) — the validated claim
is the noise-robustness ordering and the small degradation gaps.
Steps/size are scaled for CPU wall-time; pass --full for longer runs.
"""

from __future__ import annotations

import argparse

from repro import api
from repro.data import mnist, pipeline
from repro.models.mlp import MLPClassifier
from repro.train import SGDM

PAPER = {"ideal": 98.10, "offchip_bpd": 97.41, "onchip_bpd": 96.33}


def run(train_n=8192, test_n=2048, steps=512, hidden=(800, 800), seed=0,
        presets=("ideal", "offchip_bpd", "onchip_bpd")):
    data = mnist.load((train_n, test_n), seed=seed)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    rows = []
    for preset in presets:
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=seed)
        session = api.build_session(
            arch=MLPClassifier(hidden=hidden), algo="dfa", hardware=preset,
            optimizer=SGDM(lr=0.01, momentum=0.9), seed=seed, log_every=10**9)
        state, _ = session.fit(pipe.batch, total_steps=steps, verbose=False)
        ev = session.evaluate(state, pipe.eval_batches(xte, yte, 256))
        rows.append({
            "preset": preset, "source": data["source"],
            "test_accuracy": 100 * ev["accuracy"],
            "paper_accuracy": PAPER[preset],
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    kw = dict(train_n=60000, test_n=10000, steps=60000 // 64 * 15) if args.full else {}
    print("fig5b_mnist: preset,source,test_acc_%,paper_acc_%")
    for r in run(**kw):
        print(f"{r['preset']},{r['source']},{r['test_accuracy']:.2f},{r['paper_accuracy']}")


if __name__ == "__main__":
    main()
