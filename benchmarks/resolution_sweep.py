"""Paper Fig. 5(c): test accuracy vs effective resolution of the gradient
calculation.  Noise σ = 2^(1-bits) is injected into every B(k)·e product;
the paper's dashed lines sit at 4.35 b (off-chip) and 3.31 b (on-chip)."""

from __future__ import annotations

import argparse

from repro import api
from repro.core import photonics
from repro.data import mnist, pipeline
from repro.models.mlp import MLPClassifier
from repro.train import SGDM


def run(bits_list=(2.0, 3.0, 3.31, 4.35, 6.0, 8.0), train_n=6144, test_n=1536,
        steps=384, hidden=(256, 256), seed=0):
    data = mnist.load((train_n, test_n), seed=seed)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    rows = []
    for bits in bits_list:
        cfg = photonics.PhotonicConfig(noise_std=photonics.bits_to_std(bits))
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=seed)
        session = api.build_session(
            arch=MLPClassifier(hidden=hidden), algo="dfa", hardware=cfg,
            optimizer=SGDM(lr=0.01, momentum=0.9), seed=seed, log_every=10**9)
        state, _ = session.fit(pipe.batch, total_steps=steps, verbose=False)
        ev = session.evaluate(state, pipe.eval_batches(xte, yte, 256))
        rows.append({"bits": bits, "noise_std": cfg.noise_std,
                     "test_accuracy": 100 * ev["accuracy"]})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    kw = dict(bits_list=(3.31, 4.35, 8.0), steps=192) if args.quick else {}
    print("fig5c_resolution: bits,noise_std,test_acc_%")
    for r in run(**kw):
        print(f"{r['bits']},{r['noise_std']:.4f},{r['test_accuracy']:.2f}")


if __name__ == "__main__":
    main()
