"""Paper §1 claim: DFA yields performance comparable to backprop — plus the
alignment diagnostic (ref [29]: align-then-memorise).  Both algorithms are
driven through ``repro.api.build_session`` — the same registry cells the
trainer and launchers use."""

from __future__ import annotations

import jax

from repro import api
from repro.algos.dfa import grad_alignment
from repro.data import mnist, pipeline
from repro.models.mlp import MLPClassifier
from repro.train import SGDM


def run(train_n=6144, test_n=1536, steps=384, hidden=(256, 256), seed=0):
    data = mnist.load((train_n, test_n), seed=seed)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    rows = []
    sessions = {}
    for algo in ("dfa", "bp"):
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=seed)
        session = api.build_session(
            arch=MLPClassifier(hidden=hidden), algo=algo,
            optimizer=SGDM(lr=0.01, momentum=0.9), seed=seed, log_every=10**9)
        state, _ = session.fit(pipe.batch, total_steps=steps, verbose=False)
        ev = session.evaluate(state, pipe.eval_batches(xte, yte, 256))
        rows.append({"algo": algo, "test_accuracy": 100 * ev["accuracy"]})
        sessions[algo] = (session, state)

    # alignment of DFA grads with BP grads at the trained point
    session, state = sessions["dfa"]
    batch = pipe.batch(0)
    (_, _), gd = sessions["dfa"][0].value_and_grad()(
        state["params"], state["fb"], batch, jax.random.PRNGKey(0))
    (_, _), gb = sessions["bp"][0].value_and_grad()(
        state["params"], state["fb"], batch, None)
    align = grad_alignment(gd, gb)
    rows.append({"algo": "alignment_h0", "test_accuracy": float(align["h0"])})
    rows.append({"algo": "alignment_h1", "test_accuracy": float(align["h1"])})
    return rows


def main():
    print("dfa_vs_bp: algo,value")
    for r in run():
        print(f"{r['algo']},{r['test_accuracy']:.3f}")


if __name__ == "__main__":
    main()
