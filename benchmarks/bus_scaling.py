"""Multi-wavelength bus scale-out study: accuracy, GeMM schedule length,
and energy per MAC versus the number of parallel WDM buses.

The paper's throughput story (Eqs. 2-4, §5) scales by adding buses that
carry more MRR weight banks; this sweep prices that axis end to end:

* accuracy  — a short MNIST DFA fit through the device-level "emu"
  backend at each bus count, with inter-bus thermal crosstalk ON (the
  scale-out's own nonideality).  Buses don't change the math, so the
  accuracy column should be ~flat — any spread is crosstalk/quantization.
* cycles    — ``photonics.gemm_cycles`` schedule length of a
  representative LM feedback projection (d_model-sized taps, where the
  contraction is deep enough for buses to matter; the paper's MNIST MLP
  taps only 10 wide — one panel — so buses can't help it).
* pJ/MAC    — ``energy.dfa_backward_cost`` with the per-bus Eq. 4 power
  terms: flat up to schedule-quantization loss (idle buses in the last
  cycle still burn power).

Emits ``BENCH_bus_scaling.json`` (schema repro.bench/v1);
``benchmarks/run.py --bench`` runs this sweep and CI requires the file.
"""

from __future__ import annotations

import argparse
import dataclasses

from repro import api
from repro.core import energy, photonics
from repro.data import mnist, pipeline
from repro.hardware.mrr import MRRConfig
from repro.models.mlp import MLPClassifier
from repro.train import SGDM

# Accuracy cell device: measured off-chip BPD noise, realistic heater/ADC
# DACs, intra-bus AND inter-bus thermal crosstalk; drift OFF so the sweep
# isolates the bus axis (the drift story is BENCH_hardware.json).
BUS_DEVICE = dict(adc_bits=10, bus_crosstalk=0.002, drift_sigma=0.0,
                  cal_noise=0.0)

# Representative deep-contraction projection for the cycles/energy columns:
# qwen1.5-0.5b-shaped feedback (24 layers, d_model = d_tap = 896) — 45
# contraction panels on the 50×20 bank, so bus-parallel scheduling bites.
LM_LAYERS = [896] * 24
LM_D_TAP = 896


def schedule_row(n_buses: int, bank=(50, 20)) -> dict:
    """Cycles/energy/TOPS of the LM feedback backward at one bus count —
    per-bus laser stacks AND the shared-comb variant (one comb source
    carries every bus's wavelengths, so the Eq. 3 floor is paid once)."""
    m, n = bank
    ecfg = energy.EnergyConfig(n_buses=n_buses)
    r = energy.dfa_backward_cost(LM_LAYERS, LM_D_TAP, ecfg, bank_m=m, bank_n=n)
    shared = dataclasses.replace(ecfg, shared_comb=True)
    r_sh = energy.dfa_backward_cost(LM_LAYERS, LM_D_TAP, shared,
                                    bank_m=m, bank_n=n)
    pcfg = photonics.PhotonicConfig(bank_rows=m, bank_cols=n, n_buses=n_buses)
    assert r["cycles"] == sum(
        photonics.gemm_cycles(d, LM_D_TAP, pcfg) for d in LM_LAYERS)
    return {"cycles": r["cycles"], "seconds": r["seconds"],
            "pj_per_mac": r["pj_per_mac"], "tops": r["tops"],
            "pj_per_mac_shared_comb": r_sh["pj_per_mac"],
            "power_w": energy.total_power(m, n, ecfg),
            "power_w_shared_comb": energy.total_power(m, n, shared)}


def run(bus_counts=(1, 2, 4), steps: int = 96, train_n: int = 2048,
        test_n: int = 512, batch: int = 64, hidden=(64,), seed: int = 0):
    data = mnist.load((train_n, test_n), seed=seed)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    base = dataclasses.replace(photonics.preset("offchip_bpd"),
                               mrr=MRRConfig(**BUS_DEVICE))
    rows = []
    for n_buses in bus_counts:
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=batch,
                                            seed=seed)
        session = api.build_session(
            arch=MLPClassifier(hidden=hidden), algo="dfa", hardware=base,
            backend="emu", n_buses=n_buses,
            optimizer=SGDM(lr=0.01, momentum=0.9), seed=seed,
            log_every=10**9)
        state, _ = session.fit(pipe.batch, total_steps=steps, verbose=False)
        ev = session.evaluate(
            state, pipe.eval_batches(xte, yte, min(256, len(xte))))
        rows.append({"n_buses": n_buses,
                     "test_accuracy": 100 * ev["accuracy"],
                     "source": data["source"], **schedule_row(n_buses)})
    return rows


def bench_metrics(rows) -> dict:
    by_bus = {r["n_buses"]: r for r in rows}
    metrics = {}
    for b, r in sorted(by_bus.items()):
        metrics[f"acc_b{b}"] = r["test_accuracy"]
        metrics[f"cycles_b{b}"] = r["cycles"]
        metrics[f"pj_per_mac_b{b}"] = r["pj_per_mac"]
        metrics[f"pj_per_mac_shared_comb_b{b}"] = r["pj_per_mac_shared_comb"]
        metrics[f"tops_b{b}"] = r["tops"]
    b_lo, b_hi = min(by_bus), max(by_bus)
    accs = [r["test_accuracy"] for r in rows]
    # headline: schedule speedup at the largest bus count, and how much
    # accuracy the scale-out costs (should be ~0: buses change scheduling
    # and crosstalk geometry, not the math)
    metrics["cycle_speedup"] = by_bus[b_lo]["cycles"] / by_bus[b_hi]["cycles"]
    metrics["acc_spread_pts"] = max(accs) - min(accs)
    return metrics


def write_report(rows, out_dir: str = ".") -> str:
    from repro.bench import write_bench

    return write_bench("bus_scaling", bench_metrics(rows),
                       meta={"rows": rows, "device": BUS_DEVICE,
                             "lm_layers": len(LM_LAYERS),
                             "lm_d_tap": LM_D_TAP},
                       out_dir=out_dir)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=96)
    ap.add_argument("--buses", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--bench-dir", default=None, metavar="DIR",
                    help="also write BENCH_bus_scaling.json into DIR")
    args = ap.parse_args()
    print("bus_scaling: n_buses,test_acc_%,cycles,pj_per_mac,tops")
    rows = run(bus_counts=tuple(args.buses), steps=args.steps)
    for r in rows:
        print(f"{r['n_buses']},{r['test_accuracy']:.2f},{r['cycles']},"
              f"{r['pj_per_mac']:.3f},{r['tops']:.2f}")
    if args.bench_dir is not None:
        print(f"[bench] wrote {write_report(rows, args.bench_dir)}")


if __name__ == "__main__":
    main()
