"""Timing-accurate pipeline study (repro.sim): wall-clock latency,
sustained MACs/s, stage occupancy, and pJ/MAC of the photonic DFA
backward for the paper's own MLP and a qwen1.5-0.5b-shaped LM, swept
over bus counts — plus the autotuner's pick under a power budget.

This is the temporal counterpart of ``benchmarks/gemm_cycles.py`` (static
cycle counts) and ``benchmarks/energy.py`` (static watts): the simulator
replays the emulator's actual panel schedule as per-bus event timelines
(paper Fig. 3 pipelining), so the latency numbers include pipeline fill,
bus-quantization idle slots, and the per-step heater weight update that
cycle counting cannot see.

Emits ``BENCH_pipeline.json`` (schema repro.bench/v1);
``benchmarks/run.py --bench`` runs it and CI requires the file.
"""

from __future__ import annotations

import argparse

from repro import api, sim
from repro.core import energy, photonics

# nominal per-step stream length (vectors through the banks); headline
# ratios are batch-insensitive — fills and heater epilogues amortise
T_STREAM = 64

ARCHS = ("mnist_mlp", "qwen1.5-0.5b")


def workload_for(arch: str, t: int = T_STREAM):
    """DFA backward GEMMs of the full-size arch (shape-only, no params)."""
    return sim.dfa_backward_workload(api.build_model(arch), t=t)


def sweep_rows(arch: str, bus_counts=(1, 2, 4), t: int = T_STREAM,
               shared_comb: bool = False) -> list:
    """Simulate the arch's backward at each bus count (emulator tiling)."""
    import dataclasses

    work = workload_for(arch, t)
    ecfg = energy.EnergyConfig(shared_comb=shared_comb)
    rows = []
    for n_buses in bus_counts:
        pcfg = photonics.PhotonicConfig(n_buses=n_buses)
        r = sim.simulate(work, pcfg, dataclasses.replace(ecfg, n_buses=n_buses))
        rows.append({
            "arch": arch, "n_buses": n_buses,
            "wall_clock_us": r.wall_clock_s * 1e6,
            "cycles": r.cycles,
            "macs_per_s": r.macs_per_s,
            "utilisation": r.utilisation,
            "pj_per_mac": r.pj_per_mac,
            "power_w": r.power_w,
            "occupancy": dict(r.occupancy),
        })
    return rows


def autotune_row(arch: str, t: int = T_STREAM,
                 budget_buses: int = 4) -> dict:
    """The tuner's pick with the budget set at a ``budget_buses``-bus chip
    running full rate — room to trade buses against symbol rate."""
    work = workload_for(arch, t)
    pcfg = photonics.PhotonicConfig()
    budget = sim.bank_power_w(pcfg, n_buses=budget_buses)
    tuned = sim.autotune(work, pcfg, power_budget_w=budget)
    base = sim.simulate(work, pcfg)  # the default single-bus schedule
    return {
        "arch": arch, "n_buses": tuned.n_buses, "tiling": tuned.tiling,
        "f_s_ghz": tuned.f_s / 1e9, "power_budget_w": budget,
        "power_w": tuned.power_w,
        "wall_clock_us": tuned.wall_clock_s * 1e6,
        "speedup_vs_b1": base.wall_clock_s / tuned.wall_clock_s,
        "pj_per_mac": tuned.report.pj_per_mac,
    }


def run(bus_counts=(1, 2, 4), t: int = T_STREAM) -> dict:
    return {
        "sweep": [row for arch in ARCHS
                  for row in sweep_rows(arch, bus_counts, t)],
        "autotune": [autotune_row(arch, t) for arch in ARCHS],
    }


def bench_metrics(results: dict) -> dict:
    metrics = {}
    for r in results["sweep"]:
        p = f"{r['arch'].replace('.', '_').replace('-', '_')}_b{r['n_buses']}_"
        metrics[p + "wall_us"] = r["wall_clock_us"]
        metrics[p + "macs_per_s"] = r["macs_per_s"]
        metrics[p + "pj_per_mac"] = r["pj_per_mac"]
        metrics[p + "utilisation"] = r["utilisation"]
        metrics[p + "occ_adc"] = r["occupancy"]["adc"]
    for r in results["autotune"]:
        p = f"{r['arch'].replace('.', '_').replace('-', '_')}_auto_"
        metrics[p + "n_buses"] = float(r["n_buses"])
        metrics[p + "f_s_ghz"] = r["f_s_ghz"]
        metrics[p + "wall_us"] = r["wall_clock_us"]
        metrics[p + "speedup_vs_b1"] = r["speedup_vs_b1"]
        metrics[p + "power_w"] = r["power_w"]
    return metrics


def write_report(results: dict, out_dir: str = ".") -> str:
    from repro.bench import write_bench

    return write_bench("pipeline", bench_metrics(results),
                       meta={"t_stream": T_STREAM, **results},
                       out_dir=out_dir)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--buses", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--t", type=int, default=T_STREAM,
                    help="streamed vectors per step")
    ap.add_argument("--bench-dir", default=None, metavar="DIR",
                    help="also write BENCH_pipeline.json into DIR")
    args = ap.parse_args()
    results = run(bus_counts=tuple(args.buses), t=args.t)
    print("pipeline_sim: arch,n_buses,wall_us,TMAC/s,util,pJ/MAC")
    for r in results["sweep"]:
        print(f"{r['arch']},{r['n_buses']},{r['wall_clock_us']:.2f},"
              f"{r['macs_per_s'] / 1e12:.3f},{r['utilisation']:.3f},"
              f"{r['pj_per_mac']:.3f}")
    for r in results["autotune"]:
        print(f"[autotune] {r['arch']}: n_buses={r['n_buses']} "
              f"tiling={r['tiling']} f_s={r['f_s_ghz']:.2f}GHz "
              f"-> {r['wall_clock_us']:.2f}us "
              f"({r['speedup_vs_b1']:.2f}x vs 1 bus, "
              f"{r['power_w']:.1f}W <= {r['power_budget_w']:.1f}W)")
    if args.bench_dir is not None:
        print(f"[bench] wrote {write_report(results, args.bench_dir)}")


if __name__ == "__main__":
    main()
