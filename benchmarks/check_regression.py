"""Perf-regression gate over the BENCH_*.json trajectory.

Compares a fresh bench run (``--fresh``, e.g. CI's ``bench-out/``) against
the committed baselines in ``benchmarks/baselines/`` and exits nonzero when
any *gated* metric regresses past its tolerance.  Only the metrics named in
``GATES`` are gated — accuracy-style metrics have their own test-suite
checks, and ungated telemetry may move freely.

Two tolerance classes, because two kinds of metric live in the trajectory:

* **machine-independent** metrics (simulated wall-clock, cycle-count
  speedups, the fused/unfused ratio) are deterministic given the code, so
  they gate at the default −15 %;
* **absolute wall-clock** metrics (steps/s, MACs/s, p99 latency) vary with
  the host — shared CI runners jitter by tens of percent — so they carry an
  explicit looser tolerance in the registry.  They still catch the
  order-of-magnitude cliffs this gate exists for (e.g. a kernel silently
  falling back to an unfused or interpreted path).

Re-baselining (after an intentional perf change or a runner upgrade)::

    python benchmarks/run.py --smoke --bench --bench-dir bench-out
    python benchmarks/check_regression.py --fresh bench-out --update
    git add benchmarks/baselines && git commit

CI wiring: ``.github/workflows/ci.yml`` runs this right after the BENCH
schema validation; a baseline file that doesn't exist yet is reported and
skipped, so adding a new bench never turns CI red before its first
re-baseline.
"""

from __future__ import annotations

import argparse
import os
import shutil
import sys

DEFAULT_TOLERANCE = 0.15

# bench name -> {metric: direction | (direction, tolerance)}.
# direction "higher" gates fresh < baseline·(1−tol);
# direction "lower"  gates fresh > baseline·(1+tol).
_WALL = 0.60  # absolute wall-clock metrics: CI-runner jitter class
GATES: dict[str, dict[str, tuple[str, float] | str]] = {
    "train_throughput": {
        "steps_per_s": ("higher", _WALL),
        "macs_per_s": ("higher", _WALL),
        "p90_step_s": ("lower", _WALL),
    },
    "emu_kernel": {
        # the fusion ratio is the headline: both sides run on the same
        # host, so it gates tight even on noisy runners
        "fused_speedup": "higher",
        "fused_steps_per_s": ("higher", _WALL),
        "fused_macs_per_s": ("higher", _WALL),
        "fused_p99_ms": ("lower", _WALL),
    },
    "bus_scaling": {
        # simulated cycle counts — deterministic
        "cycle_speedup": "higher",
    },
    "pipeline": {
        # repro.sim timelines — deterministic
        "qwen1_5_0_5b_auto_wall_us": ("lower", DEFAULT_TOLERANCE),
        "qwen1_5_0_5b_auto_speedup_vs_b1": "higher",
    },
    "serving": {
        "capacity_req_per_s": ("higher", _WALL),
        "auto_requests_per_s": ("higher", _WALL),
        "auto_p99_latency_ms": ("lower", _WALL),
    },
    "obs": {
        # observer-on / observer-off throughput on the fused emu step:
        # both sides run back-to-back on the same host, so the ratio
        # gates tight even on noisy runners (0.95 allows scheduler
        # jitter while still catching an accidental per-step sync)
        "throughput_ratio": ("higher", 0.05),
        "on_steps_per_s": ("higher", _WALL),
    },
    "alignment": {
        # probe-on / probe-off throughput at probe_every=100 on the fused
        # emu step: same-host ratio, tight gate (acceptance is <= 5%
        # overhead; the tolerance absorbs scheduler jitter around it)
        "probe_throughput_ratio": ("higher", 0.05),
        "probe_on_steps_per_s": ("higher", _WALL),
    },
}


def _repo_paths():
    here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(os.path.dirname(here), "src"))
    return os.path.join(here, "baselines")


def _gate_spec(spec, default_tol: float) -> tuple[str, float]:
    if isinstance(spec, str):
        return spec, default_tol
    return spec


def check_bench(name: str, fresh: dict, base: dict,
                default_tol: float) -> tuple[list[str], list[str]]:
    """-> (regressions, report_lines) for one bench's gated metrics."""
    regressions, lines = [], []
    for metric, spec in GATES[name].items():
        direction, tol = _gate_spec(spec, default_tol)
        if metric not in base:
            lines.append(f"  {metric}: not in baseline — skipped")
            continue
        if metric not in fresh:
            regressions.append(f"{name}.{metric}: missing from fresh run")
            continue
        b, f = base[metric], fresh[metric]
        if b == 0:
            lines.append(f"  {metric}: zero baseline — skipped")
            continue
        delta = (f - b) / abs(b)
        bad = (delta < -tol) if direction == "higher" else (delta > tol)
        verdict = "REGRESSION" if bad else "ok"
        lines.append(f"  {metric}: baseline {b:.6g} -> fresh {f:.6g} "
                     f"({delta:+.1%}, want {direction}, tol {tol:.0%}) "
                     f"{verdict}")
        if bad:
            regressions.append(
                f"{name}.{metric}: {b:.6g} -> {f:.6g} ({delta:+.1%} "
                f"exceeds {tol:.0%} {direction}-is-better tolerance)")
    return regressions, lines


def main(argv=None) -> int:
    baselines_default = _repo_paths()
    from repro.bench import load_bench

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="bench-out",
                    help="directory with the fresh BENCH_*.json run")
    ap.add_argument("--baselines", default=baselines_default,
                    help="directory with the committed baselines")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="default relative tolerance for gated metrics")
    ap.add_argument("--update", action="store_true",
                    help="re-baseline: copy the fresh gated benches over "
                         "the committed baselines instead of checking")
    args = ap.parse_args(argv)

    if args.update:
        os.makedirs(args.baselines, exist_ok=True)
        for name in sorted(GATES):
            src = os.path.join(args.fresh, f"BENCH_{name}.json")
            if not os.path.exists(src):
                print(f"[update] {name}: no fresh BENCH_{name}.json — "
                      f"skipped")
                continue
            load_bench(src)  # refuse to baseline an invalid report
            shutil.copy(src, os.path.join(args.baselines,
                                          f"BENCH_{name}.json"))
            print(f"[update] {name}: re-baselined from {src}")
        return 0

    regressions = []
    for name in sorted(GATES):
        base_path = os.path.join(args.baselines, f"BENCH_{name}.json")
        fresh_path = os.path.join(args.fresh, f"BENCH_{name}.json")
        if not os.path.exists(base_path):
            print(f"{name}: no committed baseline — skipped "
                  f"(run --update to create one)")
            continue
        if not os.path.exists(fresh_path):
            regressions.append(f"{name}: baseline exists but the fresh run "
                               f"produced no BENCH_{name}.json")
            print(f"{name}: MISSING from fresh run")
            continue
        base = load_bench(base_path)["metrics"]
        fresh = load_bench(fresh_path)["metrics"]
        bad, lines = check_bench(name, fresh, base, args.tolerance)
        print(f"{name}:")
        for ln in lines:
            print(ln)
        regressions.extend(bad)

    if regressions:
        print(f"\n{len(regressions)} perf regression(s):", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        print("\nIf intentional, re-baseline with:\n"
              "  python benchmarks/check_regression.py "
              "--fresh <dir> --update", file=sys.stderr)
        return 1
    print("\nno perf regressions in gated metrics")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
