"""Roofline analysis (deliverable g): three-term roofline per (arch × shape)
from the dry-run's compiled artifacts.

    compute    = flops_per_device            / peak_FLOP/s   (197 TF bf16)
    memory     = hbm_traffic_per_device      / HBM_bw        (819 GB/s)
    collective = collective_bytes_per_device / link_bw       (50 GB/s)

Numbers come from the trip-count-aware HLO walker (utils/hlo_cost.py) over
the post-SPMD per-device module — equivalent to the global formulation
global_x / (chips · rate).  MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (fwd) gives the useful-compute yardstick.

  PYTHONPATH=src python -m benchmarks.roofline [--dryrun results/dryrun.json]
      [--mesh single] [--csv]
"""

from __future__ import annotations

import argparse
import json

import jax.numpy as jnp

from repro import configs
from repro.launch import analysis


def _arch_dims(arch_name: str):
    """(n_layers, d_model, vocab) from the full config — no allocation."""
    model = configs.get(arch_name).make_model(jnp.bfloat16)
    cfg = model.cfg
    if arch_name == "whisper-small":
        return cfg.n_enc_layers + cfg.n_dec_layers, cfg.d_model, cfg.vocab_size
    return cfg.n_layers, cfg.d_model, cfg.vocab_size


def memory_floor_bytes(rec: dict) -> float:
    """TPU-projected per-device HBM traffic floor for one step.

    The HLO walker's mem proxy counts every CPU-backend fusion boundary —
    on TPU, flash-attention tiles and elementwise chains stay in VMEM, so
    the walker number is an upper bound.  This floor counts traffic that
    MUST hit HBM:

      train:   param-state R/W (params fwd+bwd reads, grad write, optimizer
               R/M/W of params+momentum ≈ 6× param bytes) + DFA tape W+R
               + per-layer error reads + 3× f32 logits
      prefill: params + 2× activations + logits
      decode:  params (active) + full KV/state cache read + logits row
    """
    chips = rec.get("chips", 1)
    L, D, V = _arch_dims(rec["arch"])
    tokens = rec.get("tokens", 0)
    p_dev = rec.get("param_bytes", 0) / chips
    act_dev = tokens * D * 2 / chips  # bf16, batch+model sharded overall
    kind = rec["kind"]
    if kind == "train":
        tape = L * act_dev
        e_reads = L * tokens * D * 2 / chips
        logits = 3 * tokens * V * 4 / chips
        return 6 * p_dev + 2 * tape + e_reads + logits
    if kind == "prefill":
        logits = tokens * V * 2 / chips
        return p_dev + 2 * L * act_dev + logits
    # decode: params read once per token + cache read; active params for MoE
    active_frac = rec.get("n_params_active", 1) / max(rec.get("n_params", 1), 1)
    cache = (rec.get("memory", {}).get("argument_size_in_bytes", 0)
             - rec.get("param_bytes", 0) / chips)
    cache = max(cache, 0)
    logits = tokens * V * 2 / chips
    return p_dev * active_frac + cache + logits


def roofline_rows(dryrun_path: str, mesh: str = "single") -> list[dict]:
    with open(dryrun_path) as f:
        records = json.load(f)
    rows = []
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        row = {"arch": r["arch"], "shape": r["shape"], "status": r["status"]}
        if r["status"] != "ok":
            row["note"] = r.get("reason", "")[:80]
            rows.append(row)
            continue
        hc = r.get("hlo_cost", {})
        flops = hc.get("flops", 0.0)
        mem_upper = hc.get("mem_bytes", 0.0)
        mem_floor = memory_floor_bytes(r)
        coll = hc.get("collective_bytes", 0.0)
        # dominance judged on the TPU-projected floor; the unfused upper
        # bound is reported alongside
        terms = analysis.roofline_terms(flops, mem_floor, coll, chips=1)
        n_act = r.get("n_params_active", r.get("n_params", 0))
        model_fl = analysis.model_flops_reference(n_act, r.get("tokens", 0), r["kind"])
        chips = r.get("chips", 1)
        hbm = r.get("memory", {}).get("total_hbm_bytes", 0)
        row.update({
            "kind": r["kind"],
            "chips": chips,
            "t_compute_s": terms["t_compute_s"],
            "t_memory_s": terms["t_memory_s"],
            "t_memory_upper_s": mem_upper / analysis.HBM_BW,
            "t_collective_s": terms["t_collective_s"],
            "dominant": terms["dominant"],
            "compute_fraction": terms["compute_fraction"],
            "model_flops": model_fl,
            "useful_ratio": (model_fl / (flops * chips)) if flops else 0.0,
            "hbm_per_dev_gib": hbm / 2**30,
            "fits_v5e": hbm <= 16 * 2**30,
        })
        rows.append(row)
    return rows


def advice(row: dict) -> str:
    d = row.get("dominant")
    if d == "collective":
        return "overlap/shrink collectives: TP-block resharding, error compression"
    if d == "memory":
        return "raise arithmetic intensity: fuse epilogues, larger tiles, bf16 states"
    return "compute-bound: good — push MXU utilisation / cut redundant flops"


def print_table(rows: list[dict]):
    hdr = (f"{'arch':18s} {'shape':11s} {'st':4s} {'dom':10s} "
           f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_memUB(s)':>10s} {'t_coll(s)':>10s} "
           f"{'cf':>5s} {'useful':>7s} {'HBM GiB':>8s} fit")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:18s} {r['shape']:11s} {r['status']:4s} — {r.get('note','')}")
            continue
        print(f"{r['arch']:18s} {r['shape']:11s} ok   {r['dominant']:10s} "
              f"{r['t_compute_s']:10.3e} {r['t_memory_s']:10.3e} "
              f"{r['t_memory_upper_s']:10.3e} {r['t_collective_s']:10.3e} "
              f"{r['compute_fraction']:5.2f} {r['useful_ratio']:7.2f} "
              f"{r['hbm_per_dev_gib']:8.2f} {'Y' if r['fits_v5e'] else 'N'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = roofline_rows(args.dryrun, args.mesh)
    if args.csv:
        import csv
        import sys

        keys = ["arch", "shape", "status", "kind", "dominant", "t_compute_s",
                "t_memory_s", "t_collective_s", "compute_fraction",
                "useful_ratio", "hbm_per_dev_gib", "fits_v5e"]
        w = csv.DictWriter(sys.stdout, fieldnames=keys, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow(r)
    else:
        print_table(rows)
        print()
        for r in rows:
            if r["status"] == "ok":
                print(f"  {r['arch']:18s} {r['shape']:11s} -> {advice(r)}")


if __name__ == "__main__":
    main()
