"""Paper Fig. 6 + §5 headline numbers: energy/op vs #MAC cells (heaters vs
post-fab trimming), 50×20-bank TOPS / pJ-per-op / TOPS-per-mm²."""

from __future__ import annotations

from repro.core import energy


def run():
    rows = []
    for trimming in (False, True):
        cfg = energy.EnergyConfig(trimming=trimming)
        label = "trimming" if trimming else "heaters"
        for r in energy.fig6_curve(cfg):
            rows.append({"variant": label, **r})
    return rows


def headline():
    heat = energy.EnergyConfig(trimming=False)
    trim = energy.EnergyConfig(trimming=True)
    return {
        "tops_50x20": energy.ops_per_second(50, 20, heat) / 1e12,  # paper: 20
        "pj_heaters": energy.energy_per_op(50, 20, heat) * 1e12,  # paper: 1.0
        "pj_trimming": energy.energy_per_op(50, 20, trim) * 1e12,  # paper: 0.28
        "tops_mm2": energy.compute_density_tops_mm2(50, 20, heat),  # paper: 5.78
    }


def main():
    h = headline()
    print("fig6_headline: tops=%.2f pj_heaters=%.3f pj_trimming=%.3f tops_mm2=%.2f"
          % (h["tops_50x20"], h["pj_heaters"], h["pj_trimming"], h["tops_mm2"]))
    print("fig6_curve: variant,cells,m,n,e_op_pj")
    for r in run():
        print(f"{r['variant']},{r['cells']},{r['m']},{r['n']},{r['e_op_pj']:.3f}")


if __name__ == "__main__":
    main()
