"""Paper Fig. 3(c): per-MAC multiplication error → effective resolution.

Simulates the single-MRR multiplication experiment (3900 random operand
pairs) through the photonic execution model and reports the error std /
effective bits for each hardware preset, against the paper's measured
values (σ=0.019 → 6.72 b single MRR; 0.098 → 4.35 b off-chip BPD;
0.202 → 3.31 b on-chip BPD)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photonics

PAPER = {"single_mrr": (0.019, 6.72), "offchip_bpd": (0.098, 4.35),
         "onchip_bpd": (0.202, 3.31)}


def run(n: int = 3900, seed: int = 0):
    rows = []
    key = jax.random.PRNGKey(seed)
    for preset, (sigma, bits) in PAPER.items():
        cfg = photonics.preset(preset)
        # random multiplications: 1-element inner products
        ka, kb, kn = jax.random.split(jax.random.fold_in(key, hash(preset) % 2**31), 3)
        a = jax.random.uniform(ka, (n, 1), minval=-1, maxval=1)
        b = jax.random.uniform(kb, (1, 1), minval=-1, maxval=1)
        outs = photonics.photonic_matmul(a, b, cfg, key=kn)
        err = np.asarray(outs - a @ b.T).ravel()
        meas_std = float(err.std())
        scale = float(jnp.max(jnp.abs(a)) * jnp.max(jnp.abs(b)))
        meas_bits = photonics.std_to_bits(meas_std / scale)
        rows.append({
            "preset": preset, "paper_sigma": sigma, "paper_bits": bits,
            "measured_sigma": meas_std, "measured_bits": meas_bits,
        })
    return rows


def main():
    print("fig3c_mac_noise: preset,paper_sigma,paper_bits,measured_bits")
    for r in run():
        print(f"{r['preset']},{r['paper_sigma']},{r['paper_bits']},{r['measured_bits']:.2f}")


if __name__ == "__main__":
    main()
