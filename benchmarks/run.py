"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
benchmark's core computation; derived = the figure's headline quantity and
its paper anchor).  Individual modules offer richer CLIs:

  python -m benchmarks.mac_noise          (Fig. 3c)
  python -m benchmarks.mnist_accuracy     (Fig. 5b; --full for paper scale)
  python -m benchmarks.resolution_sweep   (Fig. 5c)
  python -m benchmarks.energy             (Fig. 6 / Eq. 2)
  python -m benchmarks.gemm_cycles        (§3 GeMM compiler)
  python -m benchmarks.dfa_vs_bp          (§1 claim)
  python -m benchmarks.roofline           (deliverable g; --bench auto-
                                           generates results/dryrun.json)
  python -m benchmarks.pipeline_sim       (repro.sim timing study)
  python -m benchmarks.emu_kernel         (fused emu-kernel speedup)

``--smoke`` instead runs one ``repro.api.build_session(...).fit`` step for
EVERY algorithm registered in ``repro.algos`` (mnist_mlp smoke arch) — the
registry's rot check: a newly registered algorithm that can't complete a
training step fails here (and in tests/test_api_smoke.py) immediately —
plus one fit step through the device-level "emu" backend, plus a reduced
``benchmarks.mac_noise`` sweep checking the measured per-MAC effective
bits against the paper's Fig. 3(c) values.  Exit code is the gate:
nonzero when any of them fails.

``--bench`` measures training throughput (repro.bench.StepTimer over a
data-parallel ``Session.fit``) and writes ``BENCH_train_throughput.json``
plus the drift/recalibration study (``benchmarks.drift_recovery``) as
``BENCH_hardware.json``, the multi-wavelength scale-out sweep
(``benchmarks.bus_scaling``) as ``BENCH_bus_scaling.json``, the repro.sim
timing study (``benchmarks.pipeline_sim``) as ``BENCH_pipeline.json``,
the roofline + photonic-backward parity numbers (auto-generating the
dry-run record when missing) as ``BENCH_roofline.json``, and the
request-level serving study (``benchmarks.serving``: p50/p99 TTFT and
latency, requests/s and J/request vs offered load + the SLO-constrained
serving autotuner) as ``BENCH_serving.json``, and the fused emu-kernel
study (``benchmarks.emu_kernel``: fused vs unfused steps/s and MACs/s
plus the measured-feedback schedule co-tuning) as
``BENCH_emu_kernel.json``, and the observability overhead study
(``benchmarks.obs_overhead``: observer-off vs observer-on fit throughput
on the fused emu step, with the run's Chrome trace + metrics JSONL as
artifacts) as ``BENCH_obs.json``, and the diagnostics-plane study
(``benchmarks.alignment``: DFA-vs-BP alignment curves, the emu
noise-budget attribution + closure check, probe-on vs probe-off
throughput) as ``BENCH_alignment.json``; combined with ``--smoke`` it also
writes ``BENCH_smoke.json``.  CI archives the ``BENCH_*.json`` files — they are
the repo's perf trajectory, and ``benchmarks/check_regression.py`` gates
changes against the committed ``benchmarks/baselines/``.
"""

from __future__ import annotations

import argparse
import importlib
import os
import time


def _sibling(name: str):
    """Import a sibling benchmark module under either invocation style:
    ``python -m benchmarks.run`` (package) or ``python benchmarks/run.py``
    (CI — sys.path[0] is the benchmarks dir itself)."""
    try:
        return importlib.import_module(f"benchmarks.{name}")
    except ModuleNotFoundError:
        return importlib.import_module(name)


def _timed(fn):
    t0 = time.monotonic()
    out = fn()
    return (time.monotonic() - t0) * 1e6, out


def fig3c_mac_noise():
    from benchmarks.mac_noise import run

    us, rows = _timed(lambda: run(n=3900))
    best = {r["preset"]: r["measured_bits"] for r in rows}
    derived = ("bits[single_mrr]=%.2f(paper 6.72) offchip=%.2f(4.35) "
               "onchip=%.2f(3.31)" % (best["single_mrr"], best["offchip_bpd"],
                                      best["onchip_bpd"]))
    return us, derived


def fig5b_mnist_noise_robustness():
    from benchmarks.mnist_accuracy import run

    us, rows = _timed(lambda: run(train_n=16384, test_n=4096, steps=1024,
                                  hidden=(800, 800)))
    acc = {r["preset"]: r["test_accuracy"] for r in rows}
    src = rows[0]["source"]
    derived = ("acc%%[%s]: ideal=%.2f offchip=%.2f onchip=%.2f "
               "(paper@MNIST: 98.10/97.41/96.33)"
               % (src, acc["ideal"], acc["offchip_bpd"], acc["onchip_bpd"]))
    return us, derived


def fig5c_resolution_sweep():
    from benchmarks.resolution_sweep import run

    us, rows = _timed(lambda: run(bits_list=(3.31, 4.35, 8.0), steps=256))
    pts = " ".join(f"{r['bits']}b={r['test_accuracy']:.1f}%" for r in rows)
    return us, f"acc vs resolution: {pts} (robust >=3.31b per paper)"


def fig6_energy_model():
    from benchmarks.energy import headline

    us, h = _timed(headline)
    return us, ("tops=%.1f(paper 20) pJ_heat=%.2f(1.0) pJ_trim=%.2f(0.28) "
                "tops_mm2=%.2f(5.78)" % (h["tops_50x20"], h["pj_heaters"],
                                         h["pj_trimming"], h["tops_mm2"]))


def tab_gemm_cycles():
    from benchmarks.gemm_cycles import run

    us, rows = _timed(run)
    mlp = rows[0]
    return us, ("paper MLP backward: %d cycles %.1f ns on 50x20 bank "
                "(%.1f TOPS)" % (mlp["cycles"], mlp["seconds"] * 1e9, mlp["tops"]))


def tab_dfa_vs_bp():
    from benchmarks.dfa_vs_bp import run

    us, rows = _timed(lambda: run(steps=768))
    d = {r["algo"]: r["test_accuracy"] for r in rows}
    return us, ("dfa=%.2f%% bp=%.2f%% align(h0)=%.2f align(h1)=%.2f"
                % (d["dfa"], d["bp"], d["alignment_h0"], d["alignment_h1"]))


def tab_ternary_error():
    from benchmarks.ternary_error import run

    us, rows = _timed(lambda: run(steps=384))
    d = {r["error_compress"]: r["test_accuracy"] for r in rows}
    return us, ("acc%%: full=%.2f int8=%.2f ternary=%.2f "
                "(int8 lossless at 1/4 broadcast; ternary trades accuracy "
                "at short horizons — ref[48] closes the gap at scale)"
                % (d["none"], d["int8"], d["ternary"]))


def tab_dfa_pipeline_latency():
    sim_rows = _sibling("dfa_pipeline_latency").sim_rows

    us, rows = _timed(sim_rows)
    if not rows:
        return us, "SKIP (no results/dryrun.json)"
    r = rows[0]
    return us, ("photonic DFA backward (repro.sim): %s %.3fs vs BP bwd "
                "%.3fs -> %.0f buses for parity"
                % (r["arch"], r["t_dfa_bwd_sim_s"], r["t_bp_bwd_s"],
                   r["buses_for_parity"]))


def tab_roofline():
    path = os.environ.get("REPRO_DRYRUN_JSON", "results/dryrun.json")
    if not os.path.exists(path):
        return 0.0, f"SKIP (no {path}; run python -m repro.launch.dryrun)"
    from benchmarks.roofline import roofline_rows

    us, rows = _timed(lambda: roofline_rows(path, "single"))
    ok = [r for r in rows if r["status"] == "ok"]
    worst = min(ok, key=lambda r: r["compute_fraction"])
    best = max(ok, key=lambda r: r["compute_fraction"])
    return us, ("%d cells; compute-fraction best=%.2f(%s/%s) worst=%.2f(%s/%s)"
                % (len(ok), best["compute_fraction"], best["arch"], best["shape"],
                   worst["compute_fraction"], worst["arch"], worst["shape"]))


def tab_bus_scaling():
    from benchmarks.bus_scaling import bench_metrics, run

    us, rows = _timed(lambda: run(steps=64))
    m = bench_metrics(rows)
    return us, ("%d-bus LM backward: %.1fx cycle speedup, acc spread "
                "%.2fpts, pJ/MAC %.2f->%.2f"
                % (max(r["n_buses"] for r in rows), m["cycle_speedup"],
                   m["acc_spread_pts"],
                   m[f"pj_per_mac_b{min(r['n_buses'] for r in rows)}"],
                   m[f"pj_per_mac_b{max(r['n_buses'] for r in rows)}"]))


def tab_drift_recovery():
    from benchmarks.drift_recovery import bench_metrics, run

    us, rows = _timed(lambda: run(steps=128))
    m = bench_metrics(rows)
    return us, ("emu-vs-ref gap=%.2fpts; drift costs %.2fpts, "
                "recalibration recovers %.2fpts"
                % (m["emu_vs_ref_gap_pts"], m["drift_cost_pts"],
                   m["recal_recovery_pts"]))


TABLES = [
    ("fig3c_mac_noise", fig3c_mac_noise),
    ("fig5b_mnist_noise_robustness", fig5b_mnist_noise_robustness),
    ("fig5c_resolution_sweep", fig5c_resolution_sweep),
    ("fig6_energy_model", fig6_energy_model),
    ("tab_gemm_cycles", tab_gemm_cycles),
    ("tab_dfa_vs_bp", tab_dfa_vs_bp),
    ("tab_ternary_error", tab_ternary_error),
    ("tab_dfa_pipeline_latency", tab_dfa_pipeline_latency),
    ("tab_bus_scaling", tab_bus_scaling),
    ("tab_drift_recovery", tab_drift_recovery),
    ("tab_roofline", tab_roofline),
]


def _smoke_mac_noise(n: int = 1024, tolerance_bits: float = 0.5):
    """Reduced Fig. 3(c) sweep: every preset's measured effective bits must
    land within ``tolerance_bits`` of the paper's value — the noise-model
    calibration rot check (previously orphaned from CI)."""
    run = _sibling("mac_noise").run

    worst = 0.0
    for r in run(n=n):
        worst = max(worst, abs(r["measured_bits"] - r["paper_bits"]))
    if worst > tolerance_bits:
        raise AssertionError(
            f"mac-noise calibration off by {worst:.2f} bits "
            f"(> {tolerance_bits})")
    return worst


def smoke(bench_dir: str | None = None) -> int:
    """One fit step per registered algorithm through repro.api (plus the
    device-level "emu" backend and the mac-noise calibration check);
    returns the number of failures (the CLI exit code — CI gates on it).
    With ``bench_dir`` the per-algorithm timings land in BENCH_smoke.json."""
    import jax

    from repro import algos, api

    failures = 0
    rows = []
    cells = [(name, {}) for name in algos.list_algos()]
    # the hardware-emulation backend through the same rot check (drifting
    # device + in-situ calibration exercised by the fit step)
    cells.append(("dfa@emu", {"backend": "emu", "hardware": "emu_onchip",
                              "recalibrate_every": 1}))
    print("smoke: algo,us_per_call,loss")
    for name, extra in cells:
        try:
            session = api.build_session(arch="mnist_mlp", algo=name.split("@")[0],
                                        smoke=True, log_every=10**9, **extra)
            kx, ky = jax.random.split(jax.random.PRNGKey(0))
            batch = {
                "x": jax.random.normal(kx, (16, session.model.in_dim)),
                "y": jax.random.randint(ky, (16,), 0, session.model.n_classes),
            }
            us, (state, metrics) = _timed(
                lambda: session.fit(lambda step: batch, total_steps=1,
                                    verbose=False))
            # one scalar read per cell, outside the timed region
            loss = float(metrics["loss"])  # lint: disable=RL002
            rows.append({"algo": name, "us_per_fit_step": us, "loss": loss})
            print(f"{name},{us:.0f},{loss:.4f}", flush=True)
        except Exception as ex:
            failures += 1
            rows.append({"algo": name, "error": f"{type(ex).__name__}: {str(ex)[:200]}"})
            print(f"{name},0,ERROR {type(ex).__name__}: {str(ex)[:120]}", flush=True)
    try:
        us, worst = _timed(_smoke_mac_noise)
        print(f"mac_noise,{us:.0f},worst_bits_err={worst:.3f}", flush=True)
    except Exception as ex:
        failures += 1
        print(f"mac_noise,0,ERROR {type(ex).__name__}: {str(ex)[:120]}",
              flush=True)
    if bench_dir is not None:
        from repro.bench import write_bench

        ok = [r for r in rows if "error" not in r]
        path = write_bench(
            "smoke",
            {"algorithms": len(rows), "failures": failures,
             "mean_us_per_fit_step":
                 sum(r["us_per_fit_step"] for r in ok) / max(len(ok), 1)},
            meta={"rows": rows}, out_dir=bench_dir)
        print(f"[bench] wrote {path}", flush=True)
    return failures


def bench_throughput(out_dir: str = ".", steps: int = 32, batch: int = 256,
                     algo: str = "dfa", arch: str = "mnist_mlp") -> str:
    """Measure data-parallel training throughput and write
    BENCH_train_throughput.json (steps/s, examples/s, model MACs/s)."""
    import numpy as np

    from repro import api
    from repro.bench import StepTimer, clamped_warmup, report_throughput
    from repro.data import pipeline

    session = api.build_session(arch=arch, algo=algo, smoke=True,
                                log_every=10**9)
    rng = np.random.default_rng(0)
    n = batch * 4
    x = rng.normal(size=(n, session.model.in_dim)).astype("float32")
    y = rng.integers(0, session.model.n_classes, size=(n,)).astype("int32")
    pipe = pipeline.ArrayClassification(x, y, batch_size=batch, seed=0)
    timer = StepTimer(warmup=clamped_warmup(steps, max(2, steps // 8)))
    state, _ = session.fit(pipe.batch, total_steps=steps, verbose=False,
                           timer=timer)
    path, _summary = report_throughput(
        session, state, pipe.batch(0), timer,
        meta={"arch": arch, "algo": algo, "batch": batch, "steps": steps},
        out_dir=out_dir)
    return path


def bench_hardware(out_dir: str = ".", steps: int = 192) -> str:
    """Run the drift/recalibration study and write BENCH_hardware.json."""
    dr = _sibling("drift_recovery")

    path = dr.write_report(dr.run(steps=steps), out_dir)
    print(f"[bench] wrote {path}", flush=True)
    return path


def bench_bus_scaling(out_dir: str = ".", steps: int = 96) -> str:
    """Run the multi-wavelength scale-out sweep and write
    BENCH_bus_scaling.json (accuracy / cycles / pJ-per-MAC vs bus count)."""
    bs = _sibling("bus_scaling")

    path = bs.write_report(bs.run(steps=steps), out_dir)
    print(f"[bench] wrote {path}", flush=True)
    return path


def bench_pipeline(out_dir: str = ".") -> str:
    """Run the repro.sim pipeline study (latency / MACs-per-s / occupancy /
    pJ-per-MAC vs bus count + the autotuner's pick) and write
    BENCH_pipeline.json."""
    ps = _sibling("pipeline_sim")

    path = ps.write_report(ps.run(), out_dir)
    print(f"[bench] wrote {path}", flush=True)
    return path


def bench_serving(out_dir: str = ".") -> str:
    """Run the request-level serving study (p50/p99 TTFT + latency,
    requests/s, J/request vs offered load, plus the SLO-constrained
    serving autotuner) and write BENCH_serving.json."""
    sv = _sibling("serving")

    path = sv.write_report(sv.run(), out_dir)
    print(f"[bench] wrote {path}", flush=True)
    return path


def bench_emu_kernel(out_dir: str = ".", steps: int = 3) -> str:
    """Run the fused emu-kernel study (ref vs fused-xla step time on the
    qwen1.5-0.5b-shaped DFA backward + the measured-feedback schedule
    co-tuning) and write BENCH_emu_kernel.json."""
    ekb = _sibling("emu_kernel")

    path = ekb.write_report(ekb.run(steps=steps, warmup=1), out_dir)
    print(f"[bench] wrote {path}", flush=True)
    return path


def bench_obs(out_dir: str = ".", steps: int = 96) -> str:
    """Run the observability overhead study (observer-off vs observer-on
    fit throughput on the fused emu step, trace + metrics artifacts) and
    write BENCH_obs.json."""
    ob = _sibling("obs_overhead")

    path = ob.write_report(ob.run(steps=steps, out_dir=out_dir), out_dir)
    print(f"[bench] wrote {path}", flush=True)
    return path


def bench_alignment(out_dir: str = ".", steps: int = 160) -> str:
    """Run the diagnostics-plane study (DFA-vs-BP alignment curves on ref
    + emu_onchip MNIST fits, the emu noise-budget attribution with its
    closure check, probe-on vs probe-off throughput) and write
    BENCH_alignment.json plus the archived diagnostics JSONL."""
    al = _sibling("alignment")

    path = al.write_report(al.run(steps=steps, out_dir=out_dir), out_dir)
    print(f"[bench] wrote {path}", flush=True)
    return path


def _dryrun_path(out_dir: str = ".") -> str:
    """Where the roofline's dry-run record lives: the env override, an
    existing local ``results/dryrun.json``, else INSIDE the bench dir —
    auto-generation must not scatter side-outputs relative to the CWD
    when the caller asked for everything under ``--bench-dir``."""
    override = os.environ.get("REPRO_DRYRUN_JSON")
    if override:
        return override
    legacy = os.path.join("results", "dryrun.json")
    if os.path.exists(legacy):
        return legacy
    return os.path.join(out_dir, "dryrun.json")


def _ensure_dryrun(path: str, arch: str = "qwen1.5-0.5b") -> str:
    """Auto-generate the dry-run record the roofline needs (one train cell
    on the single-pod mesh, ~10 s) when none exists yet.  Runs in a
    subprocess: repro.launch.dryrun forces 512 placeholder devices at
    import, which must not leak into this process's jax."""
    import subprocess
    import sys

    if os.path.exists(path):
        return path
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", "train_4k", "--mesh", "single", "--out", path],
        check=True, env=env)
    return path


def bench_roofline(out_dir: str = ".") -> str:
    """Wire the (previously orphaned) roofline + DFA-pipeline-latency
    studies into the bench trajectory: auto-generate the dry-run record if
    missing, then write BENCH_roofline.json (per-cell roofline terms plus
    the repro.sim photonic-backward parity numbers)."""
    rl = _sibling("roofline")
    dpl = _sibling("dfa_pipeline_latency")
    from repro.bench import write_bench

    path = _ensure_dryrun(_dryrun_path(out_dir))
    rows = rl.roofline_rows(path, "single")
    sim_rows = dpl.sim_rows(path, "single")
    metrics = {}
    for r in rows:
        if r["status"] != "ok":
            continue
        p = r["arch"].replace(".", "_").replace("-", "_")
        metrics[f"{p}_{r['shape']}_compute_fraction"] = r["compute_fraction"]
        metrics[f"{p}_{r['shape']}_t_compute_s"] = r["t_compute_s"]
        metrics[f"{p}_{r['shape']}_t_memory_s"] = r["t_memory_s"]
    for r in sim_rows:
        p = r["arch"].replace(".", "_").replace("-", "_")
        metrics[f"{p}_{r['shape']}_dfa_bwd_sim_s"] = r["t_dfa_bwd_sim_s"]
        metrics[f"{p}_{r['shape']}_buses_for_parity"] = r["buses_for_parity"]
    if not metrics:
        raise RuntimeError(f"no ok roofline cells in {path}")
    out = write_bench("roofline", metrics,
                      meta={"dryrun": path, "rows": rows,
                            "sim_rows": sim_rows}, out_dir=out_dir)
    print(f"[bench] wrote {out}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one build_session().fit step per registered algorithm")
    ap.add_argument("--bench", action="store_true",
                    help="record BENCH_*.json throughput telemetry")
    ap.add_argument("--bench-dir", default=".",
                    help="directory for BENCH_*.json output")
    ap.add_argument("--bench-steps", type=int, default=32)
    ap.add_argument("--bench-batch", type=int, default=256)
    ap.add_argument("--bench-algo", default="dfa")
    ap.add_argument("--hardware-steps", type=int, default=192,
                    help="training steps per drift_recovery variant")
    ap.add_argument("--bus-steps", type=int, default=96,
                    help="training steps per bus_scaling cell")
    args = ap.parse_args()
    if args.smoke:
        failures = smoke(bench_dir=args.bench_dir if args.bench else None)
        if failures or not args.bench:
            raise SystemExit(min(failures, 1))
        # --smoke --bench: smoke passed — continue to the throughput bench
    if args.bench:
        bench_throughput(out_dir=args.bench_dir, steps=args.bench_steps,
                         batch=args.bench_batch, algo=args.bench_algo)
        bench_hardware(out_dir=args.bench_dir, steps=args.hardware_steps)
        bench_bus_scaling(out_dir=args.bench_dir, steps=args.bus_steps)
        bench_pipeline(out_dir=args.bench_dir)
        bench_roofline(out_dir=args.bench_dir)
        bench_serving(out_dir=args.bench_dir)
        bench_emu_kernel(out_dir=args.bench_dir)
        bench_obs(out_dir=args.bench_dir)
        bench_alignment(out_dir=args.bench_dir)
        return
    print("name,us_per_call,derived")
    for name, fn in TABLES:
        try:
            us, derived = fn()
            print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as ex:  # keep the harness going
            print(f"{name},0,ERROR {type(ex).__name__}: {str(ex)[:120]}", flush=True)


if __name__ == "__main__":
    main()
