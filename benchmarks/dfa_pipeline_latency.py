"""DFA's signature systems property, quantified at pod scale: the backward
pass has NO inter-layer dependency (paper: "all the network layers can be
updated in parallel during the backward pass"), so under stage (pipeline)
parallelism the backward **bubble disappears**.

Analytical critical-path model (GPipe-style schedule, S stages, M
microbatches, per-stage fwd time f, per-stage bwd time b ≈ 2f):

    backprop  : T = (M + S - 1)·(f + b)          — bubble in fwd AND bwd
    DFA       : T = (M + S - 1)·f + b + e        — fwd pipeline bubble only;
                every stage runs its whole backward concurrently after ONE
                broadcast of the error e (e ≈ one stage-boundary transfer)

Bubble fraction saved = [(S-1)(f+b) - (S-1)f - b] / [(M+S-1)(f+b)].

The per-stage times are derived from the dry-run's per-device compute
roofline term (flops / peak), so the model is anchored to the compiled
artifacts rather than invented constants.  This is a latency (critical-path)
property: per-device collective BYTES are unchanged, which is why it is
reported here and not as a roofline-term change (DESIGN.md §8.9).
"""

from __future__ import annotations

import json
import os


def pipeline_times(f: float, b: float, stages: int, micro: int):
    bp = (micro + stages - 1) * (f + b)
    dfa = (micro + stages - 1) * f + b + f  # + e-broadcast ≈ one stage hop
    return bp, dfa


def run(dryrun_path="results/dryrun.json", stages=(2, 4, 8), micro=(1, 4, 16)):
    rows = []
    if not os.path.exists(dryrun_path):
        return rows
    recs = {(r["arch"], r["shape"]): r for r in json.load(open(dryrun_path))
            if r.get("mesh") == "single" and r.get("status") == "ok"}
    for arch in ("granite-8b", "kimi-k2-1t-a32b", "qwen3-1.7b"):
        r = recs.get((arch, "train_4k"))
        if r is None:
            continue
        flops = r["hlo_cost"]["flops"]
        # fwd ≈ 1/3 of the train step's flops, bwd ≈ 2/3 (standard split)
        t_total = flops / 197e12
        f_all, b_all = t_total / 3, 2 * t_total / 3
        for s in stages:
            for m in micro:
                fs, bs = f_all / s, b_all / s
                bp, dfa = pipeline_times(fs, bs, s, m)
                rows.append({
                    "arch": arch, "stages": s, "microbatches": m,
                    "t_bp_s": bp * s, "t_dfa_s": dfa * s,  # absolute per step
                    "speedup": bp / dfa,
                })
    return rows


def main():
    print("dfa_pipeline_latency: arch,stages,micro,t_bp_s,t_dfa_s,speedup")
    for r in run():
        print(f"{r['arch']},{r['stages']},{r['microbatches']},"
              f"{r['t_bp_s']:.3f},{r['t_dfa_s']:.3f},{r['speedup']:.3f}")


if __name__ == "__main__":
    main()
