"""DFA's signature systems property at pod scale: the backward pass has
NO inter-layer dependency (paper: "all the network layers can be updated
in parallel during the backward pass"), so under stage parallelism the
backward bubble disappears — and the photonic coprocessor can run the
whole feedback backward concurrently with the forward pipeline.

The original analytic two-number model here (per-stage fwd time f, bwd
time b ≈ 2f, GPipe critical paths) is DEPRECATED: ``repro.sim`` now
replays the actual panel schedule of the photonic backward as component-
timed event timelines, so ``sim_rows`` prices the DFA backward with the
simulator instead of the b ≈ 2f guess.  Per (arch × train_4k) dry-run
cell it reports:

* ``t_fwd_s`` / ``t_bp_bwd_s`` — the TPU pipeline's forward and backprop
  backward times from the dry-run's compute roofline term (unchanged:
  these describe the digital substrate);
* ``t_dfa_bwd_sim_s`` — the photonic feedback backward's simulated
  wall-clock on a single 50×20 bus (repro.sim timeline, fills + heater
  update included);
* ``buses_for_parity`` — how many parallel WDM buses the photonic
  coprocessor needs before its backward hides under the TPU backward it
  replaces (wall-clock scales ~1/buses; the honest scale-out price).

``benchmarks/run.py --bench`` folds these rows into BENCH_roofline.json.
"""

from __future__ import annotations

import json
import os
import warnings


def pipeline_times(f: float, b: float, stages: int, micro: int):
    bp = (micro + stages - 1) * (f + b)
    dfa = (micro + stages - 1) * f + b + f  # + e-broadcast ≈ one stage hop
    return bp, dfa


def run(dryrun_path="results/dryrun.json", stages=(2, 4, 8), micro=(1, 4, 16)):
    """DEPRECATED analytic model (b ≈ 2f critical paths) — use
    ``sim_rows``: repro.sim times the photonic backward from its real
    panel schedule instead of a two-number guess."""
    warnings.warn(
        "dfa_pipeline_latency.run() is deprecated: use sim_rows() — "
        "repro.sim replays the real panel schedule",
        DeprecationWarning, stacklevel=2)
    rows = []
    if not os.path.exists(dryrun_path):
        return rows
    recs = {(r["arch"], r["shape"]): r for r in json.load(open(dryrun_path))
            if r.get("mesh") == "single" and r.get("status") == "ok"}
    for arch in ("granite-8b", "kimi-k2-1t-a32b", "qwen3-1.7b"):
        r = recs.get((arch, "train_4k"))
        if r is None:
            continue
        flops = r["hlo_cost"]["flops"]
        # fwd ≈ 1/3 of the train step's flops, bwd ≈ 2/3 (standard split)
        t_total = flops / 197e12
        f_all, b_all = t_total / 3, 2 * t_total / 3
        for s in stages:
            for m in micro:
                fs, bs = f_all / s, b_all / s
                bp, dfa = pipeline_times(fs, bs, s, m)
                rows.append({
                    "arch": arch, "stages": s, "microbatches": m,
                    "t_bp_s": bp * s, "t_dfa_s": dfa * s,  # absolute per step
                    "speedup": bp / dfa,
                })
    return rows


def sim_rows(dryrun_path="results/dryrun.json", mesh="single") -> list:
    """Per train cell: TPU fwd/bwd roofline times vs the repro.sim
    timeline of the photonic DFA backward (see module docstring)."""
    import jax.numpy as jnp

    from repro import configs, sim
    from repro.core import photonics
    from repro.launch import analysis

    if not os.path.exists(dryrun_path):
        return []
    rows = []
    with open(dryrun_path) as f:
        recs = json.load(f)
    for r in sorted(recs, key=lambda r: r["arch"]):
        if (r.get("mesh") != mesh or r.get("status") != "ok"
                or r.get("kind") != "train"):
            continue
        model = configs.get(r["arch"]).make_model(jnp.bfloat16)  # no alloc
        work = sim.dfa_backward_workload(model, t=r["tokens"])
        rep = sim.simulate(work, photonics.PhotonicConfig())
        t_total = r["hlo_cost"]["flops"] / analysis.PEAK_FLOPS_BF16
        t_fwd, t_bp_bwd = t_total / 3, 2 * t_total / 3
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_fwd_s": t_fwd, "t_bp_bwd_s": t_bp_bwd,
            "t_dfa_bwd_sim_s": rep.wall_clock_s,
            "photonic_macs_per_s": rep.macs_per_s,
            "buses_for_parity": rep.wall_clock_s / t_bp_bwd
            if t_bp_bwd > 0 else float("inf"),
        })
    return rows


def main():
    rows = sim_rows()
    if not rows:
        print("no results/dryrun.json train cells — run repro.launch.dryrun")
        return
    print("dfa_pipeline_latency (repro.sim): "
          "arch,t_fwd_s,t_bp_bwd_s,t_dfa_bwd_sim_s,buses_for_parity")
    for r in rows:
        print(f"{r['arch']},{r['t_fwd_s']:.4f},{r['t_bp_bwd_s']:.4f},"
              f"{r['t_dfa_bwd_sim_s']:.4f},{r['buses_for_parity']:.1f}")


if __name__ == "__main__":
    main()
