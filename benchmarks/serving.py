"""Request-level serving study (repro.sim.serving): p50/p99 TTFT and
end-to-end latency, requests/s, bank utilisation and J/request of the
photonic serving plane vs offered load — plus the SLO-constrained
autotuner's pick against the default single-bus chip.

The load sweep measures the saturated capacity of the default single-bus
configuration first and then offers Poisson traffic at fixed fractions of
it, so the latency/throughput shape is stable across model or timing
changes.  The autotune row offers MORE traffic than one bus can clear and
asks ``sim.autotune_serving`` for the cheapest (n_buses, f_s, batch_slots)
that holds p99 end-to-end latency under an SLO within a 4-bus power
budget — the serving dual of ``benchmarks/pipeline_sim.py``'s tuner row.

Emits ``BENCH_serving.json`` (schema repro.bench/v1);
``benchmarks/run.py --bench`` runs it and CI requires the file.
"""

from __future__ import annotations

import argparse

from repro import api, sim
from repro.core import photonics

ARCH = "qwen1.5-0.5b"
N_REQUESTS = 96
PROMPT_LEN = 64
DECODE_LEN = 32
BATCH_SLOTS = 8
PREFILL_CHUNK = 16
LOAD_FRACTIONS = (0.3, 0.6, 0.9)


def _row(report, frac: float) -> dict:
    return {
        "load_fraction": frac,
        "offered_rate": report.offered_rate,
        "requests_per_s": report.requests_per_s,
        "ttft_p50_ms": report.ttft_p50_s * 1e3,
        "ttft_p99_ms": report.ttft_p99_s * 1e3,
        "latency_p50_ms": report.latency_p50_s * 1e3,
        "latency_p99_ms": report.latency_p99_s * 1e3,
        "utilisation": report.utilisation,
        "power_w": report.power_w,
        "j_per_request": report.j_per_request,
    }


def capacity(svc, *, batch_slots: int = BATCH_SLOTS) -> float:
    """Saturated requests/s of one configuration: everything arrives at
    once, so the achieved rate IS the service capacity."""
    burst = [sim.RequestSpec(arrival_s=0.0, prompt_len=PROMPT_LEN,
                             decode_len=DECODE_LEN)] * N_REQUESTS
    rep = sim.simulate_serving(burst, svc, batch_slots=batch_slots,
                               prefill_chunk=PREFILL_CHUNK)
    return rep.requests_per_s


def run(fractions=LOAD_FRACTIONS, n: int = N_REQUESTS) -> dict:
    model = api.build_model(ARCH)
    pcfg = photonics.PhotonicConfig()  # default single-bus chip
    svc = sim.service_model(model, pcfg)
    cap = capacity(svc)

    sweep = []
    for frac in fractions:
        reqs = sim.poisson_requests(frac * cap, n, prompt_len=PROMPT_LEN,
                                    decode_len=DECODE_LEN, seed=17)
        rep = sim.simulate_serving(reqs, svc, batch_slots=BATCH_SLOTS,
                                   prefill_chunk=PREFILL_CHUNK)
        sweep.append(_row(rep, frac))

    # --- SLO autotune: offer more than one bus can clear ---
    overload = sim.poisson_requests(1.5 * cap, n, prompt_len=PROMPT_LEN,
                                    decode_len=DECODE_LEN, seed=23)
    default_rep = sim.simulate_serving(overload, svc, batch_slots=BATCH_SLOTS,
                                       prefill_chunk=PREFILL_CHUNK)
    slo_p99_s = 0.5 * default_rep.latency_p99_s
    budget = sim.bank_power_w(pcfg, n_buses=4)
    tuned = sim.autotune_serving(model, overload, pcfg,
                                 slo_p99_s=slo_p99_s,
                                 power_budget_w=budget,
                                 bus_counts=(1, 2, 4),
                                 prefill_chunk=PREFILL_CHUNK)
    autotune = {
        "n_buses": tuned.n_buses, "f_s_ghz": tuned.f_s / 1e9,
        "batch_slots": tuned.batch_slots, "power_w": tuned.power_w,
        "power_budget_w": budget,
        "slo_p99_ms": slo_p99_s * 1e3,
        "p99_latency_ms": tuned.report.latency_p99_s * 1e3,
        "slo_margin_ms": (slo_p99_s - tuned.report.latency_p99_s) * 1e3,
        "requests_per_s": tuned.report.requests_per_s,
        "default_requests_per_s": default_rep.requests_per_s,
        "default_p99_latency_ms": default_rep.latency_p99_s * 1e3,
        "speedup_vs_default": (tuned.report.requests_per_s
                               / default_rep.requests_per_s),
        "j_per_request": tuned.report.j_per_request,
    }
    return {"arch": ARCH, "capacity_req_per_s": cap, "sweep": sweep,
            "autotune": autotune}


def bench_metrics(results: dict) -> dict:
    metrics = {"capacity_req_per_s": results["capacity_req_per_s"]}
    for r in results["sweep"]:
        p = f"load{int(round(r['load_fraction'] * 100)):02d}_"
        metrics[p + "requests_per_s"] = r["requests_per_s"]
        metrics[p + "ttft_p50_ms"] = r["ttft_p50_ms"]
        metrics[p + "ttft_p99_ms"] = r["ttft_p99_ms"]
        metrics[p + "latency_p50_ms"] = r["latency_p50_ms"]
        metrics[p + "latency_p99_ms"] = r["latency_p99_ms"]
        metrics[p + "j_per_request"] = r["j_per_request"]
        metrics[p + "utilisation"] = r["utilisation"]
    a = results["autotune"]
    metrics.update({
        "auto_n_buses": float(a["n_buses"]),
        "auto_f_s_ghz": a["f_s_ghz"],
        "auto_batch_slots": float(a["batch_slots"]),
        "auto_power_w": a["power_w"],
        "auto_p99_latency_ms": a["p99_latency_ms"],
        "auto_slo_margin_ms": a["slo_margin_ms"],
        "auto_requests_per_s": a["requests_per_s"],
        "auto_speedup_vs_default": a["speedup_vs_default"],
        "auto_j_per_request": a["j_per_request"],
    })
    return metrics


def write_report(results: dict, out_dir: str = ".") -> str:
    from repro.bench import write_bench

    return write_bench("serving", bench_metrics(results),
                       meta={"n_requests": N_REQUESTS,
                             "prompt_len": PROMPT_LEN,
                             "decode_len": DECODE_LEN,
                             "batch_slots": BATCH_SLOTS,
                             "prefill_chunk": PREFILL_CHUNK, **results},
                       out_dir=out_dir)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--bench-dir", default=None, metavar="DIR",
                    help="also write BENCH_serving.json into DIR")
    args = ap.parse_args()
    results = run(n=args.requests)
    print(f"serving: {results['arch']} single-bus capacity "
          f"{results['capacity_req_per_s']:.1f} req/s")
    print("load,req/s,ttft_p50_ms,ttft_p99_ms,lat_p50_ms,lat_p99_ms,J/req")
    for r in results["sweep"]:
        print(f"{r['load_fraction']:.1f},{r['requests_per_s']:.1f},"
              f"{r['ttft_p50_ms']:.2f},{r['ttft_p99_ms']:.2f},"
              f"{r['latency_p50_ms']:.2f},{r['latency_p99_ms']:.2f},"
              f"{r['j_per_request']:.4f}")
    a = results["autotune"]
    print(f"[autotune] n_buses={a['n_buses']} f_s={a['f_s_ghz']:.2f}GHz "
          f"batch_slots={a['batch_slots']} -> p99 {a['p99_latency_ms']:.2f}ms "
          f"<= SLO {a['slo_p99_ms']:.2f}ms (margin {a['slo_margin_ms']:.2f}ms), "
          f"{a['requests_per_s']:.1f} req/s "
          f"({a['speedup_vs_default']:.2f}x vs default 1-bus), "
          f"{a['power_w']:.1f}W <= {a['power_budget_w']:.1f}W")
    if args.bench_dir is not None:
        print(f"[bench] wrote {write_report(results, args.bench_dir)}")


if __name__ == "__main__":
    main()
