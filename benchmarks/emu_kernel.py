"""Fused emu-kernel study — BENCH_emu_kernel.json (ISSUE 7 headline).

Times one training step's worth of DFA feedback projections (every hidden
layer of the qwen1.5-0.5b backward, ``sim.dfa_backward_workload``) through
the device-level emulator twice:

* ``kernel="ref"``   — the unfused chain of ``hardware.channel``: jitted
  einsums + elementwise ops that materialise the full per-(panel, pass)
  partial/noise tensors;
* ``kernel="xla"``   — the fused panel loop of ``kernels.emu_matmul``:
  one kernel invocation per GEMM, partials streamed per bus-cycle (the
  compiled twin of the Pallas TPU kernel, bit-identical noise).

Both run the identical physics (inscription, crosstalk, noise, ADC), so
the steps/s ratio IS the fusion speedup.  The Pallas kernel itself only
*interprets* on CPU (unmeasurably slow, and not the compiled path the
acceptance criterion names), so it is excluded here and covered for
correctness by tests/test_emu_kernel.py.

The measured fused step time then closes the PR 5 follow-on loop: it
feeds ``sim.autotune(digital_s=...)`` so the schedule search overlaps the
*measured* digital-side cost with the photonic timeline and co-optimises
``recalibrate_every`` against the sweep's sim-time cost under a drift
budget.  The tuned schedule lands in the BENCH metrics.

CLI:  PYTHONPATH=src python -m benchmarks.emu_kernel [--steps N] [--t T]
"""

from __future__ import annotations

import argparse
import time

BENCH_NAME = "emu_kernel"


def _percentile(xs, q: float) -> float:
    xs = sorted(xs)
    return xs[min(int(round(q * (len(xs) - 1))), len(xs) - 1)]


def _make_step(workload, cfg, kernel: str):
    """One jitted training-step body: every feedback projection of the
    backward through ``emulated_matmul`` on the requested kernel, summed
    to a scalar so nothing is dead code."""
    import jax
    import jax.numpy as jnp

    from repro.hardware import channel

    def step(a_stack, b_stack, key):
        acc = jnp.float32(0.0)
        for i, _g in enumerate(workload):
            ki = jax.random.fold_in(key, i)
            out = channel.emulated_matmul(a_stack[i], b_stack[i], cfg,
                                          key=ki, kernel=kernel)
            acc = acc + out.sum()
        return acc

    return jax.jit(step)


def _time_step(step, a_stack, b_stack, *, steps: int, warmup: int):
    import jax

    key = jax.random.PRNGKey(7)
    for i in range(warmup):
        step(a_stack, b_stack, jax.random.fold_in(key, i)).block_until_ready()
    times = []
    for i in range(steps):
        k = jax.random.fold_in(key, warmup + i)
        t0 = time.monotonic()
        step(a_stack, b_stack, k).block_until_ready()
        times.append(time.monotonic() - t0)
    return times


def run(t: int = 64, steps: int = 5, warmup: int = 2,
        arch: str = "qwen1.5-0.5b", n_buses: int = 4) -> dict:
    """Measure ref vs fused-xla step time on the arch-shaped backward and
    co-tune the schedule on the measured fused time."""
    import jax
    import jax.numpy as jnp

    from repro import api, sim
    from repro.core import photonics
    from repro.hardware.mrr import MRRConfig

    # the paper's on-chip operating point, multi-bus (the production shape
    # of the emulator: bus-tiled panels, per-pass noise + 8-bit ADC)
    cfg = photonics.PhotonicConfig(noise_std=0.202, n_buses=n_buses,
                                   mrr=MRRConfig(adc_bits=8))
    model = api.build_model(arch)
    workload = sim.dfa_backward_workload(model, t=t)
    macs = sum(g.macs for g in workload)

    key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    # all feedback projections share (t, k) errors and (m, k) banks per
    # layer — stack them so one jitted call runs the whole backward
    a_stack = jnp.stack([jax.random.normal(jax.random.fold_in(ka, i),
                                           (g.t, g.k), jnp.float32)
                         for i, g in enumerate(workload)])
    b_stack = jnp.stack([jax.random.normal(jax.random.fold_in(kb, i),
                                           (g.m, g.k), jnp.float32)
                         for i, g in enumerate(workload)])

    out = {"arch": arch, "t": t, "layers": len(workload), "macs": macs,
           "n_buses": n_buses, "steps": steps,
           "gemm": {"m": workload[0].m, "k": workload[0].k},
           "jax_backend": jax.default_backend()}
    for kernel in ("ref", "xla"):
        times = _time_step(_make_step(workload, cfg, kernel),
                           a_stack, b_stack, steps=steps, warmup=warmup)
        mean = sum(times) / len(times)
        out[kernel] = {"mean_s": mean, "p99_s": _percentile(times, 0.99),
                       "steps_per_s": 1.0 / mean, "macs_per_s": macs / mean}

    # measured-feedback autotuning (PR 5 follow-on): overlap the measured
    # fused digital step with the photonic timeline; co-optimise the
    # recalibration cadence under a drift budget of half the stationary σ
    tuned = sim.autotune(
        workload, cfg, digital_s=out["xla"]["mean_s"],
        recal_candidates=sim.DEFAULT_RECAL_CANDIDATES,
        drift_budget=0.5 * cfg.mrr.drift_sigma, tilings=("panel",))
    out["tuned"] = {
        "wall_clock_s": tuned.wall_clock_s,
        "n_buses": tuned.n_buses,
        "f_s": tuned.f_s,
        "recalibrate_every": tuned.recalibrate_every,
        "drift_resid": tuned.drift_resid,
        "describe": tuned.describe(),
    }
    return out


def bench_metrics(res: dict) -> dict:
    """The gated BENCH metric view (see benchmarks/check_regression.py)."""
    return {
        "unfused_steps_per_s": res["ref"]["steps_per_s"],
        "fused_steps_per_s": res["xla"]["steps_per_s"],
        "unfused_macs_per_s": res["ref"]["macs_per_s"],
        "fused_macs_per_s": res["xla"]["macs_per_s"],
        "unfused_p99_ms": res["ref"]["p99_s"] * 1e3,
        "fused_p99_ms": res["xla"]["p99_s"] * 1e3,
        "fused_speedup": (res["xla"]["steps_per_s"]
                          / res["ref"]["steps_per_s"]),
        "tuned_wall_clock_us": res["tuned"]["wall_clock_s"] * 1e6,
        "tuned_recalibrate_every": float(res["tuned"]["recalibrate_every"]),
        "tuned_drift_resid": res["tuned"]["drift_resid"],
    }


def write_report(res: dict, out_dir: str = ".") -> str:
    from repro.bench import write_bench

    return write_bench(BENCH_NAME, bench_metrics(res),
                       meta={k: res[k] for k in
                             ("arch", "t", "layers", "macs", "n_buses",
                              "steps", "gemm", "jax_backend", "tuned")},
                       out_dir=out_dir)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--t", type=int, default=64)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--n-buses", type=int, default=4)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--out-dir", default=None,
                    help="also write BENCH_emu_kernel.json here")
    args = ap.parse_args()
    res = run(t=args.t, steps=args.steps, warmup=args.warmup,
              arch=args.arch, n_buses=args.n_buses)
    for kernel in ("ref", "xla"):
        r = res[kernel]
        print(f"{kernel}: {r['mean_s'] * 1e3:.1f} ms/step "
              f"({r['steps_per_s']:.2f} steps/s, "
              f"{r['macs_per_s'] / 1e9:.2f} GMAC/s)")
    print(f"fused speedup: {bench_metrics(res)['fused_speedup']:.2f}x")
    print("tuned:", res["tuned"]["describe"])
    if args.out_dir:
        print("wrote", write_report(res, args.out_dir))


if __name__ == "__main__":
    main()
