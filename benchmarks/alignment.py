"""Diagnostics-plane study — BENCH_alignment.json (ISSUE 10 headline).

Three measurements, one report:

1. **Alignment curves** — a short MNIST-MLP DFA fit probed every
   ``probe_every`` steps (``obs.introspect.AlignmentProbe``) on the ideal
   ``ref`` backend and on the ``emu_onchip`` device model.  The per-probe
   DFA-vs-BP cosine (``align_global``) should RISE over the fit — the
   paper's core claim that the network "learns to align" with its fixed
   random feedback — and the emu curve should track ref (noise shifts but
   does not destroy alignment).  Full curves land in the report's meta;
   first/last/gain per variant are gated-visible metrics.

2. **Noise budget** — the emu_onchip run's last attribution row
   (``obs.attribution.noise_budget``): per-source share of the observed
   error power vs the ideal twin, the Σ-sources/total closure, and the
   measured-vs-analytic thermal cross-check.  The run FAILS if the
   closure is off by more than 10 % — the acceptance bar for "the noise
   model is self-consistent" — so CI cannot go green with a noise source
   the attribution cannot account for.

3. **Probe overhead** — the SAME fused-emu qwen1.5-0.5b fit that
   BENCH_obs times, with ``probe_every=100`` vs probe off (interleaved,
   min-of-repeats walls).  ``probe_throughput_ratio`` is gated in
   ``benchmarks/check_regression.py``; the acceptance bar is <= 5 %
   overhead at that cadence.

The emu_onchip run's metrics JSONL (probe rows included) is written next
to the report as ``alignment-metrics.jsonl`` — CI uploads it so every
build archives a loadable example of the diagnostics stream (render with
``python -m repro.obs.summarize``).

CLI:  PYTHONPATH=src python -m benchmarks.alignment [--steps N]
"""

from __future__ import annotations

import argparse
import os
import time

BENCH_NAME = "alignment"

ARCH = "mnist_mlp"
OVERHEAD_ARCH = "qwen1.5-0.5b"

# (variant key, hardware preset, backend)
VARIANTS = (("ref", "ideal", "ref"), ("emu_onchip", "emu_onchip", "emu"))

CLOSURE_TOL = 0.10  # acceptance: sources must sum to total within 10 %


def _mnist_feed(model, batch: int, seed: int):
    from repro.data import mnist, pipeline

    data = mnist.load(seed=seed)
    xtr, ytr = data["train"]
    if xtr.shape[1] != model.in_dim:  # smoke configs shrink in_dim
        xtr = xtr[:, :model.in_dim]
    return pipeline.ArrayClassification(xtr, ytr, batch, seed)


def _probed_fit(preset: str, backend: str, steps: int, probe_every: int,
                metrics_path: str, seed: int = 0) -> None:
    """One probed MNIST fit whose observer rows land in metrics_path."""
    from repro import api, obs

    session = api.build_session(
        arch=ARCH, smoke=True, algo="dfa", hardware=preset, backend=backend,
        probe_every=probe_every, log_every=probe_every, prefetch=0,
        seed=seed)
    pipe = _mnist_feed(session.model, batch=128, seed=seed)
    if os.path.exists(metrics_path):
        os.remove(metrics_path)  # JsonlSink appends; keep one run's rows
    observer = obs.for_session(session, metrics_path=metrics_path)
    session.fit(pipe.batch, total_steps=steps, verbose=False,
                observer=observer)
    observer.close()


def _curve(rows: list[dict], metric: str) -> list[list[float]]:
    return [[float(r["step"]), float(r["metrics"][metric])]
            for r in rows if metric in r.get("metrics", {})]


def alignment_curves(steps: int, probe_every: int, out_dir: str) -> dict:
    """Probed ref + emu_onchip MNIST fits -> per-variant align curves,
    the emu noise-budget table, and the archived diagnostics JSONL."""
    from repro.obs import summarize

    out = {"variants": {}, "paths": {}}
    for key, preset, backend in VARIANTS:
        suffix = "" if key == "emu_onchip" else f"-{key}"
        path = os.path.join(out_dir, f"alignment-metrics{suffix}.jsonl")
        _probed_fit(preset, backend, steps, probe_every, path)
        rows = summarize.read_rows(path)
        curve = _curve(rows, "align_global")
        if not curve:
            raise RuntimeError(f"{key}: no align_global probe rows in {path}")
        vals = [v for _, v in curve]
        layers = summarize.alignment_table(rows)
        out["variants"][key] = {
            "align_curve": curve,
            "align_first": vals[0], "align_last": vals[-1],
            "align_gain": vals[-1] - vals[0],
            "align_layers": {name: s["last"] for name, s in layers.items()},
        }
        out["paths"][key] = path
        if key == "emu_onchip":
            nb = summarize.noise_budget_table(rows)
            if not nb:
                raise RuntimeError(f"emu_onchip: no nb_* rows in {path}")
            if abs(nb["closure"] - 1.0) > CLOSURE_TOL:
                raise RuntimeError(
                    "noise-budget closure %.3f off by more than %.0f%% — "
                    "a noise source the attribution cannot account for"
                    % (nb["closure"], CLOSURE_TOL * 100))
            out["noise_budget"] = nb
    return out


def _overhead_session(probe_every: int | None):
    from repro import api

    return api.build_session(
        arch=OVERHEAD_ARCH, smoke=True, algo="dfa", hardware="emu_offchip",
        backend="emu", emu_kernel="xla", recalibrate_every=16,
        log_every=10**9, probe_every=probe_every)


def _fit_wall_s(session, batch, steps: int) -> float:
    import jax

    t0 = time.monotonic()
    state, _ = session.fit(lambda s: batch, total_steps=steps,
                           verbose=False)
    jax.block_until_ready(state)
    return time.monotonic() - t0


def probe_overhead(steps: int = 400, probe_every: int = 100,
                   warmup: int = 8, repeats: int = 3, batch_size: int = 8,
                   seq_len: int = 32) -> dict:
    """Probe-on vs probe-off fit throughput on the fused emu step (same
    shape BENCH_obs gates).  Interleaved min-of-repeats walls, like
    obs_overhead: the min suppresses scheduler jitter and both modes see
    the same conditions.  The warmup fit compiles the probe's jitted
    side (cached on the trainer, so repeats pay only the probe's run
    cost — exactly what a long training run would see)."""
    from repro.data import tokens

    off = _overhead_session(None)
    on = _overhead_session(probe_every)
    gen = tokens.MarkovTokens(off.model.cfg.vocab_size, seq_len,
                              batch_size, 0)
    batch = gen.batch(0)

    _fit_wall_s(off, batch, warmup)
    _fit_wall_s(on, batch, warmup)  # probe fires at step 0: compiles

    off_walls, on_walls = [], []
    for _ in range(repeats):
        off_walls.append(_fit_wall_s(off, batch, steps))
        on_walls.append(_fit_wall_s(on, batch, steps))
    off_s, on_s = min(off_walls), min(on_walls)
    off_sps, on_sps = steps / off_s, steps / on_s
    return {
        "arch": OVERHEAD_ARCH, "backend": "emu", "emu_kernel": "xla",
        "steps": steps, "probe_every": probe_every, "repeats": repeats,
        "probes_per_fit": len(range(0, steps, probe_every)),
        "off": {"wall_s": off_s, "steps_per_s": off_sps},
        "on": {"wall_s": on_s, "steps_per_s": on_sps},
        "probe_throughput_ratio": on_sps / off_sps,
        "probe_overhead_pct": (1.0 - on_sps / off_sps) * 100.0,
    }


def run(steps: int = 160, probe_every: int = 16,
        overhead_steps: int = 400, overhead_repeats: int = 3,
        out_dir: str = ".") -> dict:
    import jax

    curves = alignment_curves(steps, probe_every, out_dir)
    overhead = probe_overhead(steps=overhead_steps,
                              repeats=overhead_repeats)
    return {
        "arch": ARCH, "steps": steps, "probe_every": probe_every,
        "jax_backend": jax.default_backend(),
        **curves, "overhead": overhead,
    }


def bench_metrics(res: dict) -> dict:
    """The gated BENCH metric view (see benchmarks/check_regression.py)."""
    out = {}
    for key, v in res["variants"].items():
        out[f"{key}_align_first"] = v["align_first"]
        out[f"{key}_align_last"] = v["align_last"]
        out[f"{key}_align_gain"] = v["align_gain"]
    nb = res["noise_budget"]
    out["nb_closure"] = nb["closure"]
    out["nb_thermal_share"] = nb["sources"]["thermal"]["share"]
    out["nb_thermal_vs_analytic"] = nb["thermal_vs_analytic"]
    ov = res["overhead"]
    out["probe_throughput_ratio"] = ov["probe_throughput_ratio"]
    out["probe_on_steps_per_s"] = ov["on"]["steps_per_s"]
    return out


def write_report(res: dict, out_dir: str = ".") -> str:
    from repro.bench import write_bench

    meta = {k: res[k] for k in ("arch", "steps", "probe_every",
                                "jax_backend", "variants", "noise_budget",
                                "overhead", "paths")}
    return write_bench(BENCH_NAME, bench_metrics(res), meta=meta,
                       out_dir=out_dir)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=160)
    ap.add_argument("--probe-every", type=int, default=16)
    ap.add_argument("--overhead-steps", type=int, default=400)
    ap.add_argument("--overhead-repeats", type=int, default=3)
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_alignment.json + JSONL files")
    args = ap.parse_args()
    res = run(steps=args.steps, probe_every=args.probe_every,
              overhead_steps=args.overhead_steps,
              overhead_repeats=args.overhead_repeats, out_dir=args.out_dir)
    for key, v in res["variants"].items():
        print(f"{key}: align {v['align_first']:.4f} -> {v['align_last']:.4f}"
              f" ({v['align_gain']:+.4f} over {res['steps']} steps)")
    nb = res["noise_budget"]
    shares = ", ".join(
        f"{name} {s['share']:.1%}" for name, s in sorted(
            nb["sources"].items(), key=lambda kv: -kv[1]["var"]))
    print(f"noise budget (emu_onchip): {shares}; "
          f"closure {nb['closure']:.3f}, "
          f"thermal vs analytic {nb['thermal_vs_analytic']:.3f}")
    ov = res["overhead"]
    print(f"probe overhead ({ov['arch']}, probe_every={ov['probe_every']}): "
          f"off {ov['off']['steps_per_s']:.2f} steps/s | "
          f"on {ov['on']['steps_per_s']:.2f} steps/s | "
          f"ratio {ov['probe_throughput_ratio']:.4f} "
          f"({ov['probe_overhead_pct']:.2f}%)")
    print("wrote", write_report(res, args.out_dir))


if __name__ == "__main__":
    main()
