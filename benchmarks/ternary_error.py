"""Paper ref [48] (Launay et al., "Hardware Beyond Backpropagation"):
competitive DFA training with the error TERNARISED to {-1, 0, +1} — the
extreme gradient-compression point.  This is also the distributed knob:
a ternary error broadcast is 16× smaller than bf16.

Compares test accuracy for full-precision / int8 / ternary error under the
off-chip-BPD photonic noise."""

from __future__ import annotations

from repro import api
from repro.data import mnist, pipeline
from repro.models.mlp import MLPClassifier
from repro.train import SGDM


def run(train_n=8192, test_n=2048, steps=512, hidden=(256, 256), seed=0):
    data = mnist.load((train_n, test_n), seed=seed)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    rows = []
    for mode in ("none", "int8", "ternary"):
        pipe = pipeline.ArrayClassification(xtr, ytr, batch_size=64, seed=seed)
        session = api.build_session(
            arch=MLPClassifier(hidden=hidden), algo="dfa",
            hardware="offchip_bpd", error_compress=mode,
            optimizer=SGDM(lr=0.01, momentum=0.9), seed=seed, log_every=10**9)
        state, _ = session.fit(pipe.batch, total_steps=steps, verbose=False)
        ev = session.evaluate(state, pipe.eval_batches(xte, yte, 256))
        bytes_per_err = {"none": 4.0, "int8": 1.0, "ternary": 0.25}[mode]
        rows.append({"error_compress": mode,
                     "test_accuracy": 100 * ev["accuracy"],
                     "broadcast_bytes_per_element": bytes_per_err})
    return rows


def main():
    print("ternary_error: mode,test_acc_%,broadcast_B_per_elem")
    for r in run():
        print(f"{r['error_compress']},{r['test_accuracy']:.2f},"
              f"{r['broadcast_bytes_per_element']}")


if __name__ == "__main__":
    main()
