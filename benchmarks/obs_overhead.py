"""Observability overhead study — BENCH_obs.json (ISSUE 8 headline).

Times the SAME fused-emu training fit (the qwen1.5-0.5b smoke arch —
the model shape BENCH_emu_kernel gates — on the device-level ``emu``
backend with the fused ``xla`` kernel) twice:

* observer **off** — ``fit(observer=None)``: the null-observer fast path
  (shared no-op context manager, one batched ``jax.device_get`` per
  logging interval);
* observer **on**  — a fully-wired ``obs.Observer``: per-step trace
  spans, recalibration instants, hardware monitor (drift vs the OU
  prediction, effective bits, dead rings), JSONL metrics sink and a
  Chrome trace written at the end.

``log_every=1`` drains metrics EVERY step — the worst case for the
observer — so the measured ratio upper-bounds any real logging cadence.
The acceptance bar is overhead <= 2% (throughput_ratio >= 0.98); the
perf gate (``benchmarks/check_regression.py``) holds ``throughput_ratio``
with a small wall-clock-jitter tolerance.  The run's trace and metrics
files land next to the BENCH json (``obs-trace.json``,
``obs-metrics.jsonl``) so CI archives a loadable example of both.

CLI:  PYTHONPATH=src python -m benchmarks.obs_overhead [--steps N]
"""

from __future__ import annotations

import argparse
import os
import time

BENCH_NAME = "obs"


ARCH = "qwen1.5-0.5b"


def _build_session(log_every: int):
    from repro import api

    return api.build_session(
        arch=ARCH, smoke=True, algo="dfa", hardware="emu_offchip",
        backend="emu", emu_kernel="xla", recalibrate_every=16,
        log_every=log_every)


def _fit_wall_s(session, batch, steps: int, observer) -> float:
    """Wall time of one ``fit`` over ``steps`` steps (result synced)."""
    import jax

    t0 = time.monotonic()
    state, _ = session.fit(lambda s: batch, total_steps=steps,
                           verbose=False, observer=observer)
    jax.block_until_ready(state)
    return time.monotonic() - t0


def probe_off_parity(steps: int = 4, batch_size: int = 8,
                     seq_len: int = 32) -> bool:
    """Acceptance check for the diagnostics plane: with ``probe_every``
    left at its default (None), an observed fit must produce BIT-IDENTICAL
    training state to an unobserved one — observability that perturbs
    training is a bug, not overhead."""
    import jax
    import numpy as np

    from repro import obs
    from repro.data import tokens

    def final_state(observed: bool):
        session = _build_session(10**9)
        gen = tokens.MarkovTokens(session.model.cfg.vocab_size, seq_len,
                                  batch_size, 0)
        batch = gen.batch(0)
        observer = obs.for_session(session) if observed else None
        state, _ = session.fit(lambda s: batch, total_steps=steps,
                               verbose=False, observer=observer)
        return jax.device_get(state)

    plain, observed = final_state(False), final_state(True)
    return all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(plain),
                        jax.tree_util.tree_leaves(observed)))


def run(steps: int = 96, warmup: int = 8, batch_size: int = 8,
        seq_len: int = 32, log_every: int = 1, repeats: int = 5,
        out_dir: str = ".") -> dict:
    """Measure observer-off vs observer-on fit throughput on the fused emu
    step.  Interleaves the two modes ``repeats`` times and takes the best
    wall per mode (min suppresses one-off scheduler jitter on shared
    runners; both modes see the same conditions).  ``steps`` must be large
    enough that the fit-entry fixed cost (state init, feed setup) washes
    out — at 96 steps the ratio is step-cost dominated.  Per-fit jitter
    on a loaded host is a few percent, larger than the observer's real
    per-step cost (~tens of µs on an ~10 ms step), so the min over
    ``repeats`` is what makes the ratio meaningful."""
    import jax

    from repro import obs
    from repro.data import tokens

    session = _build_session(log_every)
    gen = tokens.MarkovTokens(session.model.cfg.vocab_size, seq_len,
                              batch_size, 0)
    batch = gen.batch(0)

    # compile + warm both code paths before any measurement
    _fit_wall_s(session, batch, warmup, None)
    _fit_wall_s(session, batch, warmup, obs.for_session(session))

    off_walls, on_walls = [], []
    for _ in range(repeats):
        off_walls.append(_fit_wall_s(session, batch, steps, None))
        on_walls.append(_fit_wall_s(session, batch, steps,
                                    obs.for_session(session)))
    off_s, on_s = min(off_walls), min(on_walls)

    # one final observed run keeps its artifacts for inspection/CI upload
    trace_path = os.path.join(out_dir, "obs-trace.json")
    metrics_path = os.path.join(out_dir, "obs-metrics.jsonl")
    if os.path.exists(metrics_path):
        os.remove(metrics_path)  # JsonlSink appends; keep one run's rows
    observer = obs.for_session(session, metrics_path=metrics_path,
                               trace_path=trace_path)
    _fit_wall_s(session, batch, steps, observer)
    observer.close()

    off_sps, on_sps = steps / off_s, steps / on_s
    ratio = on_sps / off_sps
    with open(metrics_path) as f:
        n_rows = sum(1 for line in f if line.strip())
    parity = probe_off_parity(batch_size=batch_size, seq_len=seq_len)
    return {
        "arch": ARCH, "backend": "emu", "emu_kernel": "xla",
        "steps": steps, "repeats": repeats, "log_every": log_every,
        "batch": batch_size, "seq_len": seq_len,
        "jax_backend": jax.default_backend(),
        "off": {"wall_s": off_s, "steps_per_s": off_sps},
        "on": {"wall_s": on_s, "steps_per_s": on_sps},
        "throughput_ratio": ratio,
        "overhead_pct": (1.0 - ratio) * 100.0,
        "probe_off_parity": parity,
        "trace_events": len(observer.trace.events),
        "metric_rows": n_rows,
        "alerts": len(observer.alerts),
        "trace_path": trace_path,
        "metrics_path": metrics_path,
    }


def bench_metrics(res: dict) -> dict:
    """The gated BENCH metric view (see benchmarks/check_regression.py)."""
    return {
        "off_steps_per_s": res["off"]["steps_per_s"],
        "on_steps_per_s": res["on"]["steps_per_s"],
        "throughput_ratio": res["throughput_ratio"],
        "overhead_pct": res["overhead_pct"],
        # 1.0 iff an observed fit (probe off) matches an unobserved one
        # bitwise — failure here means observability perturbed training
        "probe_off_parity": float(res["probe_off_parity"]),
        "trace_events": float(res["trace_events"]),
        "metric_rows": float(res["metric_rows"]),
    }


def write_report(res: dict, out_dir: str = ".") -> str:
    from repro.bench import write_bench

    return write_bench(BENCH_NAME, bench_metrics(res),
                       meta={k: res[k] for k in
                             ("arch", "backend", "emu_kernel", "steps",
                              "repeats", "log_every", "batch", "seq_len",
                              "jax_backend", "alerts", "trace_path",
                              "metrics_path")},
                       out_dir=out_dir)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=96)
    ap.add_argument("--warmup", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_obs.json + trace/metrics files")
    args = ap.parse_args()
    res = run(steps=args.steps, warmup=args.warmup, repeats=args.repeats,
              log_every=args.log_every, out_dir=args.out_dir)
    print(f"observer off: {res['off']['steps_per_s']:.2f} steps/s | "
          f"on: {res['on']['steps_per_s']:.2f} steps/s | "
          f"ratio {res['throughput_ratio']:.4f} "
          f"(overhead {res['overhead_pct']:.2f}%)")
    print(f"trace: {res['trace_events']} events -> {res['trace_path']}; "
          f"metrics: {res['metric_rows']} rows -> {res['metrics_path']}")
    print(f"probe-off parity (observed fit bitwise == unobserved): "
          f"{res['probe_off_parity']}")
    print("wrote", write_report(res, args.out_dir))


if __name__ == "__main__":
    main()
