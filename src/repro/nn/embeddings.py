"""Token embeddings, unembedding, and rotary position embeddings."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import initializers
from repro.nn.module import Module


@dataclasses.dataclass(frozen=True)
class Embedding(Module):
    vocab_size: int
    dim: int
    dtype: jnp.dtype = jnp.float32
    init_std: float = 0.02

    def init(self, key):
        return {
            "table": initializers.normal(self.init_std)(
                key, (self.vocab_size, self.dim), self.dtype
            )
        }

    def __call__(self, params, token_ids):
        return jnp.take(params["table"], token_ids, axis=0)

    def attend(self, params, x):
        """Unembed (tied weights): x @ tableᵀ -> logits."""
        return x @ params["table"].T


def rotary_angles(positions, head_dim: int, theta: float = 10000.0):
    """Return (cos, sin) of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2).

    Rotates pairs (x[..., :half], x[..., half:]) — the "half-split" (GPT-NeoX /
    llama) convention used by every assigned LM arch.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast cos/sin over the heads axis
    c = cos[..., None, :]
    s = sin[..., None, :]
    rot1 = x1 * c - x2 * s
    rot2 = x2 * c + x1 * s
    return jnp.concatenate([rot1, rot2], axis=-1).astype(x.dtype)


def positions_from_offset(batch: int, seq: int, offset):
    """(batch, seq) absolute positions starting at ``offset`` (decode step)."""
    return jnp.arange(seq)[None, :] + jnp.asarray(offset).reshape(-1, 1)
