"""Normalisation layers (computed in f32, cast back)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.nn.module import Module


@dataclasses.dataclass(frozen=True)
class RMSNorm(Module):
    dim: int
    eps: float = 1e-6
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), self.dtype)}

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * (var + self.eps) ** -0.5
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class LayerNorm(Module):
    dim: int
    eps: float = 1e-5
    use_bias: bool = True
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        del key
        p = {"scale": jnp.ones((self.dim,), self.dtype)}
        if self.use_bias:
            p["bias"] = jnp.zeros((self.dim,), self.dtype)
        return p

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * (var + self.eps) ** -0.5
        y = y * params["scale"].astype(jnp.float32)
        if self.use_bias:
            y = y + params["bias"].astype(jnp.float32)
        return y.astype(x.dtype)


def rms_normalize(x, eps: float = 1e-6):
    """Parameter-free RMS normalisation (qk_norm building block)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * (var + eps) ** -0.5).astype(x.dtype)
