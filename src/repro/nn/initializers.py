"""Parameter initializers (pure functions of (key, shape, dtype))."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def normal(stddev: float = 0.02):
    def init(key, shape, dtype=jnp.float32):
        return (jax.random.normal(key, shape) * stddev).astype(dtype)

    return init


def lecun_normal(in_axis: int = -2):
    """Variance-scaling (fan_in) — the default for projection weights."""

    def init(key, shape, dtype=jnp.float32):
        fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
        std = 1.0 / np.sqrt(max(1, fan_in))
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return init


def glorot_normal():
    def init(key, shape, dtype=jnp.float32):
        fan_in, fan_out = shape[-2], shape[-1]
        std = np.sqrt(2.0 / (fan_in + fan_out))
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return init


def uniform_sym(scale: float):
    def init(key, shape, dtype=jnp.float32):
        return jax.random.uniform(key, shape, minval=-scale, maxval=scale).astype(dtype)

    return init
