"""Linear / MLP layers."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.photonics import forward_matmul
from repro.nn import activations, initializers
from repro.nn.module import Module, named_key


@dataclasses.dataclass(frozen=True)
class Linear(Module):
    in_dim: int
    out_dim: int
    use_bias: bool = False
    dtype: jnp.dtype = jnp.float32
    init_std: float | None = None  # None -> fan_in scaling

    def init(self, key):
        if self.init_std is None:
            w = initializers.lecun_normal()(
                named_key(key, "w"), (self.in_dim, self.out_dim), self.dtype)
        else:
            w = initializers.normal(self.init_std)(
                named_key(key, "w"), (self.in_dim, self.out_dim), self.dtype)
        p = {"w": w}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_dim,), self.dtype)
        return p

    def __call__(self, params, x):
        y = forward_matmul(x, params["w"])
        if self.use_bias:
            y = y + params["b"]
        return y


@dataclasses.dataclass(frozen=True)
class DenseBlock(Module):
    """linear -> activation, the paper's hidden-layer unit.

    Block-granular DFA applied to this block reproduces the paper's exact
    DFA update: injecting delta = B e at the block *output* and local-vjp'ing
    yields  grad_W = (B e ⊙ g'(a)) h_inᵀ  — Eq. 1 verbatim — because the
    local vjp through g contributes the ⊙ g'(a) Hadamard.
    """

    in_dim: int
    out_dim: int
    activation: str = "relu"
    use_bias: bool = True
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        lin = Linear(self.in_dim, self.out_dim, self.use_bias, self.dtype)
        return lin.init(key)

    def preact(self, params, x):
        return Linear(self.in_dim, self.out_dim, self.use_bias, self.dtype)(params, x)

    def __call__(self, params, x):
        g, _ = activations.get(self.activation)
        return g(self.preact(params, x))


@dataclasses.dataclass(frozen=True)
class GatedMLP(Module):
    """SwiGLU-style gated FFN: down( act(gate(x)) * up(x) ).

    Used by every assigned LM (llama/qwen/granite/minicpm lineage).
    """

    d_model: int
    d_ff: int
    activation: str = "silu"
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        return {
            "gate": Linear(self.d_model, self.d_ff, dtype=self.dtype).init(named_key(key, "gate")),
            "up": Linear(self.d_model, self.d_ff, dtype=self.dtype).init(named_key(key, "up")),
            "down": Linear(self.d_ff, self.d_model, dtype=self.dtype).init(named_key(key, "down")),
        }

    def __call__(self, params, x):
        g, _ = activations.get(self.activation)
        gate = g(forward_matmul(x, params["gate"]["w"]))
        up = forward_matmul(x, params["up"]["w"])
        return forward_matmul(gate * up, params["down"]["w"])


@dataclasses.dataclass(frozen=True)
class MLP(Module):
    """Plain 2-layer MLP (whisper-style FFN)."""

    d_model: int
    d_ff: int
    activation: str = "gelu"
    use_bias: bool = True
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        return {
            "fc1": Linear(self.d_model, self.d_ff, self.use_bias,
                          self.dtype).init(named_key(key, "fc1")),
            "fc2": Linear(self.d_ff, self.d_model, self.use_bias,
                          self.dtype).init(named_key(key, "fc2")),
        }

    def __call__(self, params, x):
        g, _ = activations.get(self.activation)
        h = Linear(self.d_model, self.d_ff, self.use_bias, self.dtype)(params["fc1"], x)
        return Linear(self.d_ff, self.d_model, self.use_bias, self.dtype)(params["fc2"], g(h))
