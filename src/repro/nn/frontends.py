"""Modality frontends — STUBS per the assignment.

``[audio]`` (whisper) and ``[vlm]`` (internvl) cells specify the transformer
backbone only; ``input_specs()`` supplies *precomputed* frame / patch
embeddings already at backbone width.  The stubs below add the minimal
learned glue (positional embedding + layernorm for audio frames; a projection
for vision patches) so smoke tests exercise a real parameter path, but no
conv/ViT tower is built (documented in DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.nn import initializers
from repro.nn.linear import Linear
from repro.nn.module import Module, named_key
from repro.nn.norms import LayerNorm


@dataclasses.dataclass(frozen=True)
class AudioFrontendStub(Module):
    """Whisper conv frontend replaced by: precomputed frames (B, T, d) →
    + learned positional embedding → layernorm."""

    d_model: int
    max_frames: int = 1500
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        return {
            "pos": initializers.normal(0.01)(
                named_key(key, "pos"), (self.max_frames, self.d_model), self.dtype),
            "ln": LayerNorm(self.d_model, dtype=self.dtype).init(named_key(key, "ln")),
        }

    def __call__(self, params, frames):
        t = frames.shape[1]
        x = frames + params["pos"][:t]
        return LayerNorm(self.d_model, dtype=self.dtype)(params["ln"], x)


@dataclasses.dataclass(frozen=True)
class VisionFrontendStub(Module):
    """InternViT replaced by: precomputed patch embeds (B, P, d_vis) →
    linear projection to LM width (the mlp1 connector in InternVL)."""

    d_vision: int
    d_model: int
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        return {
            "proj": Linear(self.d_vision, self.d_model, use_bias=True,
                           dtype=self.dtype).init(named_key(key, "proj")),
            "ln": LayerNorm(self.d_vision, dtype=self.dtype).init(named_key(key, "ln")),
        }

    def __call__(self, params, patches):
        x = LayerNorm(self.d_vision, dtype=self.dtype)(params["ln"], patches)
        return x @ params["proj"]["w"] + params["proj"]["b"]
