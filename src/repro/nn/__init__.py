from repro.nn import activations, initializers
from repro.nn.attention import Attention, CrossAttention, MLAttention
from repro.nn.embeddings import Embedding, apply_rotary, rotary_angles
from repro.nn.frontends import AudioFrontendStub, VisionFrontendStub
from repro.nn.linear import DenseBlock, GatedMLP, Linear, MLP
from repro.nn.module import Module, Params, layer_slice, named_key, stack_init
from repro.nn.moe import MoE
from repro.nn.norms import LayerNorm, RMSNorm, rms_normalize
from repro.nn.rglru import RGLRUBlock
from repro.nn.ssm import Mamba2Block

__all__ = [
    "activations", "initializers",
    "Attention", "CrossAttention", "MLAttention",
    "Embedding", "apply_rotary", "rotary_angles",
    "AudioFrontendStub", "VisionFrontendStub",
    "DenseBlock", "GatedMLP", "Linear", "MLP",
    "Module", "Params", "layer_slice", "named_key", "stack_init",
    "MoE", "LayerNorm", "RMSNorm", "rms_normalize",
    "RGLRUBlock", "Mamba2Block",
]
