"""Activation functions and their derivatives.

The DFA gradient (paper Eq. 1) needs g'(a) explicitly — on the photonic chip
it is the per-row TIA gain; here it is the Hadamard mask handed to the fused
``dfa_gradient`` kernel.  For ReLU the mask is binary, exactly as the paper
notes ("the elements in the vector g'(a) are binary (0 or 1) when the ReLU
function is used").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def relu(x):
    return jnp.maximum(x, 0)


def relu_deriv(a):
    return (a > 0).astype(a.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def gelu_deriv(a):
    # d/da of tanh-approximate gelu
    c = jnp.sqrt(2.0 / jnp.pi).astype(a.dtype)
    u = c * (a + 0.044715 * a**3)
    t = jnp.tanh(u)
    du = c * (1 + 3 * 0.044715 * a**2)
    return 0.5 * (1 + t) + 0.5 * a * (1 - t**2) * du


def silu(x):
    return x * jax.nn.sigmoid(x)


def silu_deriv(a):
    s = jax.nn.sigmoid(a)
    return s * (1 + a * (1 - s))


def tanh(x):
    return jnp.tanh(x)


def tanh_deriv(a):
    return 1 - jnp.tanh(a) ** 2


def identity(x):
    return x


def identity_deriv(a):
    return jnp.ones_like(a)


ACTIVATIONS = {
    "relu": (relu, relu_deriv),
    "gelu": (gelu, gelu_deriv),
    "silu": (silu, silu_deriv),
    "tanh": (tanh, tanh_deriv),
    "identity": (identity, identity_deriv),
}


def get(name: str):
    """Return (g, g') for a named activation."""
    return ACTIVATIONS[name]
