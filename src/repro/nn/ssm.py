"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Implements the chunked SSD algorithm: within chunks the quadratic (attention-
like) form, across chunks a linear recurrence over per-chunk states.  The
recurrence is a ``lax.scan`` over n_chunks steps (seq/chunk), so training cost
is O(S·L·N) and decode is a constant-size state update (no KV cache) — this is
what makes the ``long_500k`` cell feasible for this arch.

Scalar-per-head decay A (as in Mamba-2), grouped B/C (n_groups=1 here),
depthwise causal conv on (x‖B‖C), gated RMSNorm output.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.photonics import forward_matmul
from repro.nn.linear import Linear
from repro.nn.module import Module, named_key


def _softplus(x):
    return jax.nn.softplus(x)


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B, S, C), w: (K, C), b: (C,)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


@dataclasses.dataclass(frozen=True)
class Mamba2Block(Module):
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128
    # split_proj: emit z / xBC / dt via three shard-aligned projections
    # instead of one fused in_proj whose output dim (2·d_inner + 2·G·N + H)
    # is not divisible by the model axis — the fused layout forces
    # boundary-crossing splits (collective-permutes) on every layer (§Perf M1)
    split_proj: bool = False
    dtype: jnp.dtype = jnp.float32

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    def init(self, key):
        h = self.n_heads
        if self.split_proj:
            p = {
                "in_z": Linear(self.d_model, self.d_inner,
                               dtype=self.dtype).init(named_key(key, "in_z")),
                "in_xbc": Linear(self.d_model, self.conv_dim,
                                 dtype=self.dtype).init(named_key(key, "in_xbc")),
                "in_dt": Linear(self.d_model, h, dtype=self.dtype).init(named_key(key, "in_dt")),
            }
        else:
            d_in_proj = 2 * self.d_inner + 2 * self.n_groups * self.d_state + h
            p = {"in_proj": Linear(self.d_model, d_in_proj,
                                   dtype=self.dtype).init(named_key(key, "in_proj"))}
        p.update({
            "conv_w": (0.1 * jax.random.normal(
                named_key(key, "conv_w"),
                (self.conv_width, self.conv_dim))).astype(self.dtype),
            "conv_b": jnp.zeros((self.conv_dim,), self.dtype),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(self.dtype),
            "D": jnp.ones((h,), self.dtype),
            "dt_bias": jnp.zeros((h,), self.dtype),
            "norm_scale": jnp.ones((self.d_inner,), self.dtype),
            "out_proj": Linear(self.d_inner, self.d_model,
                               dtype=self.dtype).init(named_key(key, "out_proj")),
        })
        return p

    def _project_in(self, params, u):
        """-> (z, xBC_preconv, dt_raw)."""
        if self.split_proj:
            return (forward_matmul(u, params["in_z"]["w"]),
                    forward_matmul(u, params["in_xbc"]["w"]),
                    forward_matmul(u, params["in_dt"]["w"]))
        proj = forward_matmul(u, params["in_proj"]["w"])
        z, xBC, dt_raw = jnp.split(
            proj, [self.d_inner, self.d_inner + self.conv_dim], axis=-1)
        return z, xBC, dt_raw

    def _split(self, params, u):
        """in_proj + conv → (z, x, B, C, dt). u: (B,S,d_model)."""
        h = self.n_heads
        gn = self.n_groups * self.d_state
        z, xBC, dt_raw = self._project_in(params, u)
        xBC = jax.nn.silu(causal_conv1d(xBC, params["conv_w"], params["conv_b"]))
        x, bmat, cmat = jnp.split(xBC, [self.d_inner, self.d_inner + gn], axis=-1)
        dt = _softplus(dt_raw.astype(jnp.float32)
                       + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
        return z, x, bmat, cmat, dt

    def __call__(self, params, u):
        """u: (B, S, d_model) -> (B, S, d_model). S must be divisible by chunk
        (models pad/choose shapes accordingly)."""
        bsz, seq, _ = u.shape
        hn, pd, nst = self.n_heads, self.head_dim, self.d_state
        z, x, bmat, cmat, dt = self._split(params, u)
        x = x.reshape(bsz, seq, hn, pd)
        bmat = bmat.reshape(bsz, seq, self.n_groups, nst)
        cmat = cmat.reshape(bsz, seq, self.n_groups, nst)
        # broadcast groups → heads
        rep = hn // self.n_groups
        bh = jnp.repeat(bmat, rep, axis=2)  # (B,S,H,N)
        ch = jnp.repeat(cmat, rep, axis=2)
        a_neg = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,) negative
        l = dt * a_neg  # (B,S,H) log-decay per step (<0)
        dtx = (dt[..., None] * x.astype(jnp.float32))  # (B,S,H,P)

        q = self.chunk if seq % self.chunk == 0 else seq
        nc = seq // q
        rs = lambda t: t.reshape((bsz, nc, q) + t.shape[2:])
        lc, dtxc, bc, cc = rs(l), rs(dtx), rs(bh.astype(jnp.float32)), rs(ch.astype(jnp.float32))
        cum = jnp.cumsum(lc, axis=2)  # (B,nc,q,H) cumulative log decay
        # --- intra-chunk (quadratic within chunk) ---
        # decay(t,i) = exp(cum_t - cum_i) for i<=t
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,q_t,q_i,H)
        tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
        # exp only at masked-safe values: above the diagonal diff > 0 can
        # overflow to inf, and where(tri, exp(diff), 0)'s vjp would then be
        # 0 * inf = NaN for every upstream parameter
        dec = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
        scores = jnp.einsum("bcthn,bcihn->bctih", cc, bc) * dec.transpose(0, 1, 2, 3, 4)
        y_intra = jnp.einsum("bctih,bcihp->bcthp", scores, dtxc)
        # --- chunk states ---
        last = cum[:, :, -1:, :]  # (B,nc,1,H)
        w_state = jnp.exp(last - cum)  # decay from position i to chunk end
        s_chunk = jnp.einsum("bcihn,bcihp->bchnp", bc * w_state[..., None], dtxc)
        chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,nc,H)

        def scan_fn(s_prev, xs):
            s_c, dec_c = xs  # (B,H,N,P), (B,H)
            s_new = s_prev * dec_c[:, :, None, None] + s_c
            return s_new, s_prev

        s0 = jnp.zeros((bsz, hn, nst, pd), jnp.float32)
        _, s_before = jax.lax.scan(
            scan_fn, s0,
            (s_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
        s_before = s_before.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state at chunk start
        # --- inter-chunk contribution ---
        y_inter = jnp.einsum("bcthn,bchnp->bcthp", cc * jnp.exp(cum)[..., None], s_before)
        y = (y_intra + y_inter).reshape(bsz, seq, hn, pd)
        y = y + params["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
        y = y.reshape(bsz, seq, self.d_inner)
        # gated RMSNorm then out_proj
        y = y * jax.nn.silu(z.astype(jnp.float32))
        var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
        y = y * (var + 1e-6) ** -0.5 * params["norm_scale"].astype(jnp.float32)
        return forward_matmul(y.astype(u.dtype), params["out_proj"]["w"])

    # ---- decode -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int = 0, dtype=None):
        del max_len
        dt = dtype or self.dtype
        return {
            "ssm": jnp.zeros((batch, self.n_heads, self.d_state, self.head_dim), jnp.float32),
            "conv": jnp.zeros((batch, self.conv_width - 1, self.conv_dim), dt),
        }

    def decode(self, params, u, cache, cache_len):
        """u: (B, 1, d_model). O(1) state update."""
        del cache_len
        bsz = u.shape[0]
        hn, pd, nst = self.n_heads, self.head_dim, self.d_state
        gn = self.n_groups * self.d_state
        z, xBC_new, dt_raw = self._project_in(params, u)
        # conv over ring of last (k-1) inputs + current
        win = jnp.concatenate([cache["conv"], xBC_new], axis=1)  # (B, k, conv_dim)
        xBC = jnp.einsum("bkc,kc->bc", win, params["conv_w"]) + params["conv_b"]
        xBC = jax.nn.silu(xBC)[:, None, :]
        x, bmat, cmat = jnp.split(xBC, [self.d_inner, self.d_inner + gn], axis=-1)
        dt = _softplus(dt_raw.astype(jnp.float32)
                       + params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
        x = x.reshape(bsz, hn, pd).astype(jnp.float32)
        rep = hn // self.n_groups
        bh = jnp.repeat(bmat.reshape(bsz, self.n_groups, nst), rep, axis=1).astype(jnp.float32)
        chh = jnp.repeat(cmat.reshape(bsz, self.n_groups, nst), rep, axis=1).astype(jnp.float32)
        a_neg = -jnp.exp(params["A_log"].astype(jnp.float32))
        dec = jnp.exp(dt * a_neg)  # (B,H)
        s_new = (cache["ssm"] * dec[:, :, None, None]
                 + jnp.einsum("bhn,bhp->bhnp", bh * dt[..., None], x))
        y = jnp.einsum("bhn,bhnp->bhp", chh, s_new)
        y = y + params["D"].astype(jnp.float32)[None, :, None] * x
        y = y.reshape(bsz, 1, self.d_inner)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
        y = y * (var + 1e-6) ** -0.5 * params["norm_scale"].astype(jnp.float32)
        y = forward_matmul(y.astype(u.dtype), params["out_proj"]["w"])
        new_cache = {"ssm": s_new, "conv": win[:, 1:, :].astype(cache["conv"].dtype)}
        return y, new_cache
