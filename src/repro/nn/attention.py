"""Attention layers: MHA/GQA (+bias, +qk_norm, +local window), MLA, cross.

Two execution regimes:

* ``flash_attention`` — chunked online-softmax attention for training /
  prefill.  Q is processed in static chunks (Python loop ⇒ static bounds);
  for each Q chunk only the causally-reachable / in-window K chunks are
  scanned (``lax.scan``), so causal compute is the exact triangle (no 2×
  overcount in the roofline) and peak memory is O(chunk²), never O(S²).

* ``decode_attention`` — single-query attention against a KV cache with a
  length mask.  The sequence-sharded (model-axis) variant with logsumexp
  combine lives in ``repro/serve/decode.py``; this is the per-shard core.

GQA broadcasts KV heads over query groups.  MLA (MiniCPM3 / DeepSeek-style)
keeps a compressed latent cache and uses the absorbed form at decode time.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.photonics import forward_matmul
from repro.nn.embeddings import apply_rotary, rotary_angles
from repro.nn.linear import Linear
from repro.nn.module import Module, named_key
from repro.nn.norms import rms_normalize

NEG_INF = -1e30


def _gqa_expand(kv, n_heads: int):
    """(B, S, KVH, D) -> (B, S, H, D) by repeating each kv head."""
    b, s, kvh, d = kv.shape
    if kvh == n_heads:
        return kv
    rep = n_heads // kvh
    return jnp.repeat(kv, rep, axis=2)


def reference_attention(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                        scale=None, logit_softcap=None):
    """O(S²) oracle used by tests.  q:(B,Sq,H,D) k,v:(B,Skv,KVH,D)."""
    b, sq, h, d = q.shape
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if logit_softcap:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    mask = jnp.ones((b, sq, kv_pos.shape[1]), bool)
    if causal:
        mask &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= q_pos[:, :, None] - kv_pos[:, None, :] < window
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _attend_chunk(q, k, v, q_pos, k_pos, scale, causal, window, logit_softcap,
                  acc, m_prev, l_prev):
    """Online-softmax update for one (q-chunk, k-chunk) tile. All f32."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    if logit_softcap:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    mask = jnp.ones(scores.shape[-2:], bool)[None]  # (1, Sq, Sk)
    mask = jnp.broadcast_to(mask, (q.shape[0],) + mask.shape[1:])
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= q_pos[:, :, None] - k_pos[:, None, :] < window
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    m_cur = jnp.max(scores, axis=-1)  # (B, H, Sq)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == NEG_INF) against NaN
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(scores - safe_m[..., None])
    p = jnp.where(mask[:, None, :, :], p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - safe_m))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc = acc * jnp.transpose(alpha, (0, 2, 1))[..., None]
    acc = acc + jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return acc, m_new, l_new


def flash_attention(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                    scale=None, logit_softcap=None,
                    q_chunk: int = 2048, k_chunk: int = 1024):
    """Chunked online-softmax attention.  Shapes as reference_attention.

    Static per-q-chunk K ranges: for causal attention q-chunk j only scans
    K chunks [win_lo(j) .. j]; compute is the exact causal triangle.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, skv)
    if sq % q_chunk or skv % k_chunk:
        # fall back to a single-tile pass (ragged sizes only appear in tests)
        acc = jnp.zeros((b, sq, h, d), jnp.float32)
        m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, sq), jnp.float32)
        acc, m, l = _attend_chunk(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            q_pos, kv_pos, scale, causal, window, logit_softcap, acc, m0, l0)
        out = acc / jnp.maximum(jnp.transpose(l, (0, 2, 1))[..., None], 1e-30)
        return out.astype(q.dtype)

    n_q = sq // q_chunk
    n_k = skv // k_chunk
    out_chunks = []
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # Conservative alignment assumption for static chunk-range pruning:
    # q_pos/kv_pos are monotone per row. When causal, chunk j of Q can only
    # see K chunks whose start position <= max q_pos in chunk j.  With the
    # standard layouts used here (prefill: q_pos == kv_pos; training:
    # both are arange) chunk ranges below are exact.
    for j in range(n_q):
        qj = jax.lax.dynamic_slice_in_dim(qf, j * q_chunk, q_chunk, axis=1)
        qpj = jax.lax.dynamic_slice_in_dim(q_pos, j * q_chunk, q_chunk, axis=1)
        if causal and sq == skv and q_chunk % k_chunk == 0:
            hi = (j + 1) * (q_chunk // k_chunk)
        else:
            hi = n_k
        if window is not None and causal and sq == skv:
            lo = max(0, ((j * q_chunk - window) // k_chunk))
        else:
            lo = 0
        n_steps = hi - lo
        k_slab = jax.lax.dynamic_slice_in_dim(kf, lo * k_chunk, n_steps * k_chunk, axis=1)
        v_slab = jax.lax.dynamic_slice_in_dim(vf, lo * k_chunk, n_steps * k_chunk, axis=1)
        kp_slab = jax.lax.dynamic_slice_in_dim(kv_pos, lo * k_chunk, n_steps * k_chunk, axis=1)
        k_steps = k_slab.reshape(b, n_steps, k_chunk, h, d).transpose(1, 0, 2, 3, 4)
        v_steps = v_slab.reshape(b, n_steps, k_chunk, h, d).transpose(1, 0, 2, 3, 4)
        kp_steps = kp_slab.reshape(b, n_steps, k_chunk).transpose(1, 0, 2)

        def body(carry, xs):
            acc, m_p, l_p = carry
            k_c, v_c, kp_c = xs
            acc, m_n, l_n = _attend_chunk(
                qj, k_c, v_c, qpj, kp_c, scale, causal, window, logit_softcap,
                acc, m_p, l_p)
            return (acc, m_n, l_n), None

        acc0 = jnp.zeros((b, q_chunk, h, d), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (k_steps, v_steps, kp_steps))
        outj = acc / jnp.maximum(jnp.transpose(l, (0, 2, 1))[..., None], 1e-30)
        out_chunks.append(outj)
    return jnp.concatenate(out_chunks, axis=1).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, cache_len, window=None,
                     q_pos=None, scale=None, logit_softcap=None):
    """Single-step attention vs cache.

    q: (B, 1, H, D); caches: (B, Smax, KVH, D); cache_len: (B,) valid lengths
    (the new token's K/V must already be written at index cache_len-1).
    Returns (B, 1, H, D).
    """
    b, _, h, d = q.shape
    smax = k_cache.shape[1]
    k = _gqa_expand(k_cache, h)
    v = _gqa_expand(v_cache, h)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if logit_softcap:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    kv_pos = jnp.arange(smax)[None, :]
    valid = kv_pos < cache_len[:, None]
    if window is not None:
        qp = (cache_len - 1) if q_pos is None else q_pos
        valid &= qp[:, None] - kv_pos < window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class Attention(Module):
    """MHA / GQA self-attention with rotary, optional qkv-bias / qk_norm /
    sliding window — covers qwen1.5 (bias), qwen3 (qk_norm), granite/llama,
    qwen2-moe, kimi (GQA per assignment), recurrentgemma local layers,
    internvl LM, whisper (rope disabled, bias on)."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int | None = None
    qkv_bias: bool = False
    out_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    window: int | None = None
    logit_softcap: float | None = None
    dtype: jnp.dtype = jnp.float32

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def init(self, key):
        hd = self.hd
        mk = lambda n, i, o, b: Linear(i, o, use_bias=b, dtype=self.dtype).init(named_key(key, n))
        return {
            "q": mk("q", self.d_model, self.n_heads * hd, self.qkv_bias),
            "k": mk("k", self.d_model, self.n_kv_heads * hd, self.qkv_bias),
            "v": mk("v", self.d_model, self.n_kv_heads * hd, self.qkv_bias),
            "o": mk("o", self.n_heads * hd, self.d_model, self.out_bias),
        }

    def qkv(self, params, x, positions):
        b, s, _ = x.shape
        hd = self.hd
        lin = lambda p, o, bias: (forward_matmul(x, p["w"]) + (p["b"] if bias else 0.0))
        q = lin(params["q"], None, self.qkv_bias).reshape(b, s, self.n_heads, hd)
        k = lin(params["k"], None, self.qkv_bias).reshape(b, s, self.n_kv_heads, hd)
        v = lin(params["v"], None, self.qkv_bias).reshape(b, s, self.n_kv_heads, hd)
        if self.qk_norm:
            q = rms_normalize(q)
            k = rms_normalize(k)
        if self.rope:
            cos, sin = rotary_angles(positions, hd, self.rope_theta)
            q = apply_rotary(q, cos, sin)
            k = apply_rotary(k, cos, sin)
        return q, k, v

    def __call__(self, params, x, *, positions=None, q_chunk=2048, k_chunk=1024):
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        q, k, v = self.qkv(params, x, positions)
        if s <= 2 * k_chunk:
            out = reference_attention(q, k, v, q_pos=positions, kv_pos=positions,
                                      causal=self.causal, window=self.window,
                                      logit_softcap=self.logit_softcap)
        else:
            out = flash_attention(q, k, v, q_pos=positions, kv_pos=positions,
                                  causal=self.causal, window=self.window,
                                  logit_softcap=self.logit_softcap,
                                  q_chunk=q_chunk, k_chunk=k_chunk)
        out = out.reshape(b, s, self.n_heads * self.hd)
        y = forward_matmul(out, params["o"]["w"])
        if self.out_bias:
            y = y + params["o"]["b"]
        return y

    # ---- decode path ------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None):
        hd = self.hd
        dt = dtype or self.dtype
        eff = min(max_len, self.window) if self.window is not None else max_len
        return {
            "k": jnp.zeros((batch, eff, self.n_kv_heads, hd), dt),
            "v": jnp.zeros((batch, eff, self.n_kv_heads, hd), dt),
        }

    def decode(self, params, x, cache, cache_len):
        """One token: x (B, 1, d). Returns (y, new_cache).

        For windowed layers the cache is a ring buffer of size ``window``.
        """
        b = x.shape[0]
        positions = cache_len[:, None]  # new token's absolute position
        q, k, v = self.qkv(params, x, positions)
        smax = cache["k"].shape[1]
        if self.window is not None and smax == self.window:
            slot = (cache_len % smax)
        else:
            slot = cache_len
        bidx = jnp.arange(b)
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
        if self.window is not None and smax == self.window:
            # ring buffer: every stored slot is within the window by
            # construction; validity = stored count
            valid_len = jnp.minimum(cache_len + 1, smax)
            out = decode_attention(q, k_cache, v_cache, cache_len=valid_len,
                                   window=None, logit_softcap=self.logit_softcap)
        else:
            out = decode_attention(q, k_cache, v_cache, cache_len=cache_len + 1,
                                   window=self.window, logit_softcap=self.logit_softcap)
        y = forward_matmul(out.reshape(b, 1, self.n_heads * self.hd), params["o"]["w"])
        if self.out_bias:
            y = y + params["o"]["b"]
        return y, {"k": k_cache, "v": v_cache}

    def prefill(self, params, x, cache, cache_len, n_valid):
        """Chunked cache fill: x (B, C, d) is the next C prompt tokens of
        every slot (per-slot validity ``n_valid``), written at absolute
        positions ``cache_len + j`` and attended causally against the whole
        cache in ONE batched forward.  Invalid positions scatter out of
        bounds and are dropped (``mode="drop"``), so slots past their
        prompt (n_valid == 0 included) leave the cache untouched.  Only for
        absolute-indexed caches — windowed ring buffers take the engine's
        scan fallback (``serve.decode.make_prefill_step``)."""
        assert self.window is None, "windowed caches prefill via decode-scan"
        b, c, _ = x.shape
        positions = cache_len[:, None] + jnp.arange(c)[None, :]
        q, k, v = self.qkv(params, x, positions)
        smax = cache["k"].shape[1]
        valid = jnp.arange(c)[None, :] < n_valid[:, None]
        slot = jnp.where(valid, positions, smax)  # smax = out of bounds
        bidx = jnp.arange(b)[:, None]
        k_cache = cache["k"].at[bidx, slot].set(k, mode="drop")
        v_cache = cache["v"].at[bidx, slot].set(v, mode="drop")
        kv_pos = jnp.broadcast_to(jnp.arange(smax)[None, :], (b, smax))
        out = reference_attention(q, k_cache, v_cache, q_pos=positions,
                                  kv_pos=kv_pos, causal=True,
                                  logit_softcap=self.logit_softcap)
        y = forward_matmul(out.reshape(b, c, self.n_heads * self.hd), params["o"]["w"])
        if self.out_bias:
            y = y + params["o"]["b"]
        return y, {"k": k_cache, "v": v_cache}


@dataclasses.dataclass(frozen=True)
class CrossAttention(Module):
    """Encoder-decoder cross attention (whisper)."""

    d_model: int
    n_heads: int
    use_bias: bool = True
    dtype: jnp.dtype = jnp.float32

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def init(self, key):
        mk = lambda n, b: Linear(self.d_model, self.d_model, use_bias=b,
                                 dtype=self.dtype).init(named_key(key, n))
        return {"q": mk("q", self.use_bias), "k": mk("k", False),
                "v": mk("v", self.use_bias), "o": mk("o", self.use_bias)}

    def __call__(self, params, x, enc, q_chunk: int = 2048):
        b, s, _ = x.shape
        se = enc.shape[1]
        hd = self.hd
        q = (x @ params["q"]["w"]
             + (params["q"].get("b", 0.0) if self.use_bias else 0.0)
             ).reshape(b, s, self.n_heads, hd)
        k = (enc @ params["k"]["w"]).reshape(b, se, self.n_heads, hd)
        v = (enc @ params["v"]["w"]
             + (params["v"].get("b", 0.0) if self.use_bias else 0.0)
             ).reshape(b, se, self.n_heads, hd)
        kp = jnp.broadcast_to(jnp.arange(se)[None], (b, se))

        def attend(qc, qpc):
            return reference_attention(qc, k, v, q_pos=qpc, kv_pos=kp, causal=False)

        if s > q_chunk and s % q_chunk == 0:
            # chunk queries so score tensors stay O(q_chunk * se)
            nq = s // q_chunk
            qs = q.reshape(b, nq, q_chunk, self.n_heads, hd).transpose(1, 0, 2, 3, 4)
            qp = jnp.broadcast_to(jnp.arange(q_chunk)[None], (b, q_chunk))
            out = jax.lax.map(lambda qc: attend(qc, qp), qs)
            out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, self.n_heads, hd)
        else:
            qp = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            out = attend(q, qp)
        y = out.reshape(b, s, self.d_model) @ params["o"]["w"]
        if self.use_bias:
            y = y + params["o"]["b"]
        return y


@dataclasses.dataclass(frozen=True)
class MLAttention(Module):
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

    Projections:
      q:  x → q_lora → (per head) [nope | rope]
      kv: x → (kv_lora ‖ shared rope key)
          kv_lora → (per head) [k_nope | v]
    Cache stores only (kv_lora, k_rope): (r_kv + r_rope) floats/token.
    Decode uses the absorbed form (q_nope folded through W_uk; output read
    back through W_uv) so per-step work is O(S·(r_kv + r_rope)) per head.
    """

    d_model: int
    n_heads: int
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    rope_theta: float = 10000.0
    dtype: jnp.dtype = jnp.float32

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    def init(self, key):
        mk = lambda n, i, o: Linear(i, o, dtype=self.dtype).init(named_key(key, n))
        h = self.n_heads
        return {
            "q_down": mk("q_down", self.d_model, self.q_lora_rank),
            "q_norm_scale": jnp.ones((self.q_lora_rank,), self.dtype),
            "q_up": mk("q_up", self.q_lora_rank, h * self.qk_dim),
            "kv_down": mk("kv_down", self.d_model, self.kv_lora_rank + self.qk_rope_dim),
            "kv_norm_scale": jnp.ones((self.kv_lora_rank,), self.dtype),
            "k_up": mk("k_up", self.kv_lora_rank, h * self.qk_nope_dim),
            "v_up": mk("v_up", self.kv_lora_rank, h * self.v_head_dim),
            "o": mk("o", h * self.v_head_dim, self.d_model),
        }

    def _latents(self, params, x, positions):
        """Return (q (B,S,H,qk_dim), c_kv (B,S,r), k_rope (B,S,rope))."""
        b, s, _ = x.shape
        h = self.n_heads
        ql = forward_matmul(x, params["q_down"]["w"])
        ql = rms_normalize(ql) * params["q_norm_scale"]
        q = forward_matmul(ql, params["q_up"]["w"]).reshape(b, s, h, self.qk_dim)
        kv = forward_matmul(x, params["kv_down"]["w"])
        c_kv = rms_normalize(kv[..., : self.kv_lora_rank]) * params["kv_norm_scale"]
        k_rope = kv[..., self.kv_lora_rank:]
        cos, sin = rotary_angles(positions, self.qk_rope_dim, self.rope_theta)
        q_nope, q_rope = q[..., : self.qk_nope_dim], q[..., self.qk_nope_dim:]
        q_rope = apply_rotary(q_rope, cos, sin)
        k_rope = apply_rotary(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        return q, c_kv, k_rope

    def __call__(self, params, x, *, positions=None, q_chunk=2048, k_chunk=1024):
        b, s, _ = x.shape
        h = self.n_heads
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        q, c_kv, k_rope = self._latents(params, x, positions)
        k_nope = forward_matmul(c_kv, params["k_up"]["w"]).reshape(b, s, h, self.qk_nope_dim)
        v = forward_matmul(c_kv, params["v_up"]["w"]).reshape(b, s, h, self.v_head_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, self.qk_rope_dim))], axis=-1)
        scale = 1.0 / math.sqrt(self.qk_dim)
        # v_head_dim != qk_dim → pad V for the shared kernels, slice after
        pad = self.qk_dim - self.v_head_dim
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else v
        if s <= 2 * k_chunk:
            out = reference_attention(q, k, v_p, q_pos=positions,
                                      kv_pos=positions, causal=True, scale=scale)
        else:
            out = flash_attention(q, k, v_p, q_pos=positions, kv_pos=positions, causal=True,
                                  scale=scale, q_chunk=q_chunk, k_chunk=k_chunk)
        out = out[..., : self.v_head_dim].reshape(b, s, h * self.v_head_dim)
        return forward_matmul(out, params["o"]["w"])

    def init_cache(self, batch: int, max_len: int, dtype=None):
        dt = dtype or self.dtype
        return {
            "c_kv": jnp.zeros((batch, max_len, self.kv_lora_rank), dt),
            "k_rope": jnp.zeros((batch, max_len, self.qk_rope_dim), dt),
        }

    def decode(self, params, x, cache, cache_len):
        """Absorbed-form single-token decode. x: (B, 1, d)."""
        b = x.shape[0]
        h = self.n_heads
        positions = cache_len[:, None]
        q, c_kv_new, k_rope_new = self._latents(params, x, positions)
        bidx = jnp.arange(b)
        c_cache = cache["c_kv"].at[bidx, cache_len].set(c_kv_new[:, 0])
        r_cache = cache["k_rope"].at[bidx, cache_len].set(k_rope_new[:, 0])
        q_nope, q_rope = q[..., : self.qk_nope_dim], q[..., self.qk_nope_dim:]
        # absorb q_nope through W_uk:  (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
        w_uk = params["k_up"]["w"].reshape(self.kv_lora_rank, h, self.qk_nope_dim)
        q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
        scores = jnp.einsum("bqhr,bkr->bhqk", q_abs, c_cache.astype(jnp.float32))
        scores += jnp.einsum("bqhp,bkp->bhqk", q_rope.astype(jnp.float32),
                             r_cache.astype(jnp.float32))
        scores *= 1.0 / math.sqrt(self.qk_dim)
        valid = jnp.arange(c_cache.shape[1])[None, :] < (cache_len + 1)[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhqk,bkr->bqhr", w, c_cache.astype(jnp.float32))
        w_uv = params["v_up"]["w"].reshape(self.kv_lora_rank, h, self.v_head_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
        y = forward_matmul(out.reshape(b, 1, h * self.v_head_dim), params["o"]["w"])
        return y, {"c_kv": c_cache, "k_rope": r_cache}

    def prefill(self, params, x, cache, cache_len, n_valid):
        """Chunked absorbed-form prefill: C queries per slot against the
        latent cache — the decode math with a query axis (see
        ``Attention.prefill`` for the scatter/validity semantics)."""
        b, c, _ = x.shape
        h = self.n_heads
        positions = cache_len[:, None] + jnp.arange(c)[None, :]
        q, c_kv_new, k_rope_new = self._latents(params, x, positions)
        smax = cache["c_kv"].shape[1]
        valid = jnp.arange(c)[None, :] < n_valid[:, None]
        slot = jnp.where(valid, positions, smax)
        bidx = jnp.arange(b)[:, None]
        c_cache = cache["c_kv"].at[bidx, slot].set(c_kv_new, mode="drop")
        r_cache = cache["k_rope"].at[bidx, slot].set(k_rope_new, mode="drop")
        q_nope, q_rope = q[..., : self.qk_nope_dim], q[..., self.qk_nope_dim:]
        w_uk = params["k_up"]["w"].reshape(self.kv_lora_rank, h, self.qk_nope_dim)
        q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
        scores = jnp.einsum("bqhr,bkr->bhqk", q_abs, c_cache.astype(jnp.float32))
        scores += jnp.einsum("bqhp,bkp->bhqk", q_rope.astype(jnp.float32),
                             r_cache.astype(jnp.float32))
        scores *= 1.0 / math.sqrt(self.qk_dim)
        causal = jnp.arange(smax)[None, None, :] <= positions[:, :, None]  # (B,C,S)
        scores = jnp.where(causal[:, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhqk,bkr->bqhr", w, c_cache.astype(jnp.float32))
        w_uv = params["v_up"]["w"].reshape(self.kv_lora_rank, h, self.v_head_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
        y = forward_matmul(out.reshape(b, c, h * self.v_head_dim), params["o"]["w"])
        return y, {"c_kv": c_cache, "k_rope": r_cache}
