"""RG-LRU recurrent block (Griffin / RecurrentGemma — arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit is a diagonal linear recurrence

    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
    a_t = exp(c · r_t · log σ(Λ)),  r_t = σ(W_a x_t),  i_t = σ(W_x x_t)

Being diagonal & linear in h it admits ``lax.associative_scan`` — O(log S)
depth — which is what we lower for training/prefill; decode is the O(1)
per-step update.  The surrounding block is Griffin's recurrent block:
(proj → causal conv → RG-LRU) ⊙ gelu(gate-proj) → out-proj.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.photonics import forward_matmul
from repro.nn.linear import Linear
from repro.nn.module import Module, named_key
from repro.nn.ssm import causal_conv1d

_C = 8.0  # Griffin's recurrence-gate temperature


def _log_a(params, r):
    """log a_t = -c * r_t * softplus(Λ)  (log σ(Λ) = -softplus(-Λ); Griffin
    parameterises Λ so that a = σ(Λ)^c ⇒ log a = c·log σ(Λ))."""
    log_sig_lambda = -jax.nn.softplus(-params["lambda"].astype(jnp.float32))
    return _C * r * log_sig_lambda


def rglru_scan(x, r, i, params):
    """Associative-scan RG-LRU. x, r, i: (B, S, D) f32. Returns h: (B,S,D)."""
    log_a = _log_a(params, r)  # (B,S,D), <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably: 1 - exp(2 log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_s
    return h


@dataclasses.dataclass(frozen=True)
class RGLRUBlock(Module):
    d_model: int
    d_rnn: int
    conv_width: int = 4
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        mk = lambda n, i, o: Linear(i, o, dtype=self.dtype).init(named_key(key, n))
        # Λ init so that a^c spans ~(0.9, 0.999) as in Griffin
        u = jax.random.uniform(named_key(key, "lambda"), (self.d_rnn,), minval=0.9, maxval=0.999)
        lam = jnp.log(u ** (1 / _C) / (1 - u ** (1 / _C)))
        return {
            "in_x": mk("in_x", self.d_model, self.d_rnn),
            "in_gate": mk("in_gate", self.d_model, self.d_rnn),
            "conv_w": (jax.random.normal(named_key(key, "conv_w"),
                                         (self.conv_width, self.d_rnn)) * 0.1).astype(self.dtype),
            "conv_b": jnp.zeros((self.d_rnn,), self.dtype),
            "w_a": mk("w_a", self.d_rnn, self.d_rnn),
            "w_i": mk("w_i", self.d_rnn, self.d_rnn),
            "lambda": lam.astype(self.dtype),
            "out": mk("out", self.d_rnn, self.d_model),
        }

    def _branch(self, params, u):
        x = forward_matmul(u, params["in_x"]["w"])
        x = causal_conv1d(x, params["conv_w"], params["conv_b"])
        r = jax.nn.sigmoid(forward_matmul(x, params["w_a"]["w"]).astype(jnp.float32))
        i = jax.nn.sigmoid(forward_matmul(x, params["w_i"]["w"]).astype(jnp.float32))
        return x.astype(jnp.float32), r, i

    def __call__(self, params, u):
        """u: (B, S, d_model)."""
        x, r, i = self._branch(params, u)
        h = rglru_scan(x, r, i, params)
        gate = jax.nn.gelu(forward_matmul(u, params["in_gate"]["w"]).astype(jnp.float32))
        y = (h * gate).astype(u.dtype)
        return forward_matmul(y, params["out"]["w"])

    # ---- decode -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int = 0, dtype=None):
        del max_len
        dt = dtype or self.dtype
        return {
            "h": jnp.zeros((batch, self.d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, self.conv_width - 1, self.d_rnn), dt),
        }

    def decode(self, params, u, cache, cache_len):
        del cache_len
        x_new = forward_matmul(u, params["in_x"]["w"])  # (B,1,D)
        win = jnp.concatenate([cache["conv"], x_new], axis=1)
        x = (jnp.einsum("bkc,kc->bc", win, params["conv_w"]) + params["conv_b"])[:, None, :]
        r = jax.nn.sigmoid(forward_matmul(x, params["w_a"]["w"]).astype(jnp.float32))
        i = jax.nn.sigmoid(forward_matmul(x, params["w_i"]["w"]).astype(jnp.float32))
        xf = x.astype(jnp.float32)
        log_a = _log_a(params, r)
        a = jnp.exp(log_a)[:, 0]
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))[:, 0]
        h = a * cache["h"] + beta * (i[:, 0] * xf[:, 0])
        gate = jax.nn.gelu(forward_matmul(u, params["in_gate"]["w"]).astype(jnp.float32))
        y = forward_matmul((h[:, None, :] * gate).astype(u.dtype), params["out"]["w"])
        return y, {"h": h, "conv": win[:, 1:, :].astype(cache["conv"].dtype)}
