"""Mixture-of-Experts FFN (qwen2-moe, kimi-k2).

Dispatch uses the GShard/MaxText one-hot capacity formulation so the expert
computation is a single static einsum over the expert axis — GSPMD shards the
expert dimension over the `model` mesh axis and turns dispatch/combine into
all-to-alls (expert parallelism).  Token dropping beyond capacity follows
position-in-expert order; shared experts (qwen2-moe: 4, kimi: 1) run densely.

Aux losses: standard load-balancing loss (Switch) + router z-loss, returned
so the trainer can weight them.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.sharding import annotate
from repro.nn.linear import GatedMLP, Linear
from repro.nn.module import Module, named_key, stack_init


@dataclasses.dataclass(frozen=True)
class MoE(Module):
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    d_ff_shared: int | None = None  # defaults to d_ff_expert per shared expert
    capacity_factor: float = 1.25
    activation: str = "silu"
    norm_topk_prob: bool = True
    # tokens are routed in groups of this size (GShard-style scan): bounds
    # the (T, E, C) dispatch tensor to O(group·E·cap_group) regardless of
    # global batch — essential at kimi-k2 scale (1M tokens/step).
    group_size: int = 4096
    # dispatch implementation:
    #   einsum — GShard one-hot matmuls (MXU-dense but ~3× the useful flops:
    #            dispatch+combine each cost T·E·C·d ≈ the expert matmuls)
    #   gather — slot-indexed gather/scatter: zero matmul flops for routing
    dispatch: str = "einsum"
    dtype: jnp.dtype = jnp.float32

    def init(self, key):
        expert = GatedMLP(self.d_model, self.d_ff_expert, self.activation, self.dtype)
        p = {
            "router": Linear(self.d_model, self.n_experts,
                             dtype=self.dtype).init(named_key(key, "router")),
            "experts": stack_init(expert, named_key(key, "experts"), self.n_experts),
        }
        if self.n_shared_experts:
            d_sh = (self.d_ff_shared or self.d_ff_expert) * self.n_shared_experts
            p["shared"] = GatedMLP(self.d_model, d_sh, self.activation,
                                   self.dtype).init(named_key(key, "shared"))
        return p

    def _route(self, params, x_flat):
        """x_flat: (T, d). Returns (combine (T,E,C), dispatch (T,E,C), aux)."""
        t = x_flat.shape[0]
        e = self.n_experts
        cap = max(1, int(self.capacity_factor * self.top_k * t / e))
        logits = (x_flat @ params["router"]["w"]).astype(jnp.float32)  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, self.top_k)  # (T, K)
        if self.norm_topk_prob:
            topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        # one-hot expert assignment per k-slot: (T, K, E)
        assign = jax.nn.one_hot(topi, e, dtype=jnp.float32)
        # position of each (token, slot) within its expert queue
        flat_assign = assign.reshape(t * self.top_k, e)
        pos_in_expert = (jnp.cumsum(flat_assign, axis=0) - flat_assign).reshape(t, self.top_k, e)
        keep = (pos_in_expert < cap).astype(jnp.float32) * assign
        pos = jnp.einsum("tke,tke->tk", pos_in_expert, keep).astype(jnp.int32)  # (T, K)
        pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)  # (T, K, C)
        dispatch = jnp.einsum("tke,tkc->tec", keep, pos_oh)  # (T, E, C) in {0,1}
        combine = jnp.einsum("tk,tke,tkc->tec", topv, keep, pos_oh)
        # aux losses
        me = probs.mean(axis=0)  # (E,)
        ce = assign.sum(axis=1).mean(axis=0)  # fraction routed per expert
        lb_loss = e * jnp.sum(me * ce)
        z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        dropped = 1.0 - keep.sum() / (t * self.top_k)
        aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
        return combine, dispatch, aux

    def _route_topk(self, params, x_flat):
        """Shared routing prelude: (topv (T,K), topi (T,K), keep, pos, cap, aux)."""
        t = x_flat.shape[0]
        e = self.n_experts
        cap = max(1, int(self.capacity_factor * self.top_k * t / e))
        logits = (x_flat @ params["router"]["w"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, self.top_k)
        if self.norm_topk_prob:
            topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        assign = jax.nn.one_hot(topi, e, dtype=jnp.float32)
        flat_assign = assign.reshape(t * self.top_k, e)
        pos_in_expert = (jnp.cumsum(flat_assign, axis=0) - flat_assign).reshape(t, self.top_k, e)
        keep = (pos_in_expert < cap).astype(jnp.float32) * assign
        pos = jnp.einsum("tke,tke->tk", pos_in_expert, keep).astype(jnp.int32)
        me = probs.mean(axis=0)
        ce = assign.sum(axis=1).mean(axis=0)
        aux = {
            "lb_loss": e * jnp.sum(me * ce),
            "z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
            "dropped_frac": 1.0 - keep.sum() / (t * self.top_k),
        }
        return topv, topi, keep, pos, cap, aux

    def _group_forward(self, params, x_flat):
        """Route+compute one token group. x_flat: (Tg, d) -> (y, aux)."""
        if self.dispatch == "gather":
            return self._group_forward_gather(params, x_flat)
        combine, dispatch, aux = self._route(params, x_flat)
        # dispatch tokens into per-expert buffers: (E, C, d)
        expert_in = jnp.einsum("tec,td->ecd", dispatch.astype(x_flat.dtype), x_flat)
        expert_in = annotate(expert_in, "expert_ecd")
        expert = GatedMLP(self.d_model, self.d_ff_expert, self.activation, self.dtype)
        expert_out = jax.vmap(expert)(params["experts"], expert_in)  # (E, C, d)
        expert_out = annotate(expert_out, "expert_ecd")
        y = jnp.einsum("tec,ecd->td", combine.astype(x_flat.dtype), expert_out)
        return y, aux

    def _group_forward_gather(self, params, x_flat):
        """Slot-indexed dispatch: scatter token ids into (E·C) slots, gather
        token rows, run experts, gather slot outputs back per (token, k).
        Identical routing/capacity semantics to the einsum path with zero
        routing matmul flops."""
        t, d = x_flat.shape
        e = self.n_experts
        topv, topi, keep, pos, cap, aux = self._route_topk(params, x_flat)
        kept = keep.sum(-1) > 0  # (T, K) — this (token, k) slot was admitted
        n_slots = e * cap
        slot = topi * cap + pos  # (T, K)
        slot = jnp.where(kept, slot, n_slots)  # dropped -> overflow slot
        tok_ids = jnp.broadcast_to(jnp.arange(t)[:, None], slot.shape)
        # slots are unique per (kept) (t, k) by construction of pos
        slot_tok = jnp.zeros((n_slots + 1,), jnp.int32).at[slot.reshape(-1)].set(
            tok_ids.reshape(-1).astype(jnp.int32), mode="drop")
        slot_valid = jnp.zeros((n_slots + 1,), x_flat.dtype).at[slot.reshape(-1)].set(
            1.0, mode="drop")
        expert_in = x_flat[slot_tok[:n_slots]] * slot_valid[:n_slots, None]
        expert_in = annotate(expert_in.reshape(e, cap, d), "expert_ecd")
        expert = GatedMLP(self.d_model, self.d_ff_expert, self.activation, self.dtype)
        expert_out = jax.vmap(expert)(params["experts"], expert_in)  # (E, C, d)
        expert_out = annotate(expert_out, "expert_ecd")
        out_flat = jnp.concatenate(
            [expert_out.reshape(n_slots, d),
             jnp.zeros((1, d), expert_out.dtype)], axis=0)
        per_k = out_flat[slot]  # (T, K, d); overflow row is zeros
        y = jnp.einsum("tk,tkd->td", topv.astype(per_k.dtype), per_k)
        return y, aux

    def __call__(self, params, x):
        """x: (B, S, d) -> (y, aux).

        Token groups are cut along the SEQUENCE axis ((B, chunk) tokens per
        group) so the scanned group dim is never the batch-sharded dim —
        scanning a sharded xs dim would force a full all-gather of the
        activations in the scan (and its transpose in the vjp)."""
        b, s, d = x.shape
        t = b * s
        chunk = max(1, self.group_size // b)
        if t <= self.group_size or s % chunk != 0:
            y, aux = self._group_forward(params, x.reshape(t, d))
        else:
            g = s // chunk
            xg = x.reshape(b, g, chunk, d).swapaxes(0, 1)  # (g, B, chunk, d)

            # remat: the (Tg,E,C) dispatch/combine tensors are recomputed in
            # the backward instead of being saved per group — without this
            # the stacked routing residuals dominate peak memory at
            # kimi-k2 scale (hundreds of GB/device)
            @jax.checkpoint
            def group_fwd(params, xt):
                yt, auxt = self._group_forward(params, xt.reshape(b * chunk, d))
                return yt.reshape(b, chunk, d), auxt

            def body(_, xt):
                return None, group_fwd(params, xt)

            _, (y, auxes) = jax.lax.scan(body, None, xg)
            y = y.swapaxes(0, 1).reshape(t, d)
            aux = jax.tree_util.tree_map(jnp.mean, auxes)
        if self.n_shared_experts:
            d_sh = (self.d_ff_shared or self.d_ff_expert) * self.n_shared_experts
            y = y + GatedMLP(self.d_model, d_sh, self.activation, self.dtype)(
                params["shared"], x.reshape(t, d))
        return y.reshape(b, s, d), aux
