"""Minimal functional module system.

Modules are frozen dataclasses holding *static* configuration; parameters are
plain pytrees (nested dicts of jnp arrays) produced by ``Module.init(key)``
and consumed by ``Module.__call__(params, *args)``.  This keeps everything
jit/pjit-friendly (modules are hashable statics, params are explicit pytrees)
and makes the DFA backward — which needs per-block ``jax.vjp`` over the param
subtree — trivial to express.

Stacked (scan-over-layers) parameters are produced with ``stack_init`` and
consumed by ``jax.lax.scan`` in the model definitions: the leading axis of
every leaf is the layer index.  This keeps HLO size depth-independent and
bounds FSDP all-gather liveness to a single layer.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import prng

Params = dict  # nested {str: Params | jax.Array}


@dataclasses.dataclass(frozen=True)
class Module:
    """Base class — subclasses define init(key)->Params and __call__."""

    def init(self, key: jax.Array) -> Params:  # pragma: no cover - interface
        raise NotImplementedError

    def param_shapes(self) -> Params:
        """ShapeDtypeStructs of this module's params (no allocation)."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))


def stack_init(module: Module, key: jax.Array, n: int) -> Params:
    """Initialise ``n`` copies of a module with stacked (leading-axis) params."""
    keys = jax.random.split(key, n)
    return jax.vmap(module.init)(keys)


def layer_slice(stacked: Params, i) -> Params:
    """Select layer ``i`` from stacked params (dynamic index ok)."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False), stacked)


def named_key(key: jax.Array, name: str) -> jax.Array:
    return prng.fold_name(key, name)


def truncate_dtype(x: jax.Array, dtype) -> jax.Array:
    if dtype is not None and jnp.issubdtype(x.dtype, jnp.floating):
        return x.astype(dtype)
    return x
