"""Call-graph rules: RL002 (host syncs in hot paths) and RL003
(tracer-unsafe control flow, non-hashable static args).

Reachability is computed once and shared.  Roots are:

* every jit-traced function (``analysis.Project.jit_roots`` — decorated,
  assigned through ``jax.jit``, or force-marked ``# lint: jit-root``);
* ``Trainer.fit``/``Trainer.step`` and ``Engine`` tick methods by name —
  the training loop and the serving scheduler are hot paths even though
  they themselves run host-side Python.

RL002 distinguishes two severities:

* inside the jit-reachable set, ANY host sync flags (``float()``,
  ``.item()``, ``np.asarray``, ``jax.device_get``): one stray scalar
  pull serializes the dispatch pipeline every step;
* in *driver* functions — not jit-reachable themselves but directly
  invoking jitted callables or ``.fit``/``.step``/``.tick`` methods —
  only syncs inside ``for``/``while`` loops flag.  A single read after a
  run is how results leave the device; one per iteration is the classic
  accidental-serialization bug in benchmark timing loops.
"""

from __future__ import annotations

import ast

from repro.lint.analysis import Func, Module, Project
from repro.lint.findings import Finding
from repro.lint.rules import _src, self_or_local_jit_info

# attribute calls that drive jitted work from host loops
_DRIVER_ATTRS = {"fit", "step", "tick"}

# jnp/lax predicates that are static at trace time — an `if` on these is
# fine (shape/dtype reflection, not tracer values)
_STATIC_PREDICATES = {
    "issubdtype", "result_type", "dtype", "ndim", "shape", "iinfo", "finfo",
    "isdtype",
}
_TRACER_NAMESPACES = ("jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.")


def jit_reachable(proj: Project) -> dict[Func, tuple[str, ...]]:
    """Func -> call chain from its nearest root (roots map to themselves)."""
    roots: list[Func] = list(proj.jit_roots)
    for mod in proj.modules.values():
        for fn in mod.funcs:
            if fn.cls == "Trainer" and fn.name in ("fit", "step"):
                roots.append(fn)
            elif fn.cls == "Engine" and ("tick" in fn.name or fn.name == "step"):
                roots.append(fn)
    seen: dict[Func, tuple[str, ...]] = {}
    stack = [(fn, (fn.display,)) for fn in roots]
    while stack:
        fn, chain = stack.pop()
        if fn in seen or len(chain) > 12:
            continue
        seen[fn] = chain
        mod = fn.module
        for call in [n for n in ast.walk(fn.node) if isinstance(n, ast.Call)]:
            for callee in proj.resolve_call(mod, fn, call):
                if callee not in seen:
                    stack.append((callee, chain + (callee.display,)))
    return seen


def _sync_kind(mod: Module, call: ast.Call) -> str | None:
    func = call.func
    if isinstance(func, ast.Attribute) and func.attr == "item" and not call.args:
        return ".item()"
    qual = mod.dotted(func) or ""
    if qual == "jax.device_get":
        return "jax.device_get"
    if qual in ("numpy.asarray", "numpy.array"):
        return f"np.{qual.rsplit('.', 1)[-1]}"
    if (isinstance(func, ast.Name) and func.id == "float" and call.args
            and not isinstance(call.args[0], ast.Constant)):
        return "float()"
    return None


def _is_driver(proj: Project, mod: Module, fn: Func) -> bool:
    for call in [n for n in ast.walk(fn.node) if isinstance(n, ast.Call)]:
        if self_or_local_jit_info(proj, mod, fn, call) is not None:
            return True
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr in _DRIVER_ATTRS:
            return True
    return False


def _loop_nodes(fn_node) -> list[ast.AST]:
    out = []
    for node in ast.walk(fn_node):
        if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
            out.extend(ast.walk(node))
    return out


def run_rl002(proj: Project, reachable: dict[Func, tuple[str, ...]]
              ) -> list[Finding]:
    findings: list[Finding] = []
    for fn, chain in reachable.items():
        mod = fn.module
        nested = {id(f.node) for f in mod.funcs if f is not fn}
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            kind = _sync_kind(mod, node)
            if kind is None:
                continue
            via = f" (via {' -> '.join(chain)})" if len(chain) > 1 else ""
            findings.append(Finding(
                "RL002", mod.path, node.lineno,
                f"host sync `{kind}` in jit-reachable {fn.qualname}{via} — "
                "blocks dispatch every step; batch with one device_get "
                "outside the hot path",
                _src(mod, node)))
        del nested
    # driver loops
    for mod in proj.modules.values():
        for fn in mod.funcs:
            if fn in reachable or isinstance(fn.node, ast.Lambda):
                continue
            if not _is_driver(proj, mod, fn):
                continue
            loop_body = _loop_nodes(fn.node)
            seen_lines = set()
            for node in loop_body:
                if not isinstance(node, ast.Call):
                    continue
                kind = _sync_kind(mod, node)
                if kind is None or node.lineno in seen_lines:
                    continue
                seen_lines.add(node.lineno)
                findings.append(Finding(
                    "RL002", mod.path, node.lineno,
                    f"host sync `{kind}` inside a loop of {fn.qualname}, "
                    "which drives jitted work — one blocking transfer per "
                    "iteration; hoist or batch with device_get",
                    _src(mod, node)))
    return findings


def _tracer_valued(mod: Module, test: ast.AST) -> str | None:
    """A call into jax.numpy/lax/random inside an `if`/`while` test is a
    tracer-valued predicate (minus known static reflection helpers)."""
    for node in ast.walk(test):
        if not isinstance(node, ast.Call):
            continue
        qual = mod.dotted(node.func) or ""
        if qual.rsplit(".", 1)[-1] in _STATIC_PREDICATES:
            continue
        if any(qual.startswith(ns) for ns in _TRACER_NAMESPACES):
            return qual
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in ("any", "all")
                and not node.args and not node.keywords):
            return f".{node.func.attr}()"
    return None


def run_rl003(proj: Project, reachable: dict[Func, tuple[str, ...]]
              ) -> list[Finding]:
    findings: list[Finding] = []
    # (a) Python control flow on tracer-valued tests in jit-reachable code
    for fn in reachable:
        mod = fn.module
        for node in ast.walk(fn.node):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            culprit = _tracer_valued(mod, node.test)
            if culprit is None:
                continue
            kw = "while" if isinstance(node, ast.While) else "if"
            findings.append(Finding(
                "RL003", mod.path, node.lineno,
                f"Python `{kw}` on tracer-valued `{culprit}` in "
                f"jit-reachable {fn.qualname} — trace-time branch; use "
                "jnp.where / lax.cond / lax.while_loop",
                _src(mod, node)))
    # (b) non-hashable static args at jitted call sites
    for mod in proj.modules.values():
        for fn in mod.funcs:
            if isinstance(fn.node, ast.Lambda):
                continue
            for call in [n for n in ast.walk(fn.node)
                         if isinstance(n, ast.Call)]:
                info = self_or_local_jit_info(proj, mod, fn, call)
                if not info or not info.get("static"):
                    continue
                for pos in info["static"]:
                    if not isinstance(pos, int) or pos >= len(call.args):
                        continue
                    arg = call.args[pos]
                    if isinstance(arg, (ast.List, ast.Dict, ast.Set)):
                        kind = type(arg).__name__.lower()
                        findings.append(Finding(
                            "RL003", mod.path, call.lineno,
                            f"non-hashable {kind} literal passed as static "
                            f"arg {pos} of a jitted callable in "
                            f"{fn.qualname} — static args must be hashable "
                            "(use a tuple)",
                            _src(mod, call)))
    return findings
