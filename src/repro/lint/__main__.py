"""CLI: ``python -m repro.lint [paths...] [--baseline lint-baseline.json]``.

Exit status is 0 when every finding is accounted for by an inline
suppression or the baseline, 1 when NEW findings exist (CI gate), 2 on
usage errors.  ``--write-baseline`` rewrites the baseline from the
current findings (for adopting the linter on a tree with legacy debt —
the committed baseline for this repo's ``src/`` is empty and should
stay that way: fix or suppress-with-comment instead).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro import lint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX-aware static analysis for the photonic "
                    "training/serving stack (rules RL001-RL005)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src/)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON; only NEW findings fail the run")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite --baseline from the current findings")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset (e.g. RL001,RL002)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    args = ap.parse_args(argv)

    rules = lint.ALL_RULES
    if args.rules:
        rules = tuple(r.strip().upper() for r in args.rules.split(","))
        unknown = set(rules) - set(lint.ALL_RULES)
        if unknown:
            ap.error(f"unknown rules: {sorted(unknown)}")

    paths = args.paths or ["src"]
    findings, suppressed = lint.lint_paths(paths, rules)

    if args.write_baseline:
        if not args.baseline:
            ap.error("--write-baseline requires --baseline PATH")
        lint.write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = lint.load_baseline(args.baseline)
    fresh = lint.new_findings(findings, baseline)

    if args.format == "json":
        print(json.dumps({
            "findings": [vars(f) for f in fresh],
            "baselined": len(findings) - len(fresh),
            "suppressed": suppressed,
        }, indent=2))
    else:
        for f in fresh:
            print(f.render())
        print(f"repro.lint: {len(fresh)} new finding(s), "
              f"{len(findings) - len(fresh)} baselined, "
              f"{suppressed} suppressed inline "
              f"({', '.join(rules)} over {', '.join(paths)})")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
