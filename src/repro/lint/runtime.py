"""Opt-in runtime sanitizers: checkify value checks on the photonic
signal chain and a recompilation sentinel for the training/serving hot
loops.

The static rules (RL001–RL005) catch structure; this layer catches
*values* and *retraces* — the two failure modes no AST can see:

* ``check_finite(x, name)`` — a ``checkify.check`` asserting every
  element finite, emitted ONLY inside an active ``debug_checks()``
  context.  The emu channel and the fused XLA kernel twin call it on
  their outputs; ``photonic_matmul`` on the reference path likewise.
  Outside the context it is literally ``return x``: an un-functionalized
  ``checkify.check`` would fail at trace time under plain ``jax.jit``,
  so the guard must be trace-time, not run-time.  The Trainer/Engine
  enter the context exactly while tracing their checkified steps, so
  ordinary sessions in the same process never see a stray check.
* ``checked(fn)`` — ``checkify.checkify`` with the full sanitizer error
  set (user checks + NaN/Inf + div-by-zero + out-of-bounds indexing).
  Wrapped callables return ``(error, out)``; call ``error.throw()``
  host-side.
* ``RecompileSentinel`` — counts Python-level executions of a function
  staged under ``jax.jit``.  The traced body only runs on a compilation
  cache miss, so the count IS the retrace count: after ``warmup``
  traces, any further trace raises ``RecompileError``.  The Trainer
  installs one per jitted step and the Engine one per prefill/decode
  step when built with ``debug_checks=True`` — a stable carried-state
  pytree and constant batch shapes mean steady-state training/serving
  must never retrace.
"""

from __future__ import annotations

import contextlib
import functools

import jax.numpy as jnp
from jax.experimental import checkify

#: the default sanitizer error set: explicit checks, NaN/Inf generation,
#: division by zero.  ``index_checks`` is deliberately NOT included: on
#: this JAX version checkify's gather rule crashes on the transpose of
#: ``take_along_axis`` (vjp of the cross-entropy label gather) with
#: "tuple index out of range" — pass ``errors=STRICT_ERRORS`` explicitly
#: for forward-only functions where OOB checking is safe.
CHECK_ERRORS = (checkify.user_checks | checkify.float_checks
                | checkify.div_checks)
STRICT_ERRORS = CHECK_ERRORS | checkify.index_checks

_DEBUG_STACK: list = []


def debug_checks_enabled() -> bool:
    return bool(_DEBUG_STACK)


@contextlib.contextmanager
def debug_checks():
    """Arm ``check_finite`` for the dynamic extent (enter while *tracing*
    a checkified function — the same discipline as ``drift.use_state``)."""
    _DEBUG_STACK.append(True)
    try:
        yield
    finally:
        _DEBUG_STACK.pop()


def check_finite(x, name: str):
    """Assert every element of ``x`` finite when sanitizers are armed;
    identity otherwise.  Returns ``x`` so call sites stay expressions."""
    if _DEBUG_STACK:
        checkify.check(jnp.all(jnp.isfinite(x)),
                       f"non-finite values in {name} (debug_checks)")
    return x


def checked(fn, errors=CHECK_ERRORS):
    """``checkify.checkify(fn, errors)`` with the sanitizer error set —
    the wrapped fn returns ``(error, out)``."""
    return checkify.checkify(fn, errors=errors)


class RecompileError(RuntimeError):
    """A jitted hot-path function retraced after its warmup budget."""


class RecompileSentinel:
    """Counts traces of one staged function; raises past ``warmup``.

    Place ``sentinel.tick()`` first in the to-be-jitted Python body (or
    wrap with ``sentinel.wrap``): jit only re-executes the Python body
    when the (shapes, dtypes, pytree structure, static args) signature
    misses the compilation cache, so each execution is one compile.
    """

    def __init__(self, name: str, warmup: int = 1):
        self.name = name
        self.warmup = warmup
        self.traces = 0

    def tick(self):
        self.traces += 1
        if self.traces > self.warmup:
            raise RecompileError(
                f"{self.name} retraced (trace #{self.traces}, warmup "
                f"budget {self.warmup}) — changed pytree structure, shapes "
                "or static args in a hot loop")

    def wrap(self, fn):
        @functools.wraps(fn)
        def ticked(*args, **kwargs):
            self.tick()
            return fn(*args, **kwargs)

        return ticked


def instrument(fn, name: str, *, warmup: int = 1, errors=CHECK_ERRORS):
    """The full debug harness for one hot-path function: recompile
    sentinel + ``debug_checks`` armed during tracing + checkify.

    Returns ``(wrapped, sentinel)``; ``wrapped(*args)`` (once jitted)
    yields ``(error, out)``."""
    sentinel = RecompileSentinel(name, warmup=warmup)

    @functools.wraps(fn)
    def body(*args, **kwargs):
        sentinel.tick()
        with debug_checks():
            return fn(*args, **kwargs)

    return checked(body, errors=errors), sentinel
