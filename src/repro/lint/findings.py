"""Findings, inline suppressions, and the committed baseline.

A finding is one (rule, file, line) diagnostic.  Two escape hatches keep
the analyzer's exit code meaningful instead of aspirational:

* **inline suppression** — a trailing ``# lint: disable=RL002`` comment
  on the flagged line (or on the last line of a multi-line statement)
  acknowledges an *intentional* violation in place, with the comment
  itself documenting why.  ``# lint: disable`` with no rule list
  suppresses every rule on that line; ``# lint: disable-file=RL003``
  anywhere in a file suppresses a rule for the whole file (reserved for
  generated or fixture code).
* **baseline** — ``lint-baseline.json`` records known findings as
  (rule, path, stripped-source-line) triples so the CI gate fails only
  on NEW findings.  Line numbers are deliberately not part of the match
  key: unrelated edits above a baselined finding must not break CI.
  The committed baseline for ``src/`` is empty — every real finding was
  fixed or suppressed-with-comment at introduction time.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import re

_DISABLE_RE = re.compile(r"#\s*lint:\s*disable(?:=([A-Z0-9, ]+))?")
_DISABLE_FILE_RE = re.compile(r"#\s*lint:\s*disable-file=([A-Z0-9, ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str  # "RL001" … "RL005"
    path: str  # repo-relative path of the offending file
    line: int  # 1-based line of the flagged node
    message: str
    code: str = ""  # stripped source line — the baseline match key

    @property
    def key(self) -> tuple:
        return (self.rule, self.path, self.code)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _rules_in(match_group: str | None) -> set[str] | None:
    """None means "all rules" (a bare ``# lint: disable``)."""
    if match_group is None:
        return None
    return {r.strip() for r in match_group.split(",") if r.strip()}


class Suppressions:
    """Per-file view of inline + file-level disable comments."""

    def __init__(self, lines: list[str]):
        self.line_rules: dict[int, set[str] | None] = {}
        self.file_rules: set[str] = set()
        for i, text in enumerate(lines, start=1):
            m = _DISABLE_FILE_RE.search(text)
            if m:
                self.file_rules |= _rules_in(m.group(1)) or set()
                continue
            m = _DISABLE_RE.search(text)
            if m:
                self.line_rules[i] = _rules_in(m.group(1))

    def covers(self, rule: str, *lines: int | None) -> bool:
        if rule in self.file_rules:
            return True
        for ln in lines:
            if ln is None:
                continue
            rules = self.line_rules.get(ln, False)
            if rules is False:
                continue
            if rules is None or rule in rules:
                return True
        return False


def load_baseline(path: str | None) -> collections.Counter:
    """Baseline file -> multiset of finding keys (empty when absent)."""
    if path is None:
        return collections.Counter()
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return collections.Counter()
    return collections.Counter(
        (e["rule"], e["path"], e.get("code", "")) for e in data.get("findings", ()))


def write_baseline(path: str, findings: list[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path, "code": f.code}
               for f in sorted(findings, key=lambda f: (f.path, f.rule, f.code))]
    with open(path, "w") as f:
        json.dump({"version": 1, "findings": entries}, f, indent=2)
        f.write("\n")


def new_findings(findings: list[Finding],
                 baseline: collections.Counter) -> list[Finding]:
    """Findings not accounted for by the baseline multiset."""
    budget = collections.Counter(baseline)
    fresh = []
    for f in findings:
        if budget[f.key] > 0:
            budget[f.key] -= 1
        else:
            fresh.append(f)
    return fresh
