"""``repro.lint`` — repo-specific static analysis + runtime sanitizers.

The emulator's correctness rests on invariants no unit test covers
exhaustively: disjoint counter-based PRNG streams, zero host syncs in
jitted hot paths, stable carried-state pytree structure, frozen-config
discipline, and donation hygiene.  This package locks them in as CI
gates.

Static rules (``python -m repro.lint src/ [tests/ benchmarks/] [--baseline
lint-baseline.json]``):

=======  ==============================================================
RL001    PRNG key discipline — one key value feeding ≥2 random draws
         (or unknown consumers) without an intervening split/fold_in;
         ``utils.prng.consume(key)`` marks a key spent explicitly.
RL002    Host sync in a hot path — ``float()`` / ``.item()`` /
         ``np.asarray`` / ``jax.device_get`` reachable from
         ``Trainer.fit``/``step``, ``Engine`` ticks, or any ``@jit``
         function; in *driver* functions, per-iteration syncs in loops.
RL003    Tracer-unsafe control flow — Python ``if``/``while`` on
         tracer-valued tests in jit-reachable code; non-hashable
         literals passed as static args of jitted callables.
RL004    Frozen-config mutation and dict-mutation of carried state
         inside traced code.
RL005    Donation hazards — reading a buffer after passing it at a
         ``donate_argnums`` position.
=======  ==============================================================

Suppress intentional findings in place with a trailing
``# lint: disable=RL002`` comment; known legacy findings live in the
committed ``lint-baseline.json`` (empty for ``src/``).

Runtime layer (``repro.lint.runtime``): ``build_session(...,
debug_checks=True)`` checkifies the train step (NaN/Inf, div-by-zero,
OOB indexing + explicit ``check_finite`` assertions inside the emu
channel and the fused kernel twin) and installs recompilation sentinels
that raise if the fit step or an engine tick retraces after warmup.
"""

from __future__ import annotations

from repro.lint.analysis import Project, load_project, project_from_sources
from repro.lint.findings import (Finding, Suppressions, load_baseline,
                                 new_findings, write_baseline)
from repro.lint.hotpath import jit_reachable, run_rl002, run_rl003
from repro.lint.rules import run_rl001, run_rl004, run_rl005

ALL_RULES = ("RL001", "RL002", "RL003", "RL004", "RL005")

# the static analyzer is stdlib-only (CI runs it without installing jax);
# the runtime sanitizers need jax + checkify, so they resolve lazily
_RUNTIME_NAMES = ("runtime", "RecompileError", "RecompileSentinel",
                  "check_finite", "checked", "debug_checks", "instrument")


def __getattr__(name):
    if name in _RUNTIME_NAMES:
        from repro.lint import runtime
        return runtime if name == "runtime" else getattr(runtime, name)
    raise AttributeError(f"module 'repro.lint' has no attribute {name!r}")


def run_rules(proj: Project, rules=ALL_RULES) -> tuple[list[Finding], int]:
    """All findings for a project, minus inline suppressions.

    Returns ``(findings, n_suppressed)``; findings are sorted by
    (path, line, rule).
    """
    reachable = jit_reachable(proj)
    raw: list[Finding] = []
    if "RL001" in rules:
        raw += run_rl001(proj)
    if "RL002" in rules:
        raw += run_rl002(proj, reachable)
    if "RL003" in rules:
        raw += run_rl003(proj, reachable)
    if "RL004" in rules:
        raw += run_rl004(proj, reachable)
    if "RL005" in rules:
        raw += run_rl005(proj)
    sups = {path: Suppressions(mod.lines) for path, mod in proj.modules.items()}
    kept, suppressed = [], 0
    for f in raw:
        s = sups.get(f.path)
        lines = (f.line, f.line + 1)
        if s is not None and s.covers(f.rule, *lines):
            suppressed += 1
        else:
            kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept, suppressed


def lint_paths(paths, rules=ALL_RULES) -> tuple[list[Finding], int]:
    """Lint files/directories -> (findings, n_suppressed)."""
    return run_rules(load_project(list(paths)), rules)


def lint_source(source: str, path: str = "fixture.py",
                rules=ALL_RULES) -> list[Finding]:
    """Lint one in-memory module (test fixtures)."""
    findings, _ = run_rules(project_from_sources({path: source}), rules)
    return findings
