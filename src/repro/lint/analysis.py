"""Shared AST infrastructure: parsed modules, import-aware name
resolution, the project-wide function index, and jit-root discovery.

Everything downstream (rules.py, hotpath.py) works on a ``Project``:

* ``Module`` — one parsed file with its source lines, an import map
  (local alias -> dotted origin, so ``jnp.where`` resolves to
  ``jax.numpy.where`` and ``prng.consume`` to ``repro.utils.prng.consume``)
  and every function/method def, nested defs included.
* ``Func`` — one def with its qualified display name.  Nested defs are
  indexed in their own right (the serve engine jits closures defined
  inside ``Engine.__init__``) and also remain part of the enclosing
  body's AST, so reachability walks see both views.
* jit roots — functions traced under ``jax.jit``: decorated defs,
  ``functools.partial(jax.jit, ...)`` decorations, and assignment forms
  (``f2 = jax.jit(f)``, ``self._step = jax.jit(self._train_step)``),
  chased through known transparent wrappers (``checkify.checkify``,
  ``repro.lint.runtime.checked``, ``functools.partial``).  A
  ``# lint: jit-root`` comment on the def line force-marks a root the
  resolver cannot see (callables passed through containers).

Resolution is deliberately name-based and over-approximate: a linter
that misses an edge stays silent, one that dies on dynamic dispatch is
useless.  Unresolvable calls are skipped, ambiguous bare names fan out
to every same-name candidate in the module.
"""

from __future__ import annotations

import ast
import dataclasses
import os

# --- name universes -------------------------------------------------------

TRANSPARENT_WRAPPERS = {
    "functools.partial",
    "jax.experimental.checkify.checkify",
    "checkify.checkify",
    "repro.lint.runtime.checked",
}

# jax.random draw functions: spend the key they are given
DRAW_FNS = {
    "ball", "bernoulli", "beta", "binomial", "bits", "categorical",
    "cauchy", "chisquare", "choice", "dirichlet", "double_sided_maxwell",
    "exponential", "gamma", "geometric", "gumbel", "laplace", "loggamma",
    "logistic", "lognormal", "maxwell", "multivariate_normal", "normal",
    "orthogonal", "pareto", "permutation", "poisson", "rademacher",
    "randint", "rayleigh", "t", "triangular", "truncated_normal",
    "uniform", "wald", "weibull_min",
}
DRAW_QUALS = {f"jax.random.{n}" for n in DRAW_FNS}

# derivations: read a key to mint new ones — NOT a spend
DERIVE_QUALS = {
    "jax.random.PRNGKey", "jax.random.key", "jax.random.split",
    "jax.random.fold_in", "jax.random.clone", "jax.random.key_data",
    "jax.random.wrap_key_data",
    "repro.utils.prng.key", "repro.utils.prng.fold",
    "repro.utils.prng.fold_name", "repro.utils.prng.split_dict",
    "repro.utils.prng.step_key",
    "repro.nn.module.named_key",
}
# key-producing calls (assigning from one creates a key-typed binding)
KEY_PRODUCERS = DERIVE_QUALS - {"jax.random.key_data"}
CONSUME_QUALS = {"repro.utils.prng.consume"}


def base_name(qual: str) -> str:
    return qual.rsplit(".", 1)[-1]


# --- modules --------------------------------------------------------------


@dataclasses.dataclass(eq=False)  # identity hash: one node, one Func
class Func:
    module: "Module"
    qualname: str  # "Trainer.fit", "Engine.__init__.<locals>.decode_fn", "run"
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    cls: str | None  # enclosing class name, if a method

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")

    @property
    def display(self) -> str:
        return f"{os.path.basename(self.module.path)}:{self.qualname}"


class Module:
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.imports = self._imports(self.tree)
        self.funcs: list[Func] = []
        self.by_name: dict[str, list[Func]] = {}
        self._index_funcs()

    @staticmethod
    def _imports(tree: ast.Module) -> dict[str, str]:
        imp: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        imp[a.asname] = a.name
                    else:
                        # "import jax.numpy" binds "jax"
                        head = a.name.split(".")[0]
                        imp[head] = head
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    imp[a.asname or a.name] = f"{node.module}.{a.name}"
        return imp

    def _index_funcs(self):
        def visit(node, prefix: str, cls: str | None):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    fn = Func(self, q, child, cls)
                    self.funcs.append(fn)
                    self.by_name.setdefault(child.name, []).append(fn)
                    visit(child, f"{q}.<locals>.", cls)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{child.name}.", child.name)
                else:
                    visit(child, prefix, cls)

        visit(self.tree, "", None)

    def dotted(self, node: ast.AST) -> str | None:
        """Resolve a Name/Attribute chain through the import map:
        ``jnp.where`` -> "jax.numpy.where"; an unimported bare name
        resolves to itself (it may be a module-local function)."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.imports.get(node.id, node.id)
        parts.append(head)
        return ".".join(reversed(parts))


# --- project --------------------------------------------------------------


def _module_name(path: str) -> str:
    """File path -> dotted import name ("src/repro/api.py" -> "repro.api")."""
    norm = path.replace(os.sep, "/")
    for marker in ("src/", ""):
        if marker and f"{marker}" in norm:
            norm = norm.split(f"{marker}", 1)[1]
            break
    return norm[:-3].replace("/", ".") if norm.endswith(".py") else norm


class Project:
    """All scanned modules + the cross-module function index."""

    def __init__(self):
        self.modules: dict[str, Module] = {}  # path -> Module
        self.by_modname: dict[str, Module] = {}  # "repro.api" -> Module
        self.frozen_classes: set[str] = set()  # bare names of frozen dataclasses
        # jit info discovered in the root pass:
        self.jit_roots: list[Func] = []
        self.jit_lambdas: list[tuple[Module, ast.Lambda]] = []
        # jitted-callable bindings: ("local", module_path, scope_qual, name) or
        # ("attr", module_path, class, attr) -> {"static": (...), "donate": (...)}
        self.jitted_names: dict[tuple, dict] = {}
        self._derive_only: dict[tuple, bool] = {}

    def add(self, path: str, source: str) -> Module:
        mod = Module(path, source)
        self.modules[path] = mod
        self.by_modname[_module_name(path)] = mod
        return mod

    def finish(self):
        for mod in self.modules.values():
            self._scan_frozen(mod)
        for mod in self.modules.values():
            self._scan_jit(mod)

    # -- frozen dataclasses ------------------------------------------------
    def _scan_frozen(self, mod: Module):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                qual = mod.dotted(dec.func)
                if qual in ("dataclasses.dataclass", "dataclass"):
                    for kw in dec.keywords:
                        if (kw.arg == "frozen"
                                and isinstance(kw.value, ast.Constant)
                                and kw.value.value is True):
                            self.frozen_classes.add(node.name)

    # -- jit roots ---------------------------------------------------------
    def _is_jit_expr(self, mod: Module, node: ast.AST) -> bool:
        qual = mod.dotted(node)
        return qual in ("jax.jit", "jit", "jax.pmap", "pjit.pjit")

    def _unwrap(self, mod: Module, scope_funcs: dict[str, ast.AST], node):
        """Chase ``jax.jit``'s argument through transparent wrappers and
        same-scope assignments to the underlying def/lambda/target."""
        for _ in range(8):
            if isinstance(node, ast.Call):
                qual = mod.dotted(node.func)
                if qual in TRANSPARENT_WRAPPERS and node.args:
                    node = node.args[0]
                    continue
                return None
            if isinstance(node, ast.Name) and node.id in scope_funcs:
                node = scope_funcs[node.id]
                continue
            return node
        return node

    def _mark_root(self, mod: Module, target: ast.AST | None, cls: str | None):
        if target is None:
            return
        if isinstance(target, ast.Lambda):
            self.jit_lambdas.append((mod, target))
            return
        if isinstance(target, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for fn in mod.by_name.get(target.name, ()):
                if fn.node is target:
                    self.jit_roots.append(fn)
            return
        name = None
        if isinstance(target, ast.Name):
            name = target.id
        elif (isinstance(target, ast.Attribute)
              and isinstance(target.value, ast.Name)
              and target.value.id == "self"):
            name = target.attr
        if name is not None:
            for fn in mod.by_name.get(name, ()):
                if cls is None or fn.cls in (None, cls):
                    self.jit_roots.append(fn)

    def _jit_call_info(self, call: ast.Call) -> dict:
        info = {"static": (), "donate": ()}
        for kw in call.keywords:
            if kw.arg in ("static_argnums", "donate_argnums"):
                key = "static" if kw.arg == "static_argnums" else "donate"
                if isinstance(kw.value, (ast.Tuple, ast.List)):
                    vals = tuple(e.value for e in kw.value.elts
                                 if isinstance(e, ast.Constant))
                elif isinstance(kw.value, ast.Constant):
                    vals = (kw.value.value,)
                else:
                    vals = ()
                info[key] = vals
        return info

    def _scan_jit(self, mod: Module):
        # forced roots: "# lint: jit-root" on the def line
        for fn in mod.funcs:
            ln = getattr(fn.node, "lineno", 0)
            if 0 < ln <= len(mod.lines) and "# lint: jit-root" in mod.lines[ln - 1]:
                self.jit_roots.append(fn)
        for fn in mod.funcs:
            node = fn.node
            scope_funcs = {f.name: f.node for f in mod.funcs}
            for dec in getattr(node, "decorator_list", ()):
                dec_fn = dec.func if isinstance(dec, ast.Call) else dec
                if self._is_jit_expr(mod, dec_fn):
                    self.jit_roots.append(fn)
                elif (isinstance(dec, ast.Call)
                      and mod.dotted(dec.func) in TRANSPARENT_WRAPPERS
                      and dec.args and self._is_jit_expr(mod, dec.args[0])):
                    self.jit_roots.append(fn)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if not (isinstance(call, ast.Call) and self._is_jit_expr(mod, call.func)):
                continue
            scope_funcs = {f.name: f.node for f in mod.funcs}
            enclosing_cls = self._enclosing_class(mod, node)
            if call.args:
                self._mark_root(mod, self._unwrap(mod, scope_funcs, call.args[0]),
                                enclosing_cls)
            info = self._jit_call_info(call)
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.jitted_names[("local", mod.path, tgt.id)] = info
                elif (isinstance(tgt, ast.Attribute)
                      and isinstance(tgt.value, ast.Name)
                      and tgt.value.id == "self" and enclosing_cls):
                    self.jitted_names[("attr", mod.path, enclosing_cls,
                                       tgt.attr)] = info

    def _enclosing_class(self, mod: Module, node: ast.AST) -> str | None:
        for fn in mod.funcs:
            if fn.cls is None:
                continue
            f = fn.node
            if (f.lineno <= node.lineno
                    and node.lineno <= (f.end_lineno or f.lineno)):
                return fn.cls
        return None

    # -- call resolution ---------------------------------------------------
    def resolve_call(self, mod: Module, caller: Func, call: ast.Call) -> list[Func]:
        """Callee candidates for one call site (possibly empty)."""
        func = call.func
        # self.method(...) -> same-class methods in this module
        if (isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name)
                and func.value.id == "self" and caller.cls):
            return [f for f in mod.by_name.get(func.attr, ())
                    if f.cls == caller.cls]
        qual = mod.dotted(func)
        if qual is None:
            return []
        if "." not in qual:
            # bare name: module-local defs (any nesting level)
            return list(mod.by_name.get(qual, ()))
        target_mod, _, fname = qual.rpartition(".")
        other = self.by_modname.get(target_mod)
        if other is None and qual in (f"{m}.{base_name(qual)}"
                                      for m in self.by_modname):
            other = self.by_modname.get(target_mod)
        if other is not None:
            return [f for f in other.by_name.get(fname, ()) if f.cls is None]
        # "from repro.x import fn" -> qual is "repro.x.fn" with module repro.x
        return []

    # -- derive-only key parameters ----------------------------------------
    def derive_only(self, fn: Func, param: str) -> bool:
        """True when ``fn`` only ever *derives* from ``param`` (split /
        fold_in / named folds) — handing a key to such a callee is itself
        a derivation, not a spend.  This is the repo's named-folding
        idiom: ``segment_grads`` folds ``rng`` per segment name and
        ``embed_grads`` folds ``"embed"``, so both may safely share one
        base key."""
        cache_key = (id(fn.node), param)
        cached = self._derive_only.get(cache_key)
        if cached is not None:
            return cached
        # optimistic on recursion: a cycle with no direct draw derives only
        self._derive_only[cache_key] = True
        result = self._derive_only_scan(fn, param)
        self._derive_only[cache_key] = result
        return result

    def _derive_only_scan(self, fn: Func, param: str) -> bool:
        mod = fn.module
        parents: dict[int, ast.AST] = {}
        for node in ast.walk(fn.node):
            for child in ast.iter_child_nodes(node):
                parents[id(child)] = node
        for node in ast.walk(fn.node):
            if not (isinstance(node, ast.Name) and node.id == param
                    and isinstance(node.ctx, ast.Load)):
                continue
            parent = parents.get(id(node))
            call = None
            if isinstance(parent, ast.Call) and node in parent.args:
                call = parent
            elif isinstance(parent, ast.keyword):
                grand = parents.get(id(parent))
                if isinstance(grand, ast.Call):
                    call = grand
            if call is None:
                return False  # returned, stored, drawn from, ...
            qual = mod.dotted(call.func) or ""
            if qual in DERIVE_QUALS:
                continue
            callees = self.resolve_call(mod, fn, call)
            if not callees:
                return False
            for callee in callees:
                pname = param_for_arg(callee, call, node)
                if pname is None or not self.derive_only(callee, pname):
                    return False
        return True


def param_for_arg(callee: Func, call: ast.Call,
                  name_node: ast.Name) -> str | None:
    """Name of the callee parameter receiving ``name_node`` at this site."""
    args = callee.node.args
    params = [a.arg for a in list(args.posonlyargs) + list(args.args)]
    if params and params[0] == "self" and isinstance(call.func, ast.Attribute):
        params = params[1:]
    for i, a in enumerate(call.args):
        if a is name_node:
            return params[i] if i < len(params) else None
    for kw in call.keywords:
        if kw.value is name_node:
            return kw.arg
    return None


def load_project(paths: list[str]) -> Project:
    """Build a Project from files and/or directories of ``.py`` sources."""
    proj = Project()
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                if "__pycache__" in root:
                    continue
                files.extend(os.path.join(root, n)
                             for n in names if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    for path in sorted(set(files)):
        with open(path, encoding="utf-8") as f:
            source = f.read()
        try:
            proj.add(os.path.relpath(path), source)
        except SyntaxError:
            continue  # not our diagnostic to raise
    proj.finish()
    return proj


def project_from_sources(sources: dict[str, str]) -> Project:
    """In-memory project (test fixtures)."""
    proj = Project()
    for path, src in sources.items():
        proj.add(path, src)
    proj.finish()
    return proj
