"""Per-function rules: RL001 (PRNG key discipline), RL004 (frozen-config
and carried-state mutation), RL005 (donated-buffer reads).

RL001 runs a small flow-aware scan over each function body.  Every
assignment mints a fresh *version* of a name; versions created from
key-producing calls (``jax.random.PRNGKey``/``split``/``fold_in``,
``repro.utils.prng.*``) are key-typed.  A key version is *spent* by a
``jax.random`` draw, or by being handed to an unresolved call (the
callee will draw from it — ``bank_product(a, b, cfg, key)`` spends
``key``).  Derivations (``split``/``fold_in``/…) read without spending:
``fold_in(key, 1)`` then ``fold_in(key, 2)`` is the intended idiom.
Spending a version twice flags the second site; ``prng.consume(key)``
kills the version outright so ANY later use flags.  ``if``/``else``
branches are scanned against copies and merged by max (only one branch
executes); loop bodies are scanned twice so a loop-invariant key drawn
each iteration is caught on the second pass.
"""

from __future__ import annotations

import ast
import dataclasses
import itertools

from repro.lint.analysis import (CONSUME_QUALS, DERIVE_QUALS, DRAW_QUALS,
                                 KEY_PRODUCERS, Func, Module, Project,
                                 param_for_arg)
from repro.lint.findings import Finding


def _src(mod: Module, node: ast.AST) -> str:
    ln = getattr(node, "lineno", 0)
    if 0 < ln <= len(mod.lines):
        return mod.lines[ln - 1].strip()
    return ""


# =========================================================================
# RL001 — PRNG key discipline
# =========================================================================


@dataclasses.dataclass
class _KeyVersion:
    name: str
    vid: int
    spends: int = 0
    dead: bool = False
    dead_site: str = ""


class _KeyScan:
    def __init__(self, proj: Project, mod: Module, fn: Func,
                 findings: list[Finding]):
        self.proj = proj
        self.mod = mod
        self.fn = fn
        self.findings = findings
        self.vids = itertools.count()
        self.reported: set[tuple[int, int]] = set()  # (lineno, version id)

    # -- environment helpers ----------------------------------------------
    def fresh(self, env, name: str, is_key: bool):
        env[name] = _KeyVersion(name, next(self.vids)) if is_key else None

    def flag(self, node: ast.AST, ver: _KeyVersion, why: str):
        site = (node.lineno, ver.vid)
        if site in self.reported:
            return
        self.reported.add(site)
        self.findings.append(Finding(
            "RL001", self.mod.path, node.lineno,
            f"PRNG key `{ver.name}` {why} in {self.fn.qualname} — "
            "split/fold_in a fresh key per draw (utils.prng)",
            _src(self.mod, node)))

    # -- expression scan ---------------------------------------------------
    def _key_args(self, call: ast.Call) -> list[tuple[ast.Name, bool]]:
        """(name-node, is_first_or_key_kwarg) for plain-Name arguments."""
        out = []
        for i, a in enumerate(call.args):
            if isinstance(a, ast.Name):
                out.append((a, i == 0))
        for kw in call.keywords:
            if isinstance(kw.value, ast.Name):
                out.append((kw.value, kw.arg in ("key", "rng", "seed")))
        return out

    def scan_expr(self, node: ast.AST, env: dict) -> bool:
        """Scan one expression; returns True when it produces a key value."""
        for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
            qual = self.mod.dotted(call.func) or ""
            is_draw = qual in DRAW_QUALS
            is_derive = qual in DERIVE_QUALS
            is_consume = qual in CONSUME_QUALS
            for name_node, in_key_pos in self._key_args(call):
                ver = env.get(name_node.id)
                if ver is None:
                    continue
                if ver.dead:
                    self.flag(call, ver,
                              f"used after prng.consume ({ver.dead_site})")
                    continue
                if is_consume and in_key_pos:
                    ver.dead = True
                    ver.dead_site = f"line {call.lineno}"
                elif is_draw and in_key_pos:
                    ver.spends += 1
                    if ver.spends > 1:
                        self.flag(call, ver,
                                  "feeds a second random draw with no "
                                  "intervening split/fold_in")
                elif is_derive:
                    pass  # reading a key to mint new ones is the idiom
                else:
                    # unresolved callee given a key: assume it draws once —
                    # unless it resolves to a project fn that only derives
                    if (self._takes_key(call, name_node)
                            and not self._callee_derives_only(call, name_node)):
                        ver.spends += 1
                        if ver.spends > 1:
                            self.flag(call, ver,
                                      "is handed to a second consumer with "
                                      "no intervening split/fold_in")
        return self._produces_key(node, env)

    def _produces_key(self, node: ast.AST, env: dict) -> bool:
        """Key-typedness of the expression ROOT only — a PRNGKey buried in
        an argument list (``jax.eval_shape(init, PRNGKey(0))``) does not
        make the assigned value a key."""
        while isinstance(node, ast.Subscript):
            node = node.value  # split(key, 2)[0] is a key
        if isinstance(node, ast.Call):
            qual = self.mod.dotted(node.func) or ""
            return qual in KEY_PRODUCERS or qual in CONSUME_QUALS
        if isinstance(node, ast.Name):
            return env.get(node.id) is not None  # alias keeps key-typedness
        return False

    def _callee_derives_only(self, call: ast.Call,
                             name_node: ast.Name) -> bool:
        callees = self.proj.resolve_call(self.mod, self.fn, call)
        if not callees:
            return False
        for callee in callees:
            pname = param_for_arg(callee, call, name_node)
            if pname is None or not self.proj.derive_only(callee, pname):
                return False
        return True

    def _takes_key(self, call: ast.Call, name_node: ast.Name) -> bool:
        """Heuristic: a key passed positionally or as key=/rng= to an
        unknown callee is consumed there.  Attribute reads like
        ``state["hw"]`` or prints are not calls and never reach here."""
        for kw in call.keywords:
            if kw.value is name_node:
                return kw.arg in ("key", "rng")
        return name_node in call.args

    # -- statement scan ----------------------------------------------------
    def scan_block(self, stmts, env: dict):
        for stmt in stmts:
            self.scan_stmt(stmt, env)

    def _assign_targets(self, target, env, is_key: bool):
        if isinstance(target, ast.Name):
            self.fresh(env, target.id, is_key)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._assign_targets(elt, env, is_key)

    def scan_stmt(self, stmt, env: dict):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: scanned as its own function by run_rl001
            return
        if isinstance(stmt, ast.Assign):
            is_key = self.scan_expr(stmt.value, env)
            for t in stmt.targets:
                self._assign_targets(t, env, is_key)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                is_key = self.scan_expr(stmt.value, env)
            else:
                is_key = False
            self._assign_targets(stmt.target, env, is_key)
        elif isinstance(stmt, ast.If):
            self.scan_expr(stmt.test, env)
            b_env = self._copy(env)
            o_env = self._copy(env)
            self.scan_block(stmt.body, b_env)
            self.scan_block(stmt.orelse, o_env)
            b_term = self._terminates(stmt.body)
            o_term = self._terminates(stmt.orelse)
            if b_term and not o_term:
                # early return/raise: spends in the body never reach here
                env.clear()
                env.update(o_env)
            elif o_term and not b_term:
                env.clear()
                env.update(b_env)
            elif not b_term:  # neither terminates: join
                self._merge(env, b_env, o_env)
            # both terminate -> fall-through is unreachable; env moot
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.scan_expr(stmt.iter, env)
            self._assign_targets(stmt.target, env, False)
            for _ in range(2):  # second pass: loop-invariant key reuse
                body_env = self._copy(env)
                self.scan_block(stmt.body, body_env)
                self._merge(env, body_env, body_env)
            self.scan_block(stmt.orelse, env)
        elif isinstance(stmt, ast.While):
            self.scan_expr(stmt.test, env)
            for _ in range(2):
                body_env = self._copy(env)
                self.scan_block(stmt.body, body_env)
                self._merge(env, body_env, body_env)
            self.scan_block(stmt.orelse, env)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                is_key = self.scan_expr(item.context_expr, env)
                if item.optional_vars is not None:
                    self._assign_targets(item.optional_vars, env, is_key)
            self.scan_block(stmt.body, env)
        elif isinstance(stmt, ast.Try):
            self.scan_block(stmt.body, env)
            for h in stmt.handlers:
                self.scan_block(h.body, self._copy(env))
            self.scan_block(stmt.orelse, env)
            self.scan_block(stmt.finalbody, env)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self.scan_expr(stmt.value, env)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.scan_expr(child, env)

    @staticmethod
    def _terminates(stmts) -> bool:
        return bool(stmts) and isinstance(
            stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))

    @staticmethod
    def _copy(env: dict) -> dict:
        return {k: (dataclasses.replace(v) if v is not None else None)
                for k, v in env.items()}

    @staticmethod
    def _merge(env: dict, a: dict, b: dict):
        """Join branch environments: spends by max (one branch runs),
        dead if dead on any path, drop names whose versions diverged."""
        for k in list(env):
            ver = env.get(k)
            if ver is None:
                continue
            va, vb = a.get(k), b.get(k)
            if va is None or vb is None or va.vid != ver.vid or vb.vid != ver.vid:
                env[k] = None  # rebound in a branch — unknown afterwards
                continue
            ver.spends = max(va.spends, vb.spends)
            ver.dead = va.dead or vb.dead
            ver.dead_site = va.dead_site or vb.dead_site


_KEYISH_PARAMS = ("key", "rng", "prng_key", "rngs", "seed_key")
# predicate-style prefixes: `is_key`, `has_key`, ... are booleans, not keys
_NOT_KEY_PREFIXES = ("is_", "has_", "use_", "with_", "as_", "no_")


def _param_is_keyish(name: str) -> bool:
    if name in _KEYISH_PARAMS:
        return True
    return name.endswith("_key") and not name.startswith(_NOT_KEY_PREFIXES)


def run_rl001(proj: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in proj.modules.values():
        for fn in mod.funcs:
            scan = _KeyScan(proj, mod, fn, findings)
            env: dict = {}
            args = fn.node.args
            for a in (list(args.posonlyargs) + list(args.args)
                      + list(args.kwonlyargs)):
                # parameters that look like keys participate from the start
                if _param_is_keyish(a.arg):
                    scan.fresh(env, a.arg, True)
            body = fn.node.body if not isinstance(fn.node, ast.Lambda) else []
            scan.scan_block(body, env)
    return findings


# =========================================================================
# RL004 — frozen-config mutation and dict-mutation of carried state
# =========================================================================

_DICT_MUTATORS = ("update", "pop", "clear", "setdefault", "popitem")


def _annotation_name(node) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.split(".")[-1].strip()
    return None


def run_rl004(proj: Project, jit_reachable) -> list[Finding]:
    findings: list[Finding] = []
    frozen = proj.frozen_classes
    for mod in proj.modules.values():
        for fn in mod.funcs:
            if isinstance(fn.node, ast.Lambda):
                continue
            # (a) frozen-dataclass attribute assignment
            frozen_vars: set[str] = set()
            args = fn.node.args
            for a in (list(args.posonlyargs) + list(args.args)
                      + list(args.kwonlyargs)):
                ann = _annotation_name(a.annotation)
                if ann in frozen:
                    frozen_vars.add(a.arg)
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                    qual = mod.dotted(node.value.func) or ""
                    name = qual.rsplit(".", 1)[-1]
                    tgt_frozen = name in frozen or (
                        qual in ("dataclasses.replace", "replace")
                        and node.value.args
                        and isinstance(node.value.args[0], ast.Name)
                        and node.value.args[0].id in frozen_vars)
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            if tgt_frozen:
                                frozen_vars.add(t.id)
                            else:
                                frozen_vars.discard(t.id)
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            frozen_vars.discard(t.id)
            for node in ast.walk(fn.node):
                if not isinstance(node, (ast.Assign, ast.AugAssign)):
                    continue
                targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id in frozen_vars):
                        findings.append(Finding(
                            "RL004", mod.path, node.lineno,
                            f"mutation of frozen config `{t.value.id}.{t.attr}` "
                            f"in {fn.qualname} — use dataclasses.replace",
                            _src(mod, node)))
            # (b) dict-mutation of traced inputs (carried state) in jit code
            if fn not in jit_reachable:
                continue
            params = {a.arg for a in (list(args.posonlyargs) + list(args.args)
                                      + list(args.kwonlyargs))} - {"self"}
            aliases = set(params)
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Assign):
                    src_alias = (isinstance(node.value, ast.Name)
                                 and node.value.id in aliases)
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            if src_alias:
                                aliases.add(t.id)
                            else:
                                aliases.discard(t.id)
            for node in ast.walk(fn.node):
                bad = None
                if (isinstance(node, ast.Assign)
                        and any(isinstance(t, ast.Subscript)
                                and isinstance(t.value, ast.Name)
                                and t.value.id in aliases
                                for t in node.targets)):
                    bad = "item assignment into"
                elif (isinstance(node, ast.Delete)
                      and any(isinstance(t, ast.Subscript)
                              and isinstance(t.value, ast.Name)
                              and t.value.id in aliases
                              for t in node.targets)):
                    bad = "del on"
                elif (isinstance(node, ast.Call)
                      and isinstance(node.func, ast.Attribute)
                      and node.func.attr in _DICT_MUTATORS
                      and isinstance(node.func.value, ast.Name)
                      and node.func.value.id in aliases
                      # .pop with no mutation intent is still mutation; but
                      # reads like .get/.items never reach here
                      ):
                    bad = f".{node.func.attr}() on"
                if bad is not None:
                    name = None
                    for n in ast.walk(node):
                        if isinstance(n, ast.Name) and n.id in aliases:
                            name = n.id
                            break
                    findings.append(Finding(
                        "RL004", mod.path, node.lineno,
                        f"{bad} traced input `{name}` in jit-reachable "
                        f"{fn.qualname} — carried-state pytrees must be "
                        "rebuilt, not mutated (structure/donation hazards)",
                        _src(mod, node)))
    return findings


# =========================================================================
# RL005 — donation hazards (read-after-donate)
# =========================================================================


def _stmt_reads_writes(stmt) -> tuple[set[str], set[str]]:
    reads, writes = set(), set()
    for node in ast.walk(stmt):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Store):
                writes.add(node.id)
            elif isinstance(node.ctx, ast.Load):
                reads.add(node.id)
    return reads, writes


def _linear_stmts(body) -> list:
    """Flatten a body into source-ordered statements (branch bodies are
    visited in order — over-approximate but deterministic)."""
    out = []
    for stmt in body:
        out.append(stmt)
        for field in ("body", "orelse", "finalbody"):
            out.extend(_linear_stmts(getattr(stmt, field, []) or []))
        for h in getattr(stmt, "handlers", []) or []:
            out.extend(_linear_stmts(h.body))
    return out


def run_rl005(proj: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in proj.modules.values():
        for fn in mod.funcs:
            if isinstance(fn.node, ast.Lambda):
                continue
            stmts = _linear_stmts(fn.node.body)
            for idx, stmt in enumerate(stmts):
                for call in [n for n in ast.walk(stmt)
                             if isinstance(n, ast.Call)]:
                    info = self_or_local_jit_info(proj, mod, fn, call)
                    if not info or not info.get("donate"):
                        continue
                    donated_vars = set()
                    for pos in info["donate"]:
                        if (isinstance(pos, int) and pos < len(call.args)
                                and isinstance(call.args[pos], ast.Name)):
                            donated_vars.add(call.args[pos].id)
                    if not donated_vars:
                        continue
                    # rebinding in the same statement covers the idiom
                    # `state, m = fit_step(state, batch)`
                    _, writes = _stmt_reads_writes(stmt)
                    donated_vars -= writes
                    live = set(donated_vars)
                    for later in stmts[idx + 1:]:
                        if not live:
                            break
                        reads, writes = _stmt_reads_writes(later)
                        for v in sorted(live & reads):
                            findings.append(Finding(
                                "RL005", mod.path, later.lineno,
                                f"`{v}` read after being donated at line "
                                f"{call.lineno} (donate_argnums) in "
                                f"{fn.qualname} — donated buffers are "
                                "invalidated by the call",
                                _src(mod, later)))
                        live -= reads | writes
    return findings


def self_or_local_jit_info(proj: Project, mod: Module, fn, call: ast.Call):
    func = call.func
    if isinstance(func, ast.Name):
        return proj.jitted_names.get(("local", mod.path, func.id))
    if (isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name)
            and func.value.id == "self" and fn.cls):
        return proj.jitted_names.get(("attr", mod.path, fn.cls, func.attr))
    return None
