"""BENCH_*.json serialization — the repo's perf trajectory format.

Every benchmark emission goes through one stable schema so CI can archive
the files and later PRs can be judged against the recorded numbers::

    {
      "schema":  "repro.bench/v1",
      "name":    "train_throughput",          # -> BENCH_train_throughput.json
      "created_unix": 1722470400.0,
      "env":     {"jax": "0.4.37", "backend": "cpu", "device_count": 8,
                  "python": "3.10.14"},
      "metrics": {"steps_per_s": 12.5, ...},  # numbers only, all finite
      "meta":    {...}                        # free-form provenance
    }

``validate`` raises ValueError on anything that doesn't round-trip, so a
schema drift breaks tests/CI instead of silently corrupting the trajectory.
"""

from __future__ import annotations

import json
import math
import os
import platform
import time

SCHEMA = "repro.bench/v1"
_PREFIX = "BENCH_"


def environment() -> dict:
    import jax

    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
    }


def make_report(name: str, metrics: dict, meta: dict | None = None) -> dict:
    return validate({
        "schema": SCHEMA,
        "name": name,
        "created_unix": time.time(),
        "env": environment(),
        "metrics": dict(metrics),
        "meta": dict(meta or {}),
    })


def validate(report: dict) -> dict:
    """Check the stable schema; returns the report or raises ValueError."""
    if not isinstance(report, dict):
        raise ValueError(f"bench report must be a dict, got {type(report)}")
    if report.get("schema") != SCHEMA:
        raise ValueError(
            f"bench schema mismatch: {report.get('schema')!r} != {SCHEMA!r}")
    name = report.get("name")
    if not isinstance(name, str) or not name:
        raise ValueError(f"bench name must be a non-empty str, got {name!r}")
    if not isinstance(report.get("created_unix"), (int, float)):
        raise ValueError("bench created_unix must be a unix timestamp")
    if not isinstance(report.get("env"), dict):
        raise ValueError("bench env must be a dict")
    metrics = report.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        raise ValueError("bench metrics must be a non-empty dict")
    for k, v in metrics.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            raise ValueError(f"bench metric {k!r} must be a number, got {v!r}")
        if not math.isfinite(v):
            raise ValueError(f"bench metric {k!r} is not finite: {v!r}")
    if not isinstance(report.get("meta", {}), dict):
        raise ValueError("bench meta must be a dict")
    return report


def bench_path(name: str, out_dir: str = ".") -> str:
    return os.path.join(out_dir, f"{_PREFIX}{name}.json")


def write_bench(name: str, metrics: dict, meta: dict | None = None,
                out_dir: str = ".") -> str:
    """Validate + serialize one report; returns the BENCH_<name>.json path."""
    report = make_report(name, metrics, meta)
    os.makedirs(out_dir, exist_ok=True)
    path = bench_path(name, out_dir)
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_bench(path: str) -> dict:
    with open(path) as f:
        return validate(json.load(f))


def _shard_multiplier(mesh, batch) -> int:
    """Devices the step's flops are split over: the mesh size only when the
    batch dim actually sharded; the divisibility fallback replicates the
    batch, so each device computes full-batch flops and the multiplier is 1
    (anything else records a phantom mesh-size speedup in the BENCH json)."""
    if mesh is None:
        return 1
    import jax

    from repro.dist.sharding import make_batch_shardings

    specs = [s.spec for s in jax.tree_util.tree_leaves(
        make_batch_shardings(mesh, batch))]
    batched = [s for s in specs if len(s) >= 1]  # scalar leaves can't shard
    if batched and all(s[0] is not None for s in batched):
        return int(mesh.devices.size)
    return 1


def report_throughput(session, state, batch, timer, meta: dict | None = None,
                      out_dir: str = ".") -> tuple[str, dict]:
    """Finish a timed ``session.fit``: attach the step's per-device HLO cost
    to ``timer`` (device count = the devices the batch is actually split
    over — utils.hlo_cost reports post-SPMD per-device flops), write
    BENCH_train_throughput.json, and print the headline numbers."""
    n_dev = _shard_multiplier(session.mesh, batch)
    timer.set_step_cost(session.step_cost(state, batch).flops,
                        device_count=n_dev)
    summary = timer.summary()
    base = {"data_parallel": session.mesh is not None, "devices": int(n_dev)}
    base.update(meta or {})
    path = write_bench("train_throughput", summary, meta=base, out_dir=out_dir)
    print(f"[bench] {path}: steps/s={summary['steps_per_s']:.2f} "
          f"examples/s={summary.get('examples_per_s', 0):.0f} "
          f"MACs/s={summary.get('macs_per_s', 0):.3e} devices={n_dev}",
          flush=True)
    return path, summary


def clamped_warmup(total_steps: int, target: int) -> int:
    """Warmup steps for a StepTimer over a ``total_steps`` fit: at least one
    step must remain measured, however short the run."""
    return max(0, min(target, total_steps - 1))
