"""repro.bench — throughput telemetry (StepTimer) and the BENCH_*.json
perf-trajectory format that benchmarks/run.py emits and CI archives."""

from repro.bench.report import (SCHEMA, bench_path, clamped_warmup,
                                load_bench, make_report, report_throughput,
                                validate, write_bench)
from repro.bench.telemetry import StepTimer

__all__ = ["SCHEMA", "StepTimer", "bench_path", "clamped_warmup",
           "load_bench", "make_report", "report_throughput", "validate",
           "write_bench"]
