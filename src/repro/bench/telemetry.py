"""Step-level throughput telemetry.

The paper's headline is throughput (trillions of MACs/s at <1 pJ/MAC), so
every perf claim in this repo is anchored to measured numbers: a
``StepTimer`` threaded through ``Trainer.fit`` records the wall time of
each step *after* ``jax.block_until_ready`` (async dispatch otherwise makes
per-step timing meaningless), discards the warmup steps that pay jit
compilation, and derives

* ``steps_per_s``     — 1 / mean measured step time
* ``examples_per_s``  — steps/s × global batch size
* ``macs_per_s``      — steps/s × per-device MACs (utils.hlo_cost flops / 2)
                        × device count

``bench.report`` serializes the summary as BENCH_*.json for CI to archive.
"""

from __future__ import annotations

import time

import jax


def _percentile(sorted_vals: list, q: float) -> float:
    """Nearest-rank percentile of an already-sorted non-empty list."""
    idx = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return float(sorted_vals[idx])


class StepTimer:
    """Wall-time-per-step recorder for ``Trainer.fit(..., timer=...)``.

    Usage::

        timer = StepTimer(warmup=4)
        session.fit(data_fn, total_steps=32, timer=timer)
        timer.set_step_cost(flops_per_device=cost.flops)
        summary = timer.summary()   # steps_per_s, examples_per_s, macs_per_s
    """

    def __init__(self, warmup: int = 2, examples_per_step: int | None = None):
        self.warmup = max(0, int(warmup))
        self.examples_per_step = examples_per_step
        self.times: list[float] = []  # post-warmup step wall times (s)
        self._seen = 0
        self._last: float | None = None
        self._flops_per_device: float | None = None
        self._device_count: int | None = None

    # ---- recording (called by the fit loop) ----
    def start(self) -> None:
        self._last = time.perf_counter()

    def tick(self, sync=None) -> None:
        """Record one step boundary; ``sync`` (any pytree) is blocked on so
        the measurement covers the device compute, not just dispatch."""
        if sync is not None:
            jax.block_until_ready(sync)
        now = time.perf_counter()
        if self._last is not None:
            self._seen += 1
            if self._seen > self.warmup:
                self.times.append(now - self._last)
        self._last = now

    # ---- derived cost ----
    def set_step_cost(self, flops_per_device: float,
                      device_count: int | None = None) -> None:
        """Attach the per-device HLO flops of one step (utils.hlo_cost) so
        summary() can derive model MACs/s (1 MAC = 2 flops).

        ``device_count`` must be the number of devices the step is actually
        sharded over (the Trainer's mesh size; 1 without a mesh) — NOT the
        host's device count, which would overcount un-sharded runs.  Default
        is 1; bench.report_throughput passes the mesh size."""
        self._flops_per_device = float(flops_per_device)
        self._device_count = device_count

    # ---- results ----
    @property
    def recorded_steps(self) -> int:
        return len(self.times)

    def summary(self) -> dict:
        if not self.times:
            raise ValueError(
                f"StepTimer has no measured steps (saw {self._seen}, "
                f"warmup {self.warmup}) — run more steps or lower warmup")
        srt = sorted(self.times)
        mean = sum(self.times) / len(self.times)
        steps_per_s = 1.0 / mean
        out = {
            "steps_measured": len(self.times),
            "warmup_steps": self.warmup,
            "mean_step_s": mean,
            "p50_step_s": _percentile(srt, 0.50),
            "p90_step_s": _percentile(srt, 0.90),
            "min_step_s": srt[0],
            "steps_per_s": steps_per_s,
        }
        if self.examples_per_step is not None:
            out["examples_per_step"] = int(self.examples_per_step)
            out["examples_per_s"] = steps_per_s * self.examples_per_step
        if self._flops_per_device is not None:
            n_dev = self._device_count or 1
            out["flops_per_step_per_device"] = self._flops_per_device
            out["device_count"] = int(n_dev)
            out["macs_per_s"] = steps_per_s * (self._flops_per_device / 2.0) * n_dev
        return out
