"""Direct Feedback Alignment training engine (the paper's algorithm).

For every block k the gradient is computed from the *output error only*
(paper Eq. 1):   δ(k) = B(k)·e  ⊙ local-derivative, realised as

    δ(k) = photonic_project(e, B(k))       # the MRR weight-bank product,
                                           # with measured analog noise
    grads(k) = local_vjp(block_k, x_k)(δ(k))   # exact *within* the block

The per-layer loop is a ``lax.map`` with **no loop-carried dependency** —
unlike backprop there is no sequential chain, which is the systems property
the paper exploits (all layers updated in parallel during the backward
pass).  The error is computed once and broadcast; under a sharded mesh this
is ONE collective instead of backprop's L chained backward matmuls.

For an MLP of DenseBlocks this reduces *exactly* to the paper's update:
local vjp through the activation contributes the ⊙ g'(a) Hadamard, and
grad_W = (B e ⊙ g'(a)) · h_inᵀ.

Error compression (`ternary` per the paper's ref [48], or `int8`) is applied
to e before projection/broadcast — the gradient-compression knob for
distributed training.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import feedback as fb_lib
from repro.core import photonics
from repro.utils import prng


@dataclasses.dataclass(frozen=True)
class DFAConfig:
    photonics: photonics.PhotonicConfig = dataclasses.field(
        default_factory=lambda: photonics.PRESETS["ideal"]
    )
    feedback: fb_lib.FeedbackConfig = dataclasses.field(
        default_factory=fb_lib.FeedbackConfig
    )
    error_compress: str = "none"  # none | ternary | int8
    impl: str = "auto"  # photonic projection impl: auto | ref | kernel
    sequential: bool = False  # lax.map (False: still sequential in schedule,
    # but dependency-free; kept for clarity/ablation hooks)
    # Freeze norm scales in DFA blocks.  The cotangent at each norm output
    # exists ONLY to produce the norm-scale gradient (DFA discards input
    # cotangents), yet it costs a (B,S,D) model-axis all-reduce per matmul
    # group per layer.  Freezing norms DCEs those all-reduces (§Perf G1);
    # norm scales stay at init (a documented training-semantics trade).
    freeze_norms: bool = False


_NORM_PAT = ("norm", "ln1", "ln2", "ln3", "ln_enc", "/ln/")


def _is_norm_path(path: str) -> bool:
    return any(p in path for p in _NORM_PAT)


def freeze_norm_leaves(tree):
    """stop_gradient on norm-scale leaves: their grads become zero and XLA
    dead-code-eliminates the (B,S,D) all-reduces that fed them."""
    from repro.utils.tree import path_map

    return path_map(
        lambda p, x: jax.lax.stop_gradient(x) if _is_norm_path(p) else x, tree)


def compress_error(e, mode: str):
    """Compress the error before broadcast/projection (ref [48])."""
    if mode == "none":
        return e
    if mode == "ternary":
        # sparse ternarisation: keep only errors well above the mean
        # (swept in EXPERIMENTS.md — tau=2.0 best at 0.25 B/element;
        # denser ternary loses more accuracy at equal steps)
        a = jnp.abs(e)
        tau = 2.0 * jnp.mean(a)
        keep = a > tau
        scale = jnp.sum(a * keep) / jnp.maximum(jnp.sum(keep), 1.0)
        return jnp.sign(e) * keep * scale
    if mode == "int8":
        amax = jnp.maximum(jnp.max(jnp.abs(e)), 1e-12)
        q = jnp.round(jnp.clip(e / amax, -1, 1) * 127.0)
        return (q / 127.0 * amax).astype(e.dtype)
    raise ValueError(f"unknown error_compress {mode!r}")


def init_feedback(model, key, cfg: DFAConfig):
    """Fixed random feedback for every segment + the embed path."""
    d_tap = model.d_tap
    fb = {}
    for spec in model.segment_specs():
        fb[spec.name] = fb_lib.make_feedback(
            prng.fold_name(key, spec.name), spec.n_layers, spec.d_inject, d_tap,
            cfg.feedback,
        )
    # embed feedback: inject at embed output (d_inject of first segment)
    first = model.segment_specs()[0]
    fb["embed"] = fb_lib.make_feedback(
        prng.fold_name(key, "embed"), 1, first.d_inject, d_tap, cfg.feedback
    )[0]
    return fb


def _project(e, bmat, cfg: DFAConfig, key):
    """δ = e·Bᵀ through the photonic execution model."""
    return photonics.photonic_project(e, bmat, cfg.photonics, key, impl=cfg.impl)


def value_and_grad(model, cfg: DFAConfig):
    """Returns fn(params, fb, batch, rng) -> ((loss, metrics), grads).

    ``grads`` matches the structure of ``params``.  Head gradients are exact;
    segment/embed gradients are DFA (photonic-noisy) per Eq. 1.
    """
    specs = model.segment_specs()

    def fn(params, fb, batch, rng):
        # ---------- forward ----------
        has_embed_params = len(jax.tree_util.tree_leaves(params.get("embed", {}))) > 0
        if has_embed_params:
            x0, embed_vjp = jax.vjp(
                lambda pe: model.embed({**params, "embed": pe}, batch),
                params["embed"],
            )
        else:
            x0 = model.embed(params, batch)
            embed_vjp = None

        x_final, saved, auxes = model.run_segments(params, x0)

        logits, head_vjp = jax.vjp(
            lambda ph, xf: model.head_logits({**params, "head": ph}, xf, batch),
            params["head"], x_final,
        )
        loss, loss_vjp, metrics = jax.vjp(
            lambda lg: model.loss_from_logits(lg, batch), logits, has_aux=True
        )
        (e_logits,) = loss_vjp(jnp.float32(1.0))
        g_head, e_hidden = head_vjp(e_logits)

        e_tap = e_logits if model.error_tap == "logits" else e_hidden
        if model.error_tap == "hidden":
            # broadcast e in the model's compute dtype (the analog encoding
            # is <= 7 effective bits anyway — f32 error transport is waste)
            e_tap = e_tap.astype(x_final.dtype)
        e_tap = compress_error(e_tap, cfg.error_compress)
        # On hardware, e is fetched from SRAM & re-encoded each cycle; it is
        # a constant input to the backward pass — never differentiated.
        e_tap = jax.lax.stop_gradient(e_tap)

        # ---------- DFA backward (layer-parallel: no loop-carried deps) ----
        grads = {"head": g_head}
        for spec in specs:
            tape: "SavedSegment" = saved[spec.name]
            fb_seg = fb[spec.name]
            seg_key = prng.fold_name(rng, spec.name)

            e_seg = spec.adapt_error(e_tap) if spec.adapt_error else e_tap

            def per_layer(xs, spec=spec, fb_seg=fb_seg, seg_key=seg_key,
                          extras=tape.extras, e_seg=e_seg):
                bp, xk, idx = xs
                bmat = fb_lib.feedback_for(fb_seg, idx)
                kk = jax.random.fold_in(seg_key, idx)
                delta = _project(e_seg, bmat, cfg, kk)

                def local(p):
                    from repro.dist.sharding import unshard_fsdp

                    if cfg.freeze_norms:
                        p = freeze_norm_leaves(p)
                    return spec.apply(unshard_fsdp(p), xk, extras)

                (y, _aux), vjp = jax.vjp(local, bp)
                if spec.expand_delta is not None:
                    delta = spec.expand_delta(delta, y.shape)
                else:
                    delta = delta.reshape(y.shape)
                (g,) = vjp((delta.astype(y.dtype), jnp.float32(1.0)))
                return g

            xs = (params[spec.name], tape.inputs, jnp.arange(spec.n_layers))
            grads[spec.name] = jax.lax.map(per_layer, xs)

        # ---------- embed ----------
        if embed_vjp is not None:
            delta0 = model.embed_feedback(
                e_tap, fb["embed"], x0,
                lambda e, b: _project(e, b, cfg, prng.fold_name(rng, "embed")),
            )
            (g_embed,) = embed_vjp(delta0)
            grads["embed"] = g_embed
        elif "embed" in params:
            grads["embed"] = jax.tree_util.tree_map(jnp.zeros_like, params["embed"])

        aux_total = sum(auxes.values()) if auxes else 0.0
        total = loss + aux_total
        metrics = dict(metrics)
        metrics["loss"] = total
        if auxes:
            metrics["aux_loss"] = aux_total
        return (total, metrics), grads

    return fn


def make_fused_train_step(model, cfg: DFAConfig, optimizer):
    """DFA backward with the SGD-momentum update FUSED into the per-layer
    map: each layer's gradient is consumed immediately by its parameter /
    momentum update, so the stacked segment gradients never materialise
    (at kimi-k2 scale that is ~8 GB/device of peak memory).  This is only
    possible because the DFA backward has no inter-layer dependency — the
    update can't invalidate any later backward step.

    optimizer must be SGDM-shaped (lr, momentum, weight_decay fields).
    Returns step(params, fb, opt_state, batch, rng) ->
    (new_params, new_opt_state, loss).
    """
    specs = model.segment_specs()

    def _upd(p, m, g, lr):
        g32 = g.astype(jnp.float32)
        if optimizer.weight_decay:
            g32 = g32 + optimizer.weight_decay * p.astype(jnp.float32)
        m_new = optimizer.momentum * m.astype(jnp.float32) + g32
        p_new = p.astype(jnp.float32) - lr * m_new
        return p_new.astype(p.dtype), m_new.astype(m.dtype)

    def step(params, fb, opt_state, batch, rng):
        opt_step = opt_state["step"] + 1
        lr = optimizer.lr(opt_step) if callable(optimizer.lr) else jnp.float32(optimizer.lr)

        has_embed_params = len(jax.tree_util.tree_leaves(params.get("embed", {}))) > 0
        if has_embed_params:
            x0, embed_vjp = jax.vjp(
                lambda pe: model.embed({**params, "embed": pe}, batch),
                params["embed"])
        else:
            x0 = model.embed(params, batch)
            embed_vjp = None
        x_final, saved, auxes = model.run_segments(params, x0)
        logits, head_vjp = jax.vjp(
            lambda ph, xf: model.head_logits({**params, "head": ph}, xf, batch),
            params["head"], x_final)
        loss, loss_vjp, metrics = jax.vjp(
            lambda lg: model.loss_from_logits(lg, batch), logits, has_aux=True)
        (e_logits,) = loss_vjp(jnp.float32(1.0))
        g_head, e_hidden = head_vjp(e_logits)
        e_tap = e_logits if model.error_tap == "logits" else e_hidden
        if model.error_tap == "hidden":
            e_tap = e_tap.astype(x_final.dtype)
        e_tap = jax.lax.stop_gradient(compress_error(e_tap, cfg.error_compress))

        new_params = dict(params)
        new_mom = dict(opt_state["mom"])
        for spec in specs:
            tape = saved[spec.name]
            fb_seg = fb[spec.name]
            seg_key = prng.fold_name(rng, spec.name)

            def per_layer(xs, spec=spec, fb_seg=fb_seg, seg_key=seg_key,
                          extras=tape.extras):
                bp, mom_p, xk, idx = xs
                bmat = fb_lib.feedback_for(fb_seg, idx)
                kk = jax.random.fold_in(seg_key, idx)
                delta = _project(e_tap, bmat, cfg, kk)

                def local(p):
                    from repro.dist.sharding import unshard_fsdp

                    if cfg.freeze_norms:
                        p = freeze_norm_leaves(p)
                    return spec.apply(unshard_fsdp(p), xk, extras)

                (y, _aux), vjp = jax.vjp(local, bp)
                if spec.expand_delta is not None:
                    delta = spec.expand_delta(delta, y.shape)
                else:
                    delta = delta.reshape(y.shape)
                (g,) = vjp((delta.astype(y.dtype), jnp.float32(1.0)))
                pm = jax.tree_util.tree_map(
                    lambda p_, m_, g_: _upd(p_, m_, g_, lr), bp, mom_p, g)
                leaf = lambda x: isinstance(x, tuple)
                return (jax.tree_util.tree_map(lambda t: t[0], pm, is_leaf=leaf),
                        jax.tree_util.tree_map(lambda t: t[1], pm, is_leaf=leaf))

            xs = (params[spec.name], opt_state["mom"][spec.name], tape.inputs,
                  jnp.arange(spec.n_layers))
            new_params[spec.name], new_mom[spec.name] = jax.lax.map(per_layer, xs)

        # head (exact grads) + embed (DFA) updated out-of-loop
        for name, g in (("head", g_head),):
            pm = jax.tree_util.tree_map(
                lambda p_, m_, g_: _upd(p_, m_, g_, lr),
                params[name], opt_state["mom"][name], g)
            leaf = lambda x: isinstance(x, tuple)
            new_params[name] = jax.tree_util.tree_map(lambda t: t[0], pm, is_leaf=leaf)
            new_mom[name] = jax.tree_util.tree_map(lambda t: t[1], pm, is_leaf=leaf)
        if embed_vjp is not None:
            delta0 = model.embed_feedback(
                e_tap, fb["embed"], x0,
                lambda e, b: _project(e, b, cfg, prng.fold_name(rng, "embed")))
            (g_embed,) = embed_vjp(delta0)
            pm = jax.tree_util.tree_map(
                lambda p_, m_, g_: _upd(p_, m_, g_, lr),
                params["embed"], opt_state["mom"]["embed"], g_embed)
            leaf = lambda x: isinstance(x, tuple)
            new_params["embed"] = jax.tree_util.tree_map(lambda t: t[0], pm, is_leaf=leaf)
            new_mom["embed"] = jax.tree_util.tree_map(lambda t: t[1], pm, is_leaf=leaf)

        aux_total = sum(auxes.values()) if auxes else 0.0
        new_opt = {"mom": new_mom, "step": opt_step}
        del metrics
        return new_params, new_opt, loss + aux_total

    return step


def bp_value_and_grad(model, *, aux_metrics: bool = True):
    """Exact-backprop baseline under the identical harness/loss."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def fn(params, fb, batch, rng):
        del fb, rng
        (loss, metrics), grads = grad_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return (loss, metrics), grads

    return fn


def grad_alignment(dfa_grads, bp_grads):
    """Per-subtree cosine(DFA, BP) — the 'alignment' diagnostic (the theory
    in the paper's ref [29] predicts this grows during the align phase)."""
    out = {}
    for name in dfa_grads:
        a = dfa_grads[name]
        b = bp_grads[name]
        num = sum(
            jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
        )
        na = jnp.sqrt(sum(jnp.vdot(x, x) for x in map(lambda t: t.astype(jnp.float32), jax.tree_util.tree_leaves(a))))
        nb = jnp.sqrt(sum(jnp.vdot(x, x) for x in map(lambda t: t.astype(jnp.float32), jax.tree_util.tree_leaves(b))))
        out[name] = num / jnp.maximum(na * nb, 1e-12)
    return out
