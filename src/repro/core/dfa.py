"""Compatibility alias — the DFA engine now lives in ``repro.algos``.

The training algorithms were refactored into the pluggable registry
(``repro.algos``): the Eq. 1 engine is ``algos/dfa.py`` (registered as
``dfa`` / ``dfa-fused``), the backprop baseline is ``algos/bp.py``
(``bp``), and the shallow ablation is ``algos/layerwise.py``
(``dfa-layerwise``).  This module re-exports the historical
``repro.core.dfa`` names so existing imports keep working; new code should
go through ``repro.algos`` / ``repro.api``::

    algo = algos.get("dfa")
    fn = algo.value_and_grad(model, cfg)          # was dfa.value_and_grad
    fb = algo.init_extra_state(model, key, cfg)   # was dfa.init_feedback
    session = api.build_session(arch="mnist_mlp", algo="dfa", ...)
"""

from repro.algos.bp import bp_value_and_grad
from repro.algos.dfa import (
    DFAConfig,
    compress_error,
    freeze_norm_leaves,
    grad_alignment,
    init_feedback,
    make_fused_train_step,
    value_and_grad,
)

__all__ = [
    "DFAConfig", "bp_value_and_grad", "compress_error", "freeze_norm_leaves",
    "grad_alignment", "init_feedback", "make_fused_train_step",
    "value_and_grad",
]
