from repro.core import dfa, energy, feedback, photonics
from repro.core.dfa import DFAConfig, bp_value_and_grad, init_feedback, value_and_grad
from repro.core.feedback import FeedbackConfig
from repro.core.photonics import PhotonicConfig, preset

__all__ = [
    "dfa", "energy", "feedback", "photonics",
    "DFAConfig", "bp_value_and_grad", "init_feedback", "value_and_grad",
    "FeedbackConfig", "PhotonicConfig", "preset",
]
