"""Photonic execution model + energy model (+ the ``dfa`` compat alias).

``repro.core.dfa`` is a backwards-compatibility re-export of the engine
that now lives in ``repro.algos``; it is resolved lazily here so importing
``repro.core`` (from the algos package itself) never cycles back into the
algorithm registry.
"""

from repro.core import energy, feedback, photonics
from repro.core.feedback import FeedbackConfig
from repro.core.photonics import PhotonicBackend, PhotonicConfig, preset

_DFA_NAMES = ("DFAConfig", "bp_value_and_grad", "init_feedback", "value_and_grad")

__all__ = [
    "dfa", "energy", "feedback", "photonics",
    "FeedbackConfig", "PhotonicBackend", "PhotonicConfig", "preset",
    *_DFA_NAMES,
]


def __getattr__(name):
    if name == "dfa" or name in _DFA_NAMES:
        import importlib

        _dfa = importlib.import_module("repro.core.dfa")  # lazy: no cycle
        return _dfa if name == "dfa" else getattr(_dfa, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
