"""Energy & speed model of the photonic DFA architecture (paper §5).

Implements Eqs. (2)–(4) with the paper's component constants and reproduces
the headline numbers:  a 50×20 weight bank at f_s = 10 GHz delivers
20 TOPS at ~1.0 pJ/op (thermal MRR locking) or ~0.28 pJ/op (post-fab
trimming), with a compute density of ~5.78 TOPS/mm².

These constants describe the *photonic target hardware*; they parameterise
the roofline/benchmark layer only and place no constraint on the TPU
execution path (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

# --- physical constants ---
H_BAR_OMEGA_1550NM = 1.281e-19  # photon energy at 1550 nm [J]
ELEMENTARY_CHARGE = 1.602e-19  # [C]


@dataclasses.dataclass(frozen=True)
class EnergyConfig:
    f_s: float = 10e9  # operational rate [Hz] (DAC-throughput limited)
    # parallel WDM buses, each a full M×N bank with its own lasers, DACs,
    # TIAs and ADCs (Eq. 4 per-bus terms); throughput (Eq. 2) scales with
    # the bus count while E_op stays flat up to schedule-quantization loss
    n_buses: int = 1
    # one frequency-comb source feeds every bus (paper §5 cites Kerr combs
    # powering hundreds of channels): the Eq. 3 laser floor is then paid
    # once and split across the banks instead of once per bus — the
    # remaining Eq. 4 terms (rings, DACs, TIA/ADC chains) stay per-bus
    shared_comb: bool = False
    n_bits: int = 6  # fixed-point precision N_b
    eta: float = 0.2  # laser+detector+waveguide efficiency
    c_pd: float = 2.4e-15  # photodetector capacitance [F]
    v_d: float = 1.0  # photodetector driving voltage [V]
    p_mrr_heater: float = 14.12e-3  # thermal resonance locking [W]
    p_mrr_trimmed: float = 120e-6  # carrier-depletion tuning only [W]
    p_dac: float = 180e-3  # 12-bit 10 GS/s DAC [W]
    p_adc: float = 13e-3  # 6-bit 12 GS/s ADC [W]
    tia_pj_per_bit: float = 2.4e-12  # TIA energy per sample [J]
    mac_cell_area_m2: float = 47.4e-6 * 73.0e-6  # paper Fig. 3(a) cell
    trimming: bool = False  # post-fabrication trimming vs embedded heaters

    @property
    def p_mrr(self) -> float:
        return self.p_mrr_trimmed if self.trimming else self.p_mrr_heater

    @property
    def p_tia(self) -> float:
        # 2.4 pJ/bit at the operational sample rate
        return self.tia_pj_per_bit * self.f_s


def ops_per_second(m: int, n: int, cfg: EnergyConfig) -> float:
    """Eq. (2):  OPS = 2 f_s M N B — the B parallel buses each complete an
    M×N panel per operational cycle."""
    return 2.0 * cfg.f_s * m * n * cfg.n_buses


def laser_power(m: int, cfg: EnergyConfig) -> float:
    """Eq. (3): optical power floor per laser for M-row fan-out — the
    required photons per symbol (shot-noise or PD-capacitance limited,
    whichever is worse) delivered at the operational rate.  The ×f_s
    converts the per-symbol energy floor to watts; without it the
    "power" was dimensionally J/symbol (sub-pW — a bug that made the
    laser share of Eq. 4 vanish and the shared-comb variant a no-op)."""
    shot_limit = 2.0 ** (2 * cfg.n_bits + 1)
    cap_limit = cfg.c_pd * cfg.v_d / ELEMENTARY_CHARGE
    per_symbol = m * (H_BAR_OMEGA_1550NM / cfg.eta) * max(shot_limit, cap_limit)
    return per_symbol * cfg.f_s


def total_power(m: int, n: int, cfg: EnergyConfig) -> float:
    """Eq. (4): wall-plug power of an M×N weight bank circuit, times the
    ``n_buses`` parallel copies — every term is per-bus (each bus carries
    its own N lasers and input DACs, N·(M+1) tuned rings, and M TIA/ADC
    readout chains).  With ``shared_comb`` one comb source carries the N
    laser lines for ALL buses, so the Eq. 3 floor is paid once."""
    lasers = n * laser_power(m, cfg)
    if not cfg.shared_comb:
        lasers *= cfg.n_buses
    per_bus = (
        n * (m + 1) * cfg.p_mrr
        + n * cfg.p_dac
        + m * (cfg.p_tia + cfg.p_adc)
    )
    return lasers + cfg.n_buses * per_bus


def energy_per_op(m: int, n: int, cfg: EnergyConfig) -> float:
    """E_op = P_total / OPS  [J]."""
    return total_power(m, n, cfg) / ops_per_second(m, n, cfg)


def compute_density_tops_mm2(m: int, n: int, cfg: EnergyConfig) -> float:
    area_mm2 = m * n * cfg.mac_cell_area_m2 * 1e6
    return ops_per_second(m, n, cfg) / 1e12 / area_mm2


def optimal_bank_dims(n_cells: int, cfg: EnergyConfig, min_dim: int = 5):
    """Fig. 6: over factorizations M×N == n_cells (M, N ≥ 5), the dims that
    minimise E_op.  Returns (m, n, e_op)."""
    best = None
    for m in range(min_dim, n_cells // min_dim + 1):
        if n_cells % m:
            continue
        n = n_cells // m
        if n < min_dim:
            continue
        e = energy_per_op(m, n, cfg)
        if best is None or e < best[2]:
            best = (m, n, e)
    if best is None:
        raise ValueError(f"no factorization of {n_cells} with dims >= {min_dim}")
    return best


def fig6_curve(cfg: EnergyConfig, cells=None):
    """(n_cells, optimal E_op) samples reproducing Fig. 6."""
    if cells is None:
        cells = [100, 200, 400, 600, 1000, 1500, 2000, 3000, 4000, 6000, 10000]
    out = []
    for c in cells:
        try:
            m, n, e = optimal_bank_dims(c, cfg)
            out.append({"cells": c, "m": m, "n": n, "e_op_pj": e * 1e12})
        except ValueError:
            continue
    return out


def dfa_backward_cost(layer_dims, d_tap: int, cfg: EnergyConfig,
                      bank_m: int = 50, bank_n: int = 20):
    """Cycles/energy/time for one DFA backward pass (all B(k)·e products)
    executed on ``cfg.n_buses`` M×N banks via the GeMM compiler — the
    paper's unit of work.  layer_dims: injection dims per hidden layer.
    The schedule length comes from ``photonics.gemm_cycles`` (the single
    source of the tiling math — this used to re-implement it inline and
    would have silently disagreed once buses landed)."""
    from repro.core import photonics

    pcfg = photonics.PhotonicConfig(bank_rows=bank_m, bank_cols=bank_n,
                                    n_buses=cfg.n_buses)
    total_cycles = 0
    total_macs = 0
    for d in layer_dims:
        total_cycles += photonics.gemm_cycles(d, d_tap, pcfg)
        total_macs += d * d_tap
    seconds = total_cycles / cfg.f_s
    energy = total_power(bank_m, bank_n, cfg) * seconds
    return {
        "cycles": total_cycles,
        "seconds": seconds,
        "macs": total_macs,
        "energy_j": energy,
        "pj_per_mac": energy / total_macs * 1e12,
        "tops": 2 * total_macs / seconds / 1e12,
    }
