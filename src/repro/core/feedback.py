"""Fixed random feedback matrices B(k) for DFA (paper Eq. 1).

B(k) maps the error tap (dim ``d_tap``) to layer k's injection point (dim
``d_out``).  They are *fixed* — never updated — so they live outside the
optimizer state.  Options mirror the literature:

* init: gaussian (Nøkland), uniform, orthogonal (rows)
* shared: one B for all layers of a segment (Launay et al. show this works)
* ternary: B ∈ {-1,0,+1}·scale — the analog-memory-friendly variant
  (paper ref [48] ternarises the *error*; ternary B is the weight-bank
  analogue: MRR weights cycle through 3 levels only)

On the photonic chip B(k) values are inscribed on the MRR weight bank; the
[-1,1] physical range is handled by `core.photonics` normalisation, so here
B is stored in natural (unnormalised) units.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import prng


@dataclasses.dataclass(frozen=True)
class FeedbackConfig:
    init: str = "gaussian"  # gaussian | uniform | orthogonal
    scale: float | None = None  # None -> 1/sqrt(d_tap)
    shared: bool = False  # one B shared across a segment's layers
    ternary: bool = False
    dtype: jnp.dtype = jnp.float32


def _sample(key, shape, cfg: FeedbackConfig):
    d_tap = shape[-1]
    # default scale 1/sqrt(d_out): keeps ||B·e|| ≈ ||e|| (delta norms
    # calibrated like backprop's Wᵀe), which stabilises DFA dynamics.
    d_out = shape[-2]
    scale = cfg.scale if cfg.scale is not None else 1.0 / jnp.sqrt(d_out)
    if cfg.init == "gaussian":
        b = jax.random.normal(key, shape) * scale
    elif cfg.init == "uniform":
        b = jax.random.uniform(key, shape, minval=-scale, maxval=scale) * jnp.sqrt(3.0)
    elif cfg.init == "orthogonal":
        b = jax.random.orthogonal(key, max(shape[-2:]), shape=shape[:-2])[
            ..., : shape[-2], : shape[-1]
        ] * (scale * jnp.sqrt(d_tap))
    else:
        raise ValueError(f"unknown feedback init {cfg.init!r}")
    if cfg.ternary:
        thresh = 0.6745 * scale  # median(|N(0,s)|) keeps ~50% sparsity
        mag = jnp.mean(jnp.abs(b))
        b = jnp.sign(b) * (jnp.abs(b) > thresh) * mag * 2.0
    return b.astype(cfg.dtype)


def make_feedback(key, n_layers: int, d_out: int, d_tap: int, cfg: FeedbackConfig):
    """Stacked feedback (n_layers, d_out, d_tap) — or (1, …) if shared."""
    if cfg.shared:
        return _sample(prng.fold_name(key, "shared"), (1, d_out, d_tap), cfg)
    keys = jax.random.split(prng.fold_name(key, "layers"), n_layers)
    return jax.vmap(lambda k: _sample(k, (d_out, d_tap), cfg))(keys)


def feedback_for(stacked, layer_idx):
    """Select layer's B from stacked feedback (handles shared)."""
    i = jnp.minimum(layer_idx, stacked.shape[0] - 1)
    return jax.lax.dynamic_index_in_dim(stacked, i, 0, keepdims=False)
