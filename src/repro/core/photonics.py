"""Photonic execution model: MRR weight-bank matrix products with the
paper's measured noise, precision, and tiling semantics.

The physical machine (paper §2–3):

* An M×N MRR weight bank computes M inner products of length N per
  operational cycle; weights (and the encoded inputs) live in [-1, 1].
* Larger matrices are subdivided by a GeMM compiler into bank-sized panels
  processed over multiple cycles (paper §3).
* Every analog inner product carries Gaussian read noise.  Measured:
  σ = 0.019 (single MRR multiply), 0.098 (1×4 bank + off-chip BPD),
  0.202 (on-chip BPD) — in *full-scale output* units where the output
  range is [-1, 1]  ⇒  effective bits = log2(2/σ).

TPU adaptation (DESIGN.md §2): we do not tile the contraction by the
physical bank width (20) — that would waste the 128-wide MXU.  Instead the
Pallas kernel tiles by MXU-aligned blocks and draws noise with variance
σ²·(block_k / bank_cols), statistically identical to accumulating
block_k/bank_cols physical bank passes.  The *pure-JAX reference path*
(this module) draws the total accumulated noise once:

    C = A @ Bᵀ + η,   η ~ N(0, σ² · ceil(K / bank_cols))  (per element)

Noise conventions:
* "absolute"  — σ is added per bank pass in the operands' natural units;
  this is the paper's own MNIST-simulation protocol ("adds accurately
  scaled Gaussian noise ... to the output of each MAC operation").
* "fullscale" — σ is relative to the bank's full-scale output (N_bank·s_A·s_B
  for normalised operands): physically conservative; noise grows with
  operand magnitude.  Both are available; "absolute" is the default because
  it is what reproduces the paper's Fig. 5 numbers.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import annotate
from repro.hardware.mrr import MRRConfig
from repro.lint.runtime import check_finite
from repro.utils import prng


@dataclasses.dataclass(frozen=True)
class PhotonicConfig:
    bank_rows: int = 50  # M — rows of MRR arrays (paper headline bank 50×20)
    bank_cols: int = 20  # N — WDM channels per waveguide bus
    # Parallel WDM buses (paper §5 scale-out): each bus is a full physical
    # bank (rows×cols rings) with its own modulator/DAC and BPD/ADC chain.
    # The GeMM compiler schedules contraction panels across buses in the
    # same operational cycle, so throughput scales ~linearly while the
    # accumulated noise per output (one draw per *panel*) is unchanged.
    n_buses: int = 1
    # yield/failure model: buses (by physical index < n_buses) whose whole
    # modulator→bank→BPD chain is dead.  The GeMM compiler reroutes panels
    # onto the surviving buses — schedules lengthen (``n_bank_passes``
    # counts alive buses) but training keeps running; per-ring drift/cal
    # state keeps the full physical (n_buses, rows, cols) shape.
    failed_buses: tuple = ()
    noise_std: float = 0.0  # per-bank-pass Gaussian σ (0 = ideal hardware)
    noise_convention: str = "absolute"  # absolute | fullscale
    weight_bits: int | None = None  # fake-quant of inscribed MRR weights
    input_bits: int | None = None  # fake-quant of modulator amplitudes (DAC)
    f_s: float = 10e9  # operational rate (Hz), DAC-limited per the paper
    enabled: bool = True
    # device-level description for the "emu" backend (repro.hardware):
    # Lorentzian rings, crosstalk, drift, calibration.  None = the abstract
    # σ-per-MAC model only; the ref/pallas backends ignore it either way.
    mrr: MRRConfig | None = None

    @property
    def effective_bits(self) -> float:
        """log2(2/σ) — exact inverse of ``resolution_to_sigma``."""
        return sigma_to_resolution(self.noise_std)


# Paper-measured hardware presets (Figs. 3c, 5a).  The emu_* presets pair
# the measured per-pass σ with a device-level MRRConfig for the "emu"
# backend: emu_ideal is the nonideality-free bank (backend-equivalence
# baseline); emu_offchip / emu_onchip add realistic heater DACs, output
# ADCs, thermal crosstalk, and resonance drift (pair with
# ``TrainerConfig.recalibrate_every`` for in-situ calibration).
PRESETS: dict[str, PhotonicConfig] = {
    "ideal": PhotonicConfig(noise_std=0.0),
    "single_mrr": PhotonicConfig(noise_std=0.019),
    "offchip_bpd": PhotonicConfig(noise_std=0.098),
    "onchip_bpd": PhotonicConfig(noise_std=0.202),
    "digital": PhotonicConfig(enabled=False),
    "emu_ideal": PhotonicConfig(noise_std=0.0, mrr=MRRConfig.ideal()),
    "emu_offchip": PhotonicConfig(noise_std=0.098, mrr=MRRConfig(adc_bits=10)),
    "emu_onchip": PhotonicConfig(noise_std=0.202, mrr=MRRConfig(adc_bits=8)),
}


def preset(name: str) -> PhotonicConfig:
    return PRESETS[name]


def resolution_to_sigma(bits: float) -> float:
    """Effective resolution (bits) -> full-scale noise σ = 2^(1-bits)."""
    return 2.0 ** (1.0 - bits)


def sigma_to_resolution(sigma: float) -> float:
    """Full-scale noise σ -> effective bits = log2(2/σ), computed as
    1 - log2(σ) so the pair round-trips to float precision (the naive
    ``log2(2/σ)`` adds a division rounding; tests/test_photonics.py
    property-tests the inverse both ways)."""
    return 1.0 - math.log2(sigma) if sigma > 0 else float("inf")


def bits_to_std(bits: float) -> float:
    """Alias of ``resolution_to_sigma`` (historical name)."""
    return resolution_to_sigma(bits)


def std_to_bits(std: float) -> float:
    """Alias of ``sigma_to_resolution`` (historical name)."""
    return sigma_to_resolution(std)


def fake_quant(x, bits: int | None, amax=None):
    """Symmetric fake quantisation to ``bits`` over [-amax, amax].

    ``bits=1`` clamps to ternary/sign semantics ({-amax, 0, +amax}, the
    same grid as ``bits=2``): the naive symmetric formula has zero levels
    at one bit and used to return NaN."""
    if bits is None:
        return x
    if amax is None:
        amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    levels = max(2 ** (bits - 1) - 1, 1)
    scaled = jnp.clip(x / amax, -1.0, 1.0) * levels
    return jnp.round(scaled) / levels * amax


def n_contraction_panels(k_dim: int, cfg: PhotonicConfig) -> int:
    """Bank-sized panels along the contraction dim (GeMM compiler
    N-tiling) — the number of partial products *accumulated* per output,
    i.e. the noise-relevant count, independent of how many buses execute
    them in parallel."""
    return max(1, math.ceil(k_dim / cfg.bank_cols))


def active_buses(cfg: PhotonicConfig) -> int:
    """Buses actually carrying panels: the physical count minus the failed
    ones (``cfg.failed_buses``).  A chip with every bus dead cannot run."""
    n = max(cfg.n_buses, 1)
    failed = {b for b in cfg.failed_buses if 0 <= b < n}
    alive = n - len(failed)
    if alive < 1:
        raise ValueError(
            f"all {n} buses failed ({sorted(failed)}): no path through the chip")
    return alive


def alive_bus_indices(cfg: PhotonicConfig) -> tuple:
    """Physical indices of the surviving buses, in order — the panel
    scheduler's logical-bus → physical-bank map."""
    n = max(cfg.n_buses, 1)
    failed = {b for b in cfg.failed_buses if 0 <= b < n}
    return tuple(b for b in range(n) if b not in failed)


def n_bank_passes(k_dim: int, cfg: PhotonicConfig) -> int:
    """Operational cycles along the contraction dim: the surviving
    parallel banks each take one panel per cycle, so the schedule length
    is ⌈panels / active_buses⌉ (== panels on a single bus)."""
    return math.ceil(n_contraction_panels(k_dim, cfg) / active_buses(cfg))


def gemm_cycles(m: int, k: int, cfg: PhotonicConfig) -> int:
    """Total operational cycles for an (m×k)·(k,) matvec on the bank —
    the GeMM compiler's schedule length (paper §3), contraction panels
    bus-parallel per ``cfg.n_buses``."""
    return max(1, math.ceil(m / cfg.bank_rows)) * n_bank_passes(k, cfg)


def noise_sigma_total(k_dim: int, s_a, s_b, cfg: PhotonicConfig):
    """Std of the accumulated output noise for a length-k inner product,
    in natural (unnormalised) units.  Every contraction panel contributes
    one BPD read regardless of which bus ran it, so this counts panels,
    not bus-parallel cycles."""
    passes = n_contraction_panels(k_dim, cfg)
    if cfg.noise_convention == "absolute":
        per_pass = cfg.noise_std * s_a * s_b
    elif cfg.noise_convention == "fullscale":
        per_pass = cfg.noise_std * cfg.bank_cols * s_a * s_b
    else:
        raise ValueError(cfg.noise_convention)
    return per_pass * math.sqrt(passes)


def normalise_operands(a, b, cfg: PhotonicConfig):
    """Encode operands into the photonic [-1, 1] range: per-tensor amplitude
    normalisation followed by the DAC/weight fake-quant.  Shared by the
    reference path and the Pallas wrapper (kernels/ops.py) so both see
    identical encoding semantics.  -> (a_n, b_n, s_a, s_b)."""
    s_a = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(a)), 1e-12))
    s_b = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(b)), 1e-12))
    a_n = fake_quant(a / s_a, cfg.input_bits, 1.0)
    b_n = fake_quant(b / s_b, cfg.weight_bits, 1.0)
    return a_n, b_n, s_a, s_b


def photonic_matmul(a, b, cfg: PhotonicConfig, key=None, *, mask=None):
    """Noisy C = A @ Bᵀ  (the weight-bank product).  Pure-JAX reference path.

    a: (..., T, K) — e.g. the error vectors (amplitude-encoded inputs)
    b: (M, K)      — the inscribed weight matrix panel (B(k) rows)
    mask: optional (..., T, M) Hadamard epilogue (the TIA gain g'(a));
          applied *after* noise, as on-chip (noise enters at the BPD).
    Returns (..., T, M).
    """
    if not cfg.enabled:
        out = jnp.einsum("...tk,mk->...tm", a, b)
        return out * mask if mask is not None else out

    a_n, b_n, s_a, s_b = normalise_operands(a, b, cfg)
    out = jnp.einsum("...tk,mk->...tm", a_n, b_n)
    if cfg.noise_std > 0.0:
        if key is None:
            raise ValueError("noise_std > 0 requires a PRNG key")
        sigma = noise_sigma_total(a.shape[-1], 1.0, 1.0, cfg)  # normalised units
        noise = jax.random.normal(prng.consume(key), out.shape,
                                  dtype=out.dtype)
        if out.ndim == 2:
            noise = annotate(noise, "delta_tm")
            out = annotate(out, "delta_tm")
        out = out + sigma * noise
    out = check_finite(out * (s_a * s_b), "photonic_matmul output")
    return out * mask if mask is not None else out


# ---------------------------------------------------------------------------
# Execution backends
# ---------------------------------------------------------------------------
# A PhotonicBackend is *how* the weight-bank product is executed (pure-JAX
# einsum vs the Pallas TPU kernel); PhotonicConfig is *what* hardware is
# being modelled.  Backends are registered by name so new execution paths
# (e.g. an interferometer-mesh simulator, a real-hardware RPC bridge) are a
# registration, not an edit of every call site.


class PhotonicBackend:
    """Executes C = A @ Bᵀ (+ bank noise, ⊙ mask) with a:(T,K), b:(M,K)."""

    name = "base"
    # True when the backend consumes carried hardware state (drift /
    # calibration residuals): the Trainer then creates, advances, and
    # threads a per-ring state pytree through fit (see repro.hardware).
    stateful_hardware = False

    def matmul(self, a, b, cfg: PhotonicConfig, key=None, *, mask=None):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ReferenceBackend(PhotonicBackend):
    """Pure-JAX path: total accumulated noise drawn once per output."""

    name: str = "ref"

    def matmul(self, a, b, cfg, key=None, *, mask=None):
        return photonic_matmul(a, b, cfg, key=key, mask=mask)


@dataclasses.dataclass(frozen=True)
class PallasBackend(PhotonicBackend):
    """MXU-tiled Pallas kernel (kernels/ops.py): per-block noise with the
    statistically identical variance.  ``interpret=True`` runs the kernel in
    the Pallas interpreter (CPU-validatable)."""

    name: str = "pallas"
    interpret: bool = False

    def matmul(self, a, b, cfg, key=None, *, mask=None):
        from repro.kernels import ops as kops  # lazy: kernels import us

        return kops.photonic_matmul(a, b, cfg, key=key, mask=mask,
                                    interpret=self.interpret)


@dataclasses.dataclass(frozen=True)
class EmulatedMRRBackend(PhotonicBackend):
    """Device-level MRR bank emulation (repro.hardware.channel): Lorentzian
    ring transfer, heater inscription + DAC, thermal crosstalk, BPD
    shot/read noise, per-pass ADC — and, under the Trainer, stateful
    resonance drift with in-situ recalibration.  ``cfg.mrr`` describes the
    device (None falls back to ``MRRConfig()`` defaults).

    ``emu_kernel`` picks the execution path ("auto" | "ref" | "pallas" |
    "xla"): "ref" is the unfused einsum chain, "pallas"/"xla" run the
    fused panel loop of ``kernels.emu_matmul`` in one kernel per GEMM.
    "auto" consults ``REPRO_EMU_KERNEL`` and then the platform default
    (fused Pallas on TPU, ref elsewhere)."""

    name: str = "emu"
    stateful_hardware = True
    emu_kernel: str = "auto"

    def matmul(self, a, b, cfg, key=None, *, mask=None):
        from repro.hardware import channel  # lazy: hardware imports us

        return channel.emulated_matmul(a, b, cfg, key=key, mask=mask,
                                       kernel=self.emu_kernel)


BACKENDS: dict[str, PhotonicBackend] = {}


def register_backend(backend: PhotonicBackend) -> PhotonicBackend:
    BACKENDS[backend.name] = backend
    return backend


register_backend(ReferenceBackend())
register_backend(PallasBackend())
register_backend(EmulatedMRRBackend())


def get_backend(spec: str | PhotonicBackend = "auto") -> PhotonicBackend:
    """Resolve a backend: an instance passes through; "auto" picks the
    Pallas kernel on TPU and the reference path elsewhere."""
    if isinstance(spec, PhotonicBackend):
        return spec
    if spec == "auto":
        spec = "pallas" if jax.default_backend() == "tpu" else "ref"
    if spec not in BACKENDS:
        raise KeyError(
            f"unknown photonic backend {spec!r}; registered: {sorted(BACKENDS)}")
    return BACKENDS[spec]


def photonic_project(e, b, cfg: PhotonicConfig, key=None, *, mask=None,
                     backend: str | PhotonicBackend = "auto"):
    """DFA projection  δ = e·Bᵀ (⊙ mask)  through a registered backend.
    e: (..., d_tap), b: (d_out, d_tap)."""
    lead = e.shape[:-1]
    e2 = e.reshape(-1, e.shape[-1])
    m2 = mask.reshape(-1, mask.shape[-1]) if mask is not None else None
    out = get_backend(backend).matmul(e2, b, cfg, key=key, mask=m2)
    return out.reshape(*lead, b.shape[0])


# ---------------------------------------------------------------------------
# Forward-execution context (photonic inference)
# ---------------------------------------------------------------------------
# Training runs only the DFA feedback projections on the photonic banks;
# inference (repro.serve) runs the *forward* weight matrices through them.
# Rather than thread (cfg, backend, key) through every Linear/Attention
# call signature, the serve engine pushes a ForwardExecution context while
# tracing its jitted step — the same pattern as ``hardware.drift.use_state``
# — and ``forward_matmul`` below is the single seam every weight-stationary
# projection in nn/ and models/ calls.  Outside any context (or with
# ``cfg.enabled`` False) it is literally ``x @ w``: the training and
# digital-serving paths are bit-identical to before the seam existed.

_FORWARD: list = []


class ForwardExecution:
    """One photonic forward pass: config + backend + a PRNG stream that
    hands each routed matmul its own fold_in'd key (trace-order counter —
    deterministic under jit because tracing order is)."""

    def __init__(self, cfg: PhotonicConfig, backend, key=None):
        self.cfg = cfg
        self.backend = get_backend(backend)
        self.key = key
        self.calls = 0

    def next_key(self):
        if self.key is None:
            return None
        self.calls += 1
        return jax.random.fold_in(self.key, self.calls)


@contextlib.contextmanager
def forward_execution(cfg: PhotonicConfig, backend="ref", key=None):
    """Route every ``forward_matmul`` in the dynamic extent through
    ``backend`` under ``cfg``.  Enter *inside* the traced function so the
    key/state tracers belong to the consuming trace (cf. drift.use_state)."""
    ctx = ForwardExecution(cfg, backend, key)
    _FORWARD.append(ctx)
    try:
        yield ctx
    finally:
        _FORWARD.pop()


def active_forward() -> ForwardExecution | None:
    return _FORWARD[-1] if _FORWARD else None


def forward_matmul(x, w):
    """``x @ w`` with x: (..., K), w: (K, M) — THE forward projection seam.

    Digital (no active context / ``enabled=False``): exact ``x @ w``.
    Photonic: flatten leading dims to a (T, K) stream and run the weight
    bank product through the context's backend — the emu backend then
    prices in inscription error, quantisation, crosstalk, and any active
    drift state.  Biases, norms, and activations stay electronic (they are
    TIA-side ops, not bank products)."""
    ctx = active_forward()
    if ctx is None or not ctx.cfg.enabled:
        return x @ w
    lead = x.shape[:-1]
    a = x.reshape(-1, x.shape[-1])
    out = ctx.backend.matmul(a, w.T, ctx.cfg, key=ctx.next_key())
    return out.reshape(*lead, w.shape[-1]).astype(jnp.result_type(x, w))
