"""Request-level serving simulation on the photonic pipeline.

``sim.pipeline.simulate`` prices one batched forward; this module lifts
it to request *timelines*: Poisson (or trace) arrivals enter an
admission queue, are placed into a fixed pool of batch slots, and walk
the same prefill/decode rounds the real ``serve.Engine`` runs — chunked
prompt prefill, then one greedy token per decode round — with each
round's duration read from the pipeline simulator on the model's
``forward_workload``.

The per-round cost uses an exact affine collapse of the pipeline
timeline: with panel tiling, every bus streams ``T`` vectors through its
slot list back-to-back, so ``wall(T) = a·T + b`` where ``a`` is the
max-loaded bus's slot count times the cycle time and ``b`` is the
pipeline fill paid once per round (weight updates do not occur while
serving).  ``ServiceModel`` fits (a, b) from two simulator calls and the
DES then prices millions of rounds in O(1) each — the fit is exact, not
a regression (tests assert ``wall(7) == a·7 + b`` against the full
simulator).

Reports per offered load: p50/p99 TTFT and end-to-end latency,
requests/s, bank utilisation, and J/request (Eq. 4 wall-plug power
integrated over the makespan).  ``autotune_serving`` (sim.autotune)
searches (n_buses, f_s, batch_slots) under an SLO + power budget.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import photonics
from repro.sim import pipeline


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One simulated request: arrival offset + token counts."""

    arrival_s: float
    prompt_len: int
    decode_len: int  # generated tokens incl. the prefill-emitted first one


def poisson_requests(rate: float, n: int, *, prompt_len: int = 64,
                     decode_len: int = 32, seed: int = 0) -> list[RequestSpec]:
    """``n`` requests with Poisson arrivals at ``rate`` req/s."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [RequestSpec(arrival_s=float(a), prompt_len=prompt_len,
                        decode_len=decode_len) for a in arrivals]


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Exact affine round-cost model: ``round_s(T) = a·T + b`` (T > 0)."""

    a: float  # seconds per streamed token
    b: float  # pipeline fill per round
    macs_per_token: float
    power_w: float
    peak_macs_per_s: float
    n_buses: int
    f_s: float

    def round_s(self, tokens: int) -> float:
        if tokens <= 0:
            return 0.0
        return self.a * tokens + self.b


def service_model(model, pcfg: photonics.PhotonicConfig, ecfg=None, *,
                  f_s: float | None = None, tiling: str = "panel") -> ServiceModel:
    """Fit the affine model from two pipeline simulations of the model's
    forward workload (T=1, T=2); exact because the panel timeline is
    affine in the streamed-vector count."""
    w1 = pipeline.forward_workload(model, 1)
    w2 = pipeline.forward_workload(model, 2)
    r1 = pipeline.simulate(w1, pcfg, ecfg, f_s=f_s, tiling=tiling,
                           include_weight_update=False)
    r2 = pipeline.simulate(w2, pcfg, ecfg, f_s=f_s, tiling=tiling,
                           include_weight_update=False)
    a = r2.wall_clock_s - r1.wall_clock_s
    b = r1.wall_clock_s - a
    return ServiceModel(a=a, b=b,
                        macs_per_token=float(sum(g.macs for g in w1)),
                        power_w=r1.power_w,
                        peak_macs_per_s=r1.peak_macs_per_s,
                        n_buses=r1.n_buses, f_s=r1.f_s)


@dataclasses.dataclass
class _Active:
    spec: RequestSpec
    prompt_left: int
    decode_left: int
    admit_s: float
    first_token_s: float | None = None
    record: dict | None = None  # trace-export lifecycle record (trace= only)


@dataclasses.dataclass
class ServingReport:
    """Request-level timeline summary at one offered load."""

    n_requests: int
    offered_rate: float  # n / last arrival (req/s offered)
    makespan_s: float
    requests_per_s: float  # achieved: n / makespan
    ttft_p50_s: float
    ttft_p99_s: float
    latency_p50_s: float
    latency_p99_s: float
    queue_p50_s: float  # admission wait (arrival -> slot)
    queue_p99_s: float
    prefill_tokens: int
    decode_tokens: int
    rounds: int
    utilisation: float  # useful MACs / (peak · makespan)
    busy_frac: float  # fraction of the makespan a round was streaming
    power_w: float
    energy_j: float
    j_per_request: float
    batch_slots: int
    prefill_chunk: int

    def as_metrics(self, prefix: str = "") -> dict:
        return {
            f"{prefix}offered_rate": self.offered_rate,
            f"{prefix}requests_per_s": self.requests_per_s,
            f"{prefix}ttft_p50_ms": self.ttft_p50_s * 1e3,
            f"{prefix}ttft_p99_ms": self.ttft_p99_s * 1e3,
            f"{prefix}latency_p50_ms": self.latency_p50_s * 1e3,
            f"{prefix}latency_p99_ms": self.latency_p99_s * 1e3,
            f"{prefix}queue_p99_ms": self.queue_p99_s * 1e3,
            f"{prefix}utilisation": self.utilisation,
            f"{prefix}power_w": self.power_w,
            f"{prefix}j_per_request": self.j_per_request,
        }


def simulate_serving(requests, svc: ServiceModel, *, batch_slots: int = 8,
                     prefill_chunk: int = 16,
                     trace=None) -> ServingReport:
    """Replay the engine's tick loop over simulated time.

    Each tick: admit arrived requests into free slots, run one chunked
    prefill round over all prefilling slots (duration =
    ``svc.round_s(total chunk tokens)``), then one decode round over all
    decoding slots (one token each).  A request's prompt completion emits
    its first token at the end of the prefill round (TTFT); remaining
    ``decode_len - 1`` tokens come one per decode round.  When the pool
    is idle, time jumps to the next arrival — queueing delay is the
    arrival→slot wait when it is not.

    ``trace`` exports the simulated timeline as Chrome-trace tracks
    (round spans + one async lifecycle track per request): pass an
    ``obs.TraceRecorder`` to accumulate into, or a path to write a
    standalone trace JSON.  ``None`` (the default) collects nothing.
    """
    if batch_slots < 1:
        raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
    prefill_chunk = max(1, int(prefill_chunk))
    pending = sorted(requests, key=lambda r: r.arrival_s)
    n = len(pending)
    if n == 0:
        raise ValueError("no requests")
    for r in pending:
        if r.prompt_len < 1 or r.decode_len < 1:
            raise ValueError(f"degenerate request {r}")
    idx = 0
    slots: list[_Active] = []
    t = 0.0
    busy_s = 0.0
    rounds = 0
    prefill_tokens = 0
    decode_tokens = 0
    ttft, latency, queue = [], [], []
    collect = trace is not None
    round_ev: list = []  # (kind, start_s, end_s, tokens, n_slots)
    req_ev: list = []  # lifecycle records for export.serving_to_trace

    def finish(s: _Active, now: float):
        latency.append(now - s.spec.arrival_s)
        ttft.append(s.first_token_s - s.spec.arrival_s)
        queue.append(s.admit_s - s.spec.arrival_s)
        if s.record is not None:
            s.record["first_token_s"] = s.first_token_s
            s.record["finish_s"] = now
        slots.remove(s)

    while idx < n or slots:
        if not slots and (idx < n and pending[idx].arrival_s > t):
            t = pending[idx].arrival_s  # idle pool: jump to next arrival
        while idx < n and pending[idx].arrival_s <= t and len(slots) < batch_slots:
            r = pending[idx]
            idx += 1
            rec = None
            if collect:
                rec = {"id": len(req_ev), "arrival_s": r.arrival_s,
                       "admit_s": t, "first_token_s": None, "finish_s": t,
                       "prompt_len": r.prompt_len, "decode_len": r.decode_len}
                req_ev.append(rec)
            slots.append(_Active(spec=r, prompt_left=r.prompt_len,
                                 decode_left=r.decode_len, admit_s=t,
                                 record=rec))
        # --- prefill round ---
        pf = [s for s in slots if s.prompt_left > 0]
        if pf:
            tok = sum(min(prefill_chunk, s.prompt_left) for s in pf)
            dur = svc.round_s(tok)
            if collect:
                round_ev.append(("prefill", t, t + dur, tok, len(pf)))
            t += dur
            busy_s += dur
            rounds += 1
            prefill_tokens += tok
            for s in pf:
                s.prompt_left -= min(prefill_chunk, s.prompt_left)
                if s.prompt_left == 0:
                    # the first output token falls out of the prefill
                    # forward itself — no extra decode-round MACs
                    s.first_token_s = t
                    s.decode_left -= 1
                    if s.decode_left == 0:
                        finish(s, t)
        # --- decode round ---
        dc = [s for s in slots if s.prompt_left == 0]
        if dc:
            dur = svc.round_s(len(dc))
            if collect:
                round_ev.append(("decode", t, t + dur, len(dc), len(dc)))
            t += dur
            busy_s += dur
            rounds += 1
            decode_tokens += len(dc)
            for s in dc:
                s.decode_left -= 1
                if s.decode_left == 0:
                    finish(s, t)

    makespan = t
    if collect:
        from repro.obs import export  # lazy: obs is optional at sim time

        rec_, path = export.resolve_recorder(trace)
        export.serving_to_trace(round_ev, req_ev, rec_)
        if path is not None:
            export.write(rec_, path)
    useful_macs = svc.macs_per_token * (prefill_tokens + decode_tokens)
    energy = svc.power_w * makespan
    last_arrival = max(pending[-1].arrival_s, 1e-12)
    pct = lambda xs, q: float(np.percentile(np.asarray(xs), q))
    return ServingReport(
        n_requests=n,
        offered_rate=n / last_arrival,
        makespan_s=makespan,
        requests_per_s=n / makespan if makespan > 0 else 0.0,
        ttft_p50_s=pct(ttft, 50), ttft_p99_s=pct(ttft, 99),
        latency_p50_s=pct(latency, 50), latency_p99_s=pct(latency, 99),
        queue_p50_s=pct(queue, 50), queue_p99_s=pct(queue, 99),
        prefill_tokens=prefill_tokens,
        decode_tokens=decode_tokens,
        rounds=rounds,
        utilisation=(useful_macs / (svc.peak_macs_per_s * makespan)
                     if makespan > 0 else 0.0),
        busy_frac=busy_s / makespan if makespan > 0 else 0.0,
        power_w=svc.power_w,
        energy_j=energy,
        j_per_request=energy / n,
        batch_slots=batch_slots,
        prefill_chunk=prefill_chunk,
    )
