"""Timing-accurate replay of the photonic training pipeline.

``simulate`` takes the *same* panel schedule the emulator executes — the
bus-tiled layout of ``hardware.channel.tile_operands``, read shape-only
through ``jax.eval_shape`` so simulator and emulator can never disagree
about what runs when — and expands it into per-bus event timelines over
the component stages of ``sim.components``:

* every (row-block i, bus-cycle j) panel slot on a bus streams the
  GEMM's T input vectors through the 5-stage chain at one vector per
  operational cycle (the paper's Fig. 3 pipelining);
* DFA's backward has no inter-layer dependency, so buses roll straight
  from one layer's panels into the next with the pipeline still full —
  the fill latency is paid once per bus, not once per GEMM;
* panel slots padded onto idle buses (indivisible panel counts) occupy
  schedule time but do no useful MACs — exactly the occupancy loss
  ``photonics.n_bank_passes``'s ceiling division implies;
* the optional weight-update epilogue prices the once-per-training-step
  heater write of the forward banks (thermal settling, µs — the one
  activity that is NOT hidden by pipelining).

Two panel→bus assignment policies ("bank tiling"):

* ``"panel"`` — the emulator's schedule: each GEMM's contraction panels
  round-robin across the alive buses (cycle identity with
  ``photonics.gemm_cycles`` / ``n_bank_passes`` holds per GEMM);
* ``"layer"`` — whole GEMMs (DFA's independent per-layer projections,
  Fig. 3) are placed greedily on the least-loaded bus; no per-GEMM bus
  quantization, but a layer never spans buses.  The numerics are
  identical either way (scheduling does not change the math) — only the
  timeline differs, which is why the autotuner may pick it.

Energy integrates Eq. 4 wall-plug power (``core.energy.total_power``,
single source of truth) over the simulated makespan; for pipelined
schedules this lands within <1% of ``energy.dfa_backward_cost``'s static
cycles/f_s pricing — tests/test_sim.py holds the cross-check.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import energy as energy_lib
from repro.core import photonics
from repro.sim import components

# cap on the per-stage event records kept in a report (the timeline is
# aggregated exactly either way; events are for introspection/plots)
MAX_EVENTS = 4096


@dataclasses.dataclass(frozen=True)
class Gemm:
    """One weight-bank product of the training step: (T, K) · (M, K)ᵀ —
    T streamed vectors against an inscribed M×K matrix panel-set."""

    name: str
    t: int  # streamed input vectors (batch × tokens)
    m: int  # output dim (rows of the inscribed matrix)
    k: int  # contraction dim (the error-tap width for DFA feedback)

    @property
    def macs(self) -> int:
        return self.t * self.m * self.k


def dfa_backward_workload(model, t: int) -> list[Gemm]:
    """The paper's unit of work: every hidden layer's feedback projection
    e·B(k)ᵀ for one training step of ``t`` examples (tokens), read from
    the model's segment specs — the same structure the DFA engine runs."""
    d_tap = model.d_tap
    work = []
    for spec in model.segment_specs():
        for i in range(spec.n_layers):
            work.append(Gemm(name=f"{spec.name}[{i}]", t=t,
                             m=spec.d_inject, k=d_tap))
    return work


def forward_workload(model, t: int) -> list[Gemm]:
    """The serving unit of work: every weight-stationary forward projection
    of ``t`` streamed tokens, read from the model's forward GEMM specs —
    the projections the engine routes through ``photonics.forward_matmul``
    when serving on a photonic backend."""
    return [Gemm(name=name, t=t, m=m, k=k)
            for name, m, k in model.forward_gemm_specs()]


@functools.lru_cache(maxsize=4096)
def _panel_layout(m: int, k: int, pcfg: photonics.PhotonicConfig):
    """T-independent part of ``panel_schedule`` — memoised: serving sims
    replay the same per-layer layout at thousands of (candidate, round)
    points, and ``eval_shape`` retracing would dominate the DES."""
    from repro.hardware import channel  # lazy: hardware imports photonics

    a = jax.ShapeDtypeStruct((1, k), jnp.float32)
    b = jax.ShapeDtypeStruct((m, k), jnp.float32)
    a_t, b_t = jax.eval_shape(
        lambda a, b: channel.tile_operands(a, b, pcfg)[:2], a, b)
    nm, n_alive, _rows, nj, _cols = b_t.shape
    assert a_t.shape[1:3] == (n_alive, nj)
    n_panels = photonics.n_contraction_panels(k, pcfg)
    assert nj == -(-n_panels // n_alive)  # the emulator's own ceiling
    return nm, n_alive, nj, n_panels


def panel_schedule(gemm: Gemm, pcfg: photonics.PhotonicConfig):
    """The GEMM's bus-tiled panel layout, straight from the emulator.

    Shape-only (``jax.eval_shape`` over ``channel.tile_operands`` — no
    allocation at any T).  Returns (nm, n_alive, nj, n_panels): row
    blocks, alive buses, bus-cycles, and real contraction panels; slot
    (i, j) on alive bus q is real iff j·n_alive + q < n_panels.
    """
    return _panel_layout(gemm.m, gemm.k, pcfg)


@dataclasses.dataclass
class PipelineReport:
    """One simulated training-step timeline and its headline numbers."""

    wall_clock_s: float  # makespan incl. the weight-update epilogue
    compute_s: float  # streaming makespan (panels through the pipeline)
    weight_update_s: float  # heater epilogue (0 when disabled)
    cycles: int  # schedule length in operational cycles (max over buses)
    cycles_per_gemm: dict  # name -> per-bus slot count (panel tiling)
    macs: int  # useful MACs (real panels only)
    macs_per_s: float  # sustained: macs / wall_clock_s
    peak_macs_per_s: float  # f_s · rows · cols · alive buses
    utilisation: float  # sustained / peak
    occupancy: dict  # stage -> busy fraction of (alive buses × wall)
    bus_busy_s: list  # per alive bus: useful streaming time
    power_w: float  # Eq. 4 wall-plug power of the modelled chip
    energy_j: float  # power × wall_clock_s
    energy_compute_j: float  # power × compute_s (Eq. 2/4 cross-check)
    pj_per_mac: float
    n_buses: int  # alive buses the schedule ran on
    f_s: float
    tiling: str
    events: list  # (bus, stage, start_s, end_s, gemm) — capped sample
    # measured-feedback overlap model (defaults keep positional callers
    # working): the host's digital step time runs concurrently with the
    # photonic stream, and in-situ recalibration amortises a heater sweep
    digital_s: float = 0.0  # measured digital-side step time (overlapped)
    recal_s: float = 0.0  # amortised per-step recalibration epilogue
    recalibrate_every: int = 0  # cadence the recal_s amortisation assumes

    def as_metrics(self, prefix: str = "") -> dict:
        """Flat numeric view for BENCH_*.json emission."""
        out = {
            f"{prefix}wall_clock_us": self.wall_clock_s * 1e6,
            f"{prefix}compute_us": self.compute_s * 1e6,
            f"{prefix}cycles": float(self.cycles),
            f"{prefix}macs_per_s": self.macs_per_s,
            f"{prefix}utilisation": self.utilisation,
            f"{prefix}pj_per_mac": self.pj_per_mac,
            f"{prefix}power_w": self.power_w,
            f"{prefix}digital_us": self.digital_s * 1e6,
            f"{prefix}recal_us": self.recal_s * 1e6,
        }
        for stage, occ in self.occupancy.items():
            out[f"{prefix}occ_{stage}"] = occ
        return out


def _assign_slots(workload, pcfg, tiling: str):
    """Per-bus ordered slot lists: (gemm, n_slots, n_real_slots) runs.

    "panel": every GEMM spreads its panels over all alive buses (the
    emulator's layout).  "layer": whole GEMMs go to the least-loaded bus.
    Returns (per_bus_runs, cycles_per_gemm, n_alive).
    """
    n_alive = photonics.active_buses(pcfg)
    per_bus: list[list] = [[] for _ in range(n_alive)]
    cycles_per_gemm: dict[str, int] = {}
    if tiling == "panel":
        for g in workload:
            nm, nb, nj, n_panels = panel_schedule(g, pcfg)
            cycles_per_gemm[g.name] = nm * nj
            for q in range(nb):
                real = sum(1 for j in range(nj) if j * nb + q < n_panels)
                per_bus[q].append((g, nm * nj, nm * real))
    elif tiling == "layer":
        # greedy longest-processing-time: heaviest layers placed first on
        # the least-loaded bus; each layer runs single-bus (nm × n_panels
        # slots, no idle-bus padding)
        load = [0.0] * n_alive
        single = dataclasses.replace(pcfg, n_buses=1, failed_buses=())
        sized = []
        for g in workload:
            nm, _nb, nj, n_panels = panel_schedule(g, single)
            assert nj == n_panels
            sized.append((g, nm * n_panels))
            cycles_per_gemm[g.name] = nm * n_panels
        for g, slots in sorted(sized, key=lambda s: -s[1] * s[0].t):
            q = min(range(n_alive), key=lambda i: load[i])
            per_bus[q].append((g, slots, slots))
            load[q] += slots * g.t
    else:
        raise ValueError(f"unknown tiling {tiling!r} (panel | layer)")
    return per_bus, cycles_per_gemm, n_alive


def simulate(workload, pcfg: photonics.PhotonicConfig, ecfg=None, *,
             f_s: float | None = None, tiling: str = "panel",
             include_weight_update: bool = True,
             digital_s: float = 0.0,
             recalibrate_every: int = 0,
             trace=None) -> PipelineReport:
    """Replay one training step's panel schedule as per-bus event
    timelines; see the module docstring for the event model.

    ``digital_s`` is the measured host-side (digital) step time — quant
    prep, optimizer, bookkeeping — which runs concurrently with the
    photonic stream, so the step's front half is max(compute, digital)
    (feed it from ``BENCH_emu_kernel``'s fused-step measurement).
    ``recalibrate_every`` > 0 amortises one in-situ recalibration heater
    sweep (``st.heater``) over that many steps as a per-step epilogue —
    the sim-time cost the autotuner weighs against drift accuracy.

    ``trace`` exports the event timeline as Chrome-trace tracks (one per
    bus × stage, viewable in Perfetto): pass an ``obs.TraceRecorder`` to
    accumulate into, or a path to write a standalone trace JSON."""
    if not workload:
        raise ValueError("empty workload")
    st = components.stage_times(pcfg, f_s=f_s)
    ecfg = ecfg or energy_lib.EnergyConfig()
    per_bus, cycles_per_gemm, n_alive = _assign_slots(workload, pcfg, tiling)

    events = []
    bus_end = [0.0] * n_alive
    bus_busy = [0.0] * n_alive
    stage_busy = {s: 0.0 for s in components.STAGES}
    stage_busy["heater"] = 0.0
    for q in range(n_alive):
        now = 0.0
        for g, n_slots, n_real in per_bus[q]:
            # contiguous stream: n_slots panel slots × T samples each, one
            # sample per cycle — the pipeline never drains between slots
            # (fixed feedback weights; panel select is a routing choice,
            # not a thermal re-inscription)
            dur = n_slots * g.t * st.ii
            offset = 0.0
            for stage in components.STAGES:
                if len(events) < MAX_EVENTS:
                    events.append((q, stage, now + offset,
                                   now + offset + dur, g.name))
                stage_busy[stage] += dur
                offset += st.latency(stage)
            bus_busy[q] += n_real * g.t * st.ii
            now += dur
        if per_bus[q]:
            # the last sample's contribution clears the ADC one fill after
            # its cycle started — paid once per bus, the pipeline depth
            now += st.fill - st.ii
        bus_end[q] = now

    compute_s = max(bus_end)
    weight_update_s = 0.0
    if include_weight_update:
        # per-step epilogue: the forward banks take their weight update
        # through the heater DACs — thermal settling, in parallel across
        # buses but unhidden by the sample pipeline
        weight_update_s = st.heater
        for q in range(n_alive):
            if len(events) < MAX_EVENTS:
                events.append((q, "heater", compute_s,
                               compute_s + st.heater, "weight-update"))
            stage_busy["heater"] += st.heater
    recal_s = st.heater / recalibrate_every if recalibrate_every > 0 else 0.0
    wall = max(compute_s, digital_s) + weight_update_s + recal_s

    total_cycles = max(
        sum(n_slots for _g, n_slots, _r in per_bus[q]) for q in range(n_alive))
    macs = sum(g.macs for g in workload)
    f = 1.0 / st.ii
    peak = f * pcfg.bank_rows * pcfg.bank_cols * n_alive
    power = components.bank_power_w(pcfg, ecfg, f_s=f, n_buses=n_alive)
    energy_j = power * wall
    occupancy = {s: (b / (n_alive * wall) if wall > 0 else 0.0)
                 for s, b in stage_busy.items()}
    report = PipelineReport(
        wall_clock_s=wall,
        compute_s=compute_s,
        weight_update_s=weight_update_s,
        cycles=total_cycles,
        cycles_per_gemm=cycles_per_gemm,
        macs=macs,
        macs_per_s=macs / wall if wall > 0 else 0.0,
        peak_macs_per_s=peak,
        utilisation=(macs / wall) / peak if wall > 0 and peak > 0 else 0.0,
        occupancy=occupancy,
        bus_busy_s=bus_busy,
        power_w=power,
        energy_j=energy_j,
        energy_compute_j=power * compute_s,
        pj_per_mac=energy_j / macs * 1e12 if macs else float("inf"),
        n_buses=n_alive,
        f_s=f,
        tiling=tiling,
        events=events,
        digital_s=digital_s,
        recal_s=recal_s,
        recalibrate_every=recalibrate_every,
    )
    if trace is not None:
        from repro.obs import export  # lazy: obs is optional at sim time

        rec, path = export.resolve_recorder(trace)
        export.pipeline_to_trace(report, rec)
        if path is not None:
            export.write(rec, path)
    return report
