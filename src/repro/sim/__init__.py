"""``repro.sim`` — discrete-event, component-timed simulation of the
photonic training pipeline (paper Fig. 3, Eqs. 2–4).

The static layer (``photonics.gemm_cycles``, ``core.energy``) counts
cycles and prices watts; this package answers the *temporal* questions:
what wall-clock speed does a schedule actually reach once DAC settling,
modulation, ring response, BPD/TIA rise, ADC conversion, and heater
updates overlap — and which (n_buses, bank tiling, f_s) schedule is the
fastest one that fits a power budget.

* ``components`` — per-stage timing/power models from
  ``PhotonicConfig``/``MRRConfig``/``EnergyConfig``
* ``pipeline``   — replays the emulator's own panel schedule
  (``hardware.channel.tile_operands``) as per-bus event timelines
* ``autotune``   — searches the schedule space under a power budget

Entry points: ``api.build_session(schedule="auto")``,
``launch/train.py --autotune``, ``benchmarks/pipeline_sim.py``.
"""

from repro.sim.autotune import (DEFAULT_BUS_COUNTS, Candidate, TunedSchedule,
                                autotune)
from repro.sim.components import STAGES, StageTimes, bank_power_w, stage_times
from repro.sim.pipeline import (Gemm, PipelineReport, dfa_backward_workload,
                                panel_schedule, simulate)

__all__ = [
    "DEFAULT_BUS_COUNTS", "Candidate", "TunedSchedule", "autotune",
    "STAGES", "StageTimes", "bank_power_w", "stage_times",
    "Gemm", "PipelineReport", "dfa_backward_workload", "panel_schedule",
    "simulate",
]
