"""``repro.sim`` — discrete-event, component-timed simulation of the
photonic training pipeline (paper Fig. 3, Eqs. 2–4).

The static layer (``photonics.gemm_cycles``, ``core.energy``) counts
cycles and prices watts; this package answers the *temporal* questions:
what wall-clock speed does a schedule actually reach once DAC settling,
modulation, ring response, BPD/TIA rise, ADC conversion, and heater
updates overlap — and which (n_buses, bank tiling, f_s) schedule is the
fastest one that fits a power budget.

* ``components`` — per-stage timing/power models from
  ``PhotonicConfig``/``MRRConfig``/``EnergyConfig``
* ``pipeline``   — replays the emulator's own panel schedule
  (``hardware.channel.tile_operands``) as per-bus event timelines;
  ``forward_workload`` is the serving-side (inference GEMM) counterpart
  of ``dfa_backward_workload``
* ``serving``    — request-level timelines (arrivals → queueing →
  chunked prefill → decode rounds) with p50/p99 TTFT/latency, req/s and
  J/request per offered load
* ``autotune``   — searches the schedule space under a power budget
  (training) or an SLO + power budget (``autotune_serving``)

Entry points: ``api.build_session(schedule="auto")``,
``launch/train.py --autotune``, ``launch/serve.py --arrival-rate``,
``benchmarks/pipeline_sim.py``, ``benchmarks/serving.py``.
"""

from repro.sim.autotune import (DEFAULT_BUS_COUNTS, DEFAULT_RECAL_CANDIDATES,
                                DEFAULT_SLOT_COUNTS, Candidate,
                                ServingCandidate, TunedSchedule, TunedServing,
                                autotune, autotune_serving,
                                expected_drift_sigma)
from repro.sim.components import STAGES, StageTimes, bank_power_w, stage_times
from repro.sim.pipeline import (Gemm, PipelineReport, dfa_backward_workload,
                                forward_workload, panel_schedule, simulate)
from repro.sim.serving import (RequestSpec, ServiceModel, ServingReport,
                               poisson_requests, service_model,
                               simulate_serving)

__all__ = [
    "DEFAULT_BUS_COUNTS", "DEFAULT_RECAL_CANDIDATES", "DEFAULT_SLOT_COUNTS",
    "Candidate", "ServingCandidate", "TunedSchedule", "TunedServing",
    "autotune", "autotune_serving", "expected_drift_sigma",
    "STAGES", "StageTimes", "bank_power_w", "stage_times",
    "Gemm", "PipelineReport", "dfa_backward_workload", "forward_workload",
    "panel_schedule", "simulate",
    "RequestSpec", "ServiceModel", "ServingReport", "poisson_requests",
    "service_model", "simulate_serving",
]
