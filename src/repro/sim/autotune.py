"""Schedule autotuner: the fastest feasible (n_buses, tiling, f_s).

The knobs trade against each other under a wall-plug power budget:

* more buses — near-linear speedup on deep contractions (Eq. 2), but
  every bus adds its Eq. 4 ring/DAC/TIA/ADC stack (and, without a shared
  comb, its own laser stack);
* bank tiling — "panel" (the emulator's round-robin layout, per-GEMM bus
  quantization) vs "layer" (whole DFA layers per bus — coarser, but no
  idle-bus padding inside a GEMM);
* f_s — throughput is linear in the symbol rate, and so is the TIA term;
  under a tight budget, slower symbols can buy a bus that more than pays
  the rate back.

``autotune`` simulates every candidate with ``sim.pipeline.simulate`` on
the caller's actual workload and returns the fastest schedule whose
power fits the budget, with every evaluated candidate attached for
inspection (``TunedSchedule.candidates``).  ``repro.api.build_session``
exposes it as ``schedule="auto"``; ``launch/train.py`` as ``--autotune``.

``autotune_serving`` is the serving-plane dual: it replays a request
trace through ``sim.serving.simulate_serving`` for every
(n_buses, f_s, batch_slots) candidate and returns the *cheapest* one
holding p99 end-to-end latency under an SLO — power is the objective
and latency the constraint, where training tuning is the reverse.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import photonics
from repro.sim import components, pipeline

DEFAULT_BUS_COUNTS = (1, 2, 4, 8)
DEFAULT_TILINGS = ("panel", "layer")
DEFAULT_RECAL_CANDIDATES = (0, 100, 250, 500, 1000)


def expected_drift_sigma(device, recalibrate_every: int) -> float:
    """Expected per-ring detuning residual (OU model) at the end of a
    recalibration window of ``recalibrate_every`` training steps.

    The bank's resonance drift is the OU process of ``hardware.drift``:
    stationary σ = ``drift_sigma``, step time-constant ``drift_tau``.  A
    recalibration measures and cancels the drift up to ``cal_noise``; the
    residual then regrows toward stationary, so just before the next sweep

        σ_resid² = drift_sigma² · (1 − exp(−2·every/τ)) + cal_noise²

    ``recalibrate_every <= 0`` means never: the stationary drift_sigma.
    This is the accuracy proxy the autotuner holds under ``drift_budget``
    while pricing the sweep's sim-time cost (``PipelineReport.recal_s``).
    """
    if device is None or device.drift_sigma <= 0:
        return 0.0
    if recalibrate_every <= 0:
        return float(device.drift_sigma)
    grow = 1.0 - math.exp(-2.0 * recalibrate_every / device.drift_tau)
    return math.sqrt(device.drift_sigma ** 2 * grow + device.cal_noise ** 2)


@dataclasses.dataclass(frozen=True)
class Candidate:
    n_buses: int
    tiling: str
    f_s: float
    power_w: float
    feasible: bool
    wall_clock_s: float | None  # None when skipped on power
    report: pipeline.PipelineReport | None
    # recalibration co-tuning (defaults keep positional callers working)
    recalibrate_every: int = 0
    drift_resid: float = 0.0  # expected_drift_sigma at this cadence


@dataclasses.dataclass(frozen=True)
class TunedSchedule:
    """The winning schedule plus the full search record."""

    n_buses: int
    tiling: str
    f_s: float
    power_w: float
    report: pipeline.PipelineReport
    power_budget_w: float | None
    candidates: tuple
    # recalibration co-tuning (defaulted: pre-existing callers unchanged)
    recalibrate_every: int = 0
    drift_resid: float = 0.0
    drift_budget: float | None = None
    digital_s: float = 0.0

    @property
    def wall_clock_s(self) -> float:
        return self.report.wall_clock_s

    def apply(self, pcfg: photonics.PhotonicConfig) -> photonics.PhotonicConfig:
        """The tuned hardware description: bus count and symbol rate set.
        (Tiling is a scheduling policy, not a device property — the
        emulator always runs the "panel" layout; the math is identical.)
        """
        return dataclasses.replace(pcfg, n_buses=self.n_buses, f_s=self.f_s)

    def describe(self) -> str:
        r = self.report
        recal = (f" recal@{self.recalibrate_every} "
                 f"(σ_resid={self.drift_resid:.3f})"
                 if self.recalibrate_every > 0 else "")
        return (f"n_buses={self.n_buses} tiling={self.tiling} "
                f"f_s={self.f_s / 1e9:.2f}GHz -> "
                f"{r.wall_clock_s * 1e6:.2f}us/step "
                f"{r.macs_per_s / 1e12:.3f}TMAC/s {r.power_w:.1f}W "
                f"{r.pj_per_mac:.2f}pJ/MAC{recal}")


def default_f_s_grid(f_max: float) -> tuple:
    """Symbol-rate candidates: the DAC limit and two halvings of it."""
    return (f_max, f_max / 2.0, f_max / 4.0)


DEFAULT_SLOT_COUNTS = (4, 8, 16)


@dataclasses.dataclass(frozen=True)
class ServingCandidate:
    n_buses: int
    f_s: float
    batch_slots: int
    power_w: float
    feasible: bool  # fits the power budget
    meets_slo: bool
    p99_latency_s: float | None  # None when skipped on power
    requests_per_s: float | None
    report: object | None  # serving.ServingReport


@dataclasses.dataclass(frozen=True)
class TunedServing:
    """The cheapest SLO-meeting serving configuration + search record."""

    n_buses: int
    f_s: float
    batch_slots: int
    power_w: float
    report: object  # serving.ServingReport
    slo_p99_s: float
    power_budget_w: float | None
    candidates: tuple

    def apply(self, pcfg: photonics.PhotonicConfig) -> photonics.PhotonicConfig:
        """The tuned hardware description (batch_slots is an engine knob,
        not a device property — pass it to ``Engine``/``Session.engine``)."""
        return dataclasses.replace(pcfg, n_buses=self.n_buses, f_s=self.f_s)

    def describe(self) -> str:
        r = self.report
        return (f"n_buses={self.n_buses} f_s={self.f_s / 1e9:.2f}GHz "
                f"batch_slots={self.batch_slots} -> "
                f"p99 {r.latency_p99_s * 1e3:.2f}ms "
                f"{r.requests_per_s:.1f}req/s {self.power_w:.1f}W "
                f"{r.j_per_request * 1e3:.2f}mJ/req")


def autotune_serving(model, requests, pcfg: photonics.PhotonicConfig, ecfg=None, *,
                     slo_p99_s: float, power_budget_w: float | None = None,
                     bus_counts: tuple = DEFAULT_BUS_COUNTS,
                     f_s_grid: tuple | None = None,
                     slot_counts: tuple = DEFAULT_SLOT_COUNTS,
                     prefill_chunk: int = 16) -> TunedServing:
    """SLO-constrained serving search over (n_buses, f_s, batch_slots).

    Every candidate replays the *same* request trace through
    ``sim.serving.simulate_serving``; among candidates that fit the power
    budget AND hold p99 end-to-end latency under ``slo_p99_s``, the
    cheapest (lowest wall-plug power) wins, ties broken by higher
    requests/s — the serving dual of ``autotune``'s "fastest under a
    budget".  Raises ValueError when nothing meets the SLO in budget,
    naming the closest miss.
    """
    from repro.sim import serving

    if f_s_grid is None:
        f_s_grid = default_f_s_grid(pcfg.f_s)
    candidates = []
    best = None
    closest = None  # least-bad p99 among in-budget candidates
    for n_buses in sorted(set(bus_counts)):
        cand_cfg = dataclasses.replace(pcfg, n_buses=n_buses)
        n_alive = photonics.active_buses(cand_cfg)
        for f_s in sorted(set(f_s_grid), reverse=True):
            power = components.bank_power_w(cand_cfg, ecfg, f_s=f_s,
                                            n_buses=n_alive)
            in_budget = power_budget_w is None or power <= power_budget_w
            if not in_budget:
                for slots in slot_counts:
                    candidates.append(ServingCandidate(
                        n_buses, f_s, slots, power, False, False,
                        None, None, None))
                continue
            svc = serving.service_model(model, cand_cfg, ecfg, f_s=f_s)
            for slots in sorted(set(slot_counts)):
                report = serving.simulate_serving(
                    requests, svc, batch_slots=slots,
                    prefill_chunk=prefill_chunk)
                meets = report.latency_p99_s <= slo_p99_s
                cand = ServingCandidate(
                    n_buses, f_s, slots, power, True, meets,
                    report.latency_p99_s, report.requests_per_s, report)
                candidates.append(cand)
                if closest is None or report.latency_p99_s < closest.p99_latency_s:
                    closest = cand
                if meets:
                    key = (power, -report.requests_per_s, n_buses)
                    if best is None or key < best[0]:
                        best = (key, cand)
    if best is None:
        if closest is None:
            min_power = min(c.power_w for c in candidates)
            raise ValueError(
                f"no serving candidate fits power_budget_w={power_budget_w:.2f} "
                f"(cheapest needs {min_power:.2f} W)")
        raise ValueError(
            f"no in-budget candidate meets p99 SLO {slo_p99_s * 1e3:.2f} ms "
            f"(closest: n_buses={closest.n_buses} f_s={closest.f_s / 1e9:.2f}GHz "
            f"batch_slots={closest.batch_slots} at "
            f"{closest.p99_latency_s * 1e3:.2f} ms)")
    _, cand = best
    return TunedServing(
        n_buses=cand.n_buses, f_s=cand.f_s, batch_slots=cand.batch_slots,
        power_w=cand.power_w, report=cand.report, slo_p99_s=slo_p99_s,
        power_budget_w=power_budget_w, candidates=tuple(candidates))


def autotune(workload, pcfg: photonics.PhotonicConfig, ecfg=None, *,
             power_budget_w: float | None = None,
             bus_counts: tuple = DEFAULT_BUS_COUNTS,
             f_s_grid: tuple | None = None,
             tilings: tuple = DEFAULT_TILINGS,
             include_weight_update: bool = True,
             digital_s: float = 0.0,
             recal_candidates: tuple = (0,),
             drift_budget: float | None = None) -> TunedSchedule:
    """Exhaustive search of the (small) schedule space on the real
    workload.  Raises ValueError when no candidate fits the budget.

    ``digital_s`` overlaps the measured host-side step time with every
    candidate timeline (``pipeline.simulate``'s max(compute, digital) —
    feed it from the fused-kernel bench).  ``recal_candidates`` widens the
    search over the recalibration cadence: each cadence pays its amortised
    heater sweep in sim time while ``expected_drift_sigma`` prices its
    accuracy; candidates whose expected residual exceeds ``drift_budget``
    are infeasible.  The fastest feasible schedule wins; ties go to lower
    power, fewer buses, then lower drift residual."""
    if f_s_grid is None:
        f_s_grid = default_f_s_grid(pcfg.f_s)
    device = pcfg.mrr
    recal_grid = tuple(sorted(set(int(e) for e in recal_candidates)))
    candidates = []
    best = None
    for n_buses in sorted(set(bus_counts)):
        # the chip's failed buses ride along: a degraded chip is tuned (and
        # its report priced) as the degraded chip it is — dead buses carry
        # no panels and draw no power, exactly as the session will run it
        cand_cfg = dataclasses.replace(pcfg, n_buses=n_buses)
        n_alive = photonics.active_buses(cand_cfg)
        for f_s in sorted(set(f_s_grid), reverse=True):
            power = components.bank_power_w(cand_cfg, ecfg, f_s=f_s,
                                            n_buses=n_alive)
            if power_budget_w is not None and power > power_budget_w:
                for tiling in tilings:
                    for every in recal_grid:
                        candidates.append(Candidate(
                            n_buses, tiling, f_s, power, False, None, None,
                            every, expected_drift_sigma(device, every)))
                continue
            for tiling in tilings:
                for every in recal_grid:
                    resid = expected_drift_sigma(device, every)
                    in_budget = drift_budget is None or resid <= drift_budget
                    report = pipeline.simulate(
                        workload, cand_cfg, ecfg, f_s=f_s, tiling=tiling,
                        include_weight_update=include_weight_update,
                        digital_s=digital_s, recalibrate_every=every)
                    cand = Candidate(n_buses, tiling, f_s, power, in_budget,
                                     report.wall_clock_s, report,
                                     every, resid)
                    candidates.append(cand)
                    if not in_budget:
                        continue
                    # fastest wins; ties go to the lower-power, fewer-bus
                    # chip, then the tighter-calibrated schedule
                    key = (report.wall_clock_s, power, n_buses, resid)
                    if best is None or key < best[0]:
                        best = (key, cand)
    if best is None:
        in_power = [c for c in candidates
                    if power_budget_w is None or c.power_w <= power_budget_w]
        if not in_power:
            min_power = min(c.power_w for c in candidates)
            raise ValueError(
                f"no schedule fits power_budget_w={power_budget_w:.2f} "
                f"(cheapest candidate needs {min_power:.2f} W)")
        min_resid = min(c.drift_resid for c in in_power)
        raise ValueError(
            f"no in-power schedule meets drift_budget={drift_budget:.4f} "
            f"(tightest cadence leaves σ_resid={min_resid:.4f} — add "
            f"smaller recal_candidates or relax the budget)")
    _, cand = best
    return TunedSchedule(
        n_buses=cand.n_buses, tiling=cand.tiling, f_s=cand.f_s,
        power_w=cand.power_w, report=cand.report,
        power_budget_w=power_budget_w, candidates=tuple(candidates),
        recalibrate_every=cand.recalibrate_every,
        drift_resid=cand.drift_resid, drift_budget=drift_budget,
        digital_s=digital_s)
