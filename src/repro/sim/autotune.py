"""Schedule autotuner: the fastest feasible (n_buses, tiling, f_s).

The knobs trade against each other under a wall-plug power budget:

* more buses — near-linear speedup on deep contractions (Eq. 2), but
  every bus adds its Eq. 4 ring/DAC/TIA/ADC stack (and, without a shared
  comb, its own laser stack);
* bank tiling — "panel" (the emulator's round-robin layout, per-GEMM bus
  quantization) vs "layer" (whole DFA layers per bus — coarser, but no
  idle-bus padding inside a GEMM);
* f_s — throughput is linear in the symbol rate, and so is the TIA term;
  under a tight budget, slower symbols can buy a bus that more than pays
  the rate back.

``autotune`` simulates every candidate with ``sim.pipeline.simulate`` on
the caller's actual workload and returns the fastest schedule whose
power fits the budget, with every evaluated candidate attached for
inspection (``TunedSchedule.candidates``).  ``repro.api.build_session``
exposes it as ``schedule="auto"``; ``launch/train.py`` as ``--autotune``.
"""

from __future__ import annotations

import dataclasses

from repro.core import photonics
from repro.sim import components, pipeline

DEFAULT_BUS_COUNTS = (1, 2, 4, 8)
DEFAULT_TILINGS = ("panel", "layer")


@dataclasses.dataclass(frozen=True)
class Candidate:
    n_buses: int
    tiling: str
    f_s: float
    power_w: float
    feasible: bool
    wall_clock_s: float | None  # None when skipped on power
    report: pipeline.PipelineReport | None


@dataclasses.dataclass(frozen=True)
class TunedSchedule:
    """The winning schedule plus the full search record."""

    n_buses: int
    tiling: str
    f_s: float
    power_w: float
    report: pipeline.PipelineReport
    power_budget_w: float | None
    candidates: tuple

    @property
    def wall_clock_s(self) -> float:
        return self.report.wall_clock_s

    def apply(self, pcfg: photonics.PhotonicConfig) -> photonics.PhotonicConfig:
        """The tuned hardware description: bus count and symbol rate set.
        (Tiling is a scheduling policy, not a device property — the
        emulator always runs the "panel" layout; the math is identical.)
        """
        return dataclasses.replace(pcfg, n_buses=self.n_buses, f_s=self.f_s)

    def describe(self) -> str:
        r = self.report
        return (f"n_buses={self.n_buses} tiling={self.tiling} "
                f"f_s={self.f_s / 1e9:.2f}GHz -> "
                f"{r.wall_clock_s * 1e6:.2f}us/step "
                f"{r.macs_per_s / 1e12:.3f}TMAC/s {r.power_w:.1f}W "
                f"{r.pj_per_mac:.2f}pJ/MAC")


def default_f_s_grid(f_max: float) -> tuple:
    """Symbol-rate candidates: the DAC limit and two halvings of it."""
    return (f_max, f_max / 2.0, f_max / 4.0)


def autotune(workload, pcfg: photonics.PhotonicConfig, ecfg=None, *,
             power_budget_w: float | None = None,
             bus_counts: tuple = DEFAULT_BUS_COUNTS,
             f_s_grid: tuple | None = None,
             tilings: tuple = DEFAULT_TILINGS,
             include_weight_update: bool = True) -> TunedSchedule:
    """Exhaustive search of the (small) schedule space on the real
    workload.  Raises ValueError when no candidate fits the budget."""
    if f_s_grid is None:
        f_s_grid = default_f_s_grid(pcfg.f_s)
    candidates = []
    best = None
    for n_buses in sorted(set(bus_counts)):
        # the chip's failed buses ride along: a degraded chip is tuned (and
        # its report priced) as the degraded chip it is — dead buses carry
        # no panels and draw no power, exactly as the session will run it
        cand_cfg = dataclasses.replace(pcfg, n_buses=n_buses)
        n_alive = photonics.active_buses(cand_cfg)
        for f_s in sorted(set(f_s_grid), reverse=True):
            power = components.bank_power_w(cand_cfg, ecfg, f_s=f_s,
                                            n_buses=n_alive)
            if power_budget_w is not None and power > power_budget_w:
                for tiling in tilings:
                    candidates.append(Candidate(n_buses, tiling, f_s, power,
                                                False, None, None))
                continue
            for tiling in tilings:
                report = pipeline.simulate(
                    workload, cand_cfg, ecfg, f_s=f_s, tiling=tiling,
                    include_weight_update=include_weight_update)
                cand = Candidate(n_buses, tiling, f_s, power, True,
                                 report.wall_clock_s, report)
                candidates.append(cand)
                # fastest wins; ties go to the lower-power, fewer-bus chip
                key = (report.wall_clock_s, power, n_buses)
                if best is None or key < best[0]:
                    best = (key, cand)
    if best is None:
        min_power = min(c.power_w for c in candidates)
        raise ValueError(
            f"no schedule fits power_budget_w={power_budget_w:.2f} "
            f"(cheapest candidate needs {min_power:.2f} W)")
    _, cand = best
    return TunedSchedule(
        n_buses=cand.n_buses, tiling=cand.tiling, f_s=cand.f_s,
        power_w=cand.power_w, report=cand.report,
        power_budget_w=power_budget_w, candidates=tuple(candidates))
