"""Per-stage timing and power models of the photonic pipeline.

The paper's throughput claim (Eq. 2, Fig. 3) is a *pipeline* claim: a new
input vector enters the chip every operational cycle (the DAC-limited
initiation interval 1/f_s) while earlier vectors are still in flight
through the downstream stages.  The stages, in signal order:

    dac   input DAC settling        — one sample period (the design is
                                      DAC-throughput-limited, paper §5)
    mod   MZM electro-optic encode  — tens of ps (carrier-depletion EO
                                      response, effectively instantaneous)
    ring  MRR cavity response       — the photon lifetime of the loaded
                                      resonator, ~ps at the paper's Q
    bpd   BPD + TIA rise            — 0.35 / receiver bandwidth, with the
                                      receiver matched to the symbol rate
    adc   ADC conversion            — a pipelined converter: one sample
                                      per cycle throughput, a few cycles
                                      of conversion latency

A sixth, *off-pipeline* activity is the heater update: re-inscribing a
ring's weight waits on the thermal settling time (µs — 4+ orders slower
than a cycle).  It never sits on the per-sample path — feedback matrices
are fixed and forward weights update once per training step — but the
simulator prices it wherever weights actually change (the per-step update
epilogue, recalibration sweeps).

``StageTimes`` carries the resolved latencies; ``stage_times`` derives
them from a ``PhotonicConfig`` (+ its optional ``MRRConfig``) so the
simulator, the emulator, and the energy model read the same hardware
description.  Powers stay single-sourced in ``core.energy`` (Eq. 3/4).
"""

from __future__ import annotations

import dataclasses

from repro.core import photonics
from repro.hardware.mrr import MRRConfig

# stage names in signal order — the pipeline the event timeline models
STAGES = ("dac", "mod", "ring", "bpd", "adc")

# electro-optic modulation response: effectively instantaneous next to a
# 100 ps cycle, kept nonzero so the fill latency is honest
MOD_LATENCY_S = 20e-12
# photon lifetime of the loaded resonator (Q ~ 1e4 at 193 THz)
RING_LATENCY_S = 10e-12
# pipelined-ADC conversion latency, in operational cycles
ADC_LATENCY_CYCLES = 4.0


@dataclasses.dataclass(frozen=True)
class StageTimes:
    """Resolved per-stage latencies [s] of one bus's signal chain."""

    ii: float  # initiation interval: one sample period, 1/f_s
    dac: float
    mod: float
    ring: float
    bpd: float
    adc: float
    heater: float  # weight re-inscription (thermal settling), off-pipeline

    @property
    def fill(self) -> float:
        """Pipeline depth: latency from a sample entering the DAC to its
        contribution leaving the ADC."""
        return self.dac + self.mod + self.ring + self.bpd + self.adc

    def latency(self, stage: str) -> float:
        return getattr(self, stage)


def stage_times(pcfg: photonics.PhotonicConfig,
                f_s: float | None = None) -> StageTimes:
    """Derive the stage latencies from the hardware description.

    ``f_s`` overrides the config's operational rate (the autotuner sweeps
    it); the receiver chain is assumed rate-matched, so the BPD/TIA rise
    and the ADC latency scale with the symbol period.
    """
    f = float(f_s if f_s is not None else pcfg.f_s)
    if f <= 0.0:
        raise ValueError(f"operational rate must be positive, got {f}")
    ii = 1.0 / f
    device = pcfg.mrr or MRRConfig()
    return StageTimes(
        ii=ii,
        dac=ii,  # settles within one sample period (DAC-limited design)
        mod=MOD_LATENCY_S,
        ring=RING_LATENCY_S,
        bpd=0.35 / f,  # 10–90% rise of a rate-matched receiver
        adc=ADC_LATENCY_CYCLES * ii,
        heater=float(device.thermal_settle_s),
    )


def bank_power_w(pcfg: photonics.PhotonicConfig, ecfg=None,
                 f_s: float | None = None, n_buses: int | None = None) -> float:
    """Wall-plug power of the modelled chip (Eq. 4 via ``core.energy``),
    with the simulator's knobs (f_s, bus count) applied on top of the
    energy config — the autotuner's feasibility measure."""
    from repro.core import energy

    base = ecfg or energy.EnergyConfig()
    cfg = dataclasses.replace(
        base,
        f_s=float(f_s if f_s is not None else pcfg.f_s),
        n_buses=int(n_buses if n_buses is not None
                    else photonics.active_buses(pcfg)),
    )
    return energy.total_power(pcfg.bank_rows, pcfg.bank_cols, cfg)
