"""Microring-resonator device physics: the Lorentzian transfer function and
the weight → heater-detuning inscription (and its exact inverse).

The paper's weight bank (§2) encodes each weight in one MRR read out by a
balanced photodetector: the through- and drop-port photocurrents subtract,
so the *effective* weight seen by the analog MAC is

    w(δ) = T_thru(δ) - T_drop(δ) = 1 - 2·γ² / (γ² + δ²)
         = (δ² - γ²) / (δ² + γ²)                          (Lorentzian BPD)

where δ is the ring's detuning from the carrier (in the same units as the
half-width γ).  δ = 0 (on resonance) gives w = -1 (all drop), δ → ∞ gives
w = +1 (all through), δ = γ crosses w = 0.  Detuning is set thermally: the
heater drive tunes δ over [0, delta_max]; ``inscribe`` is the controller's
lookup-table inversion

    δ(w) = γ · sqrt((1 + w) / (1 - w))

which is the *exact* inverse of ``ring_weight`` on [-1, w_ceiling].  Weights
at exactly +1 are unreachable (infinite detuning); the inscription clips at
``w_ceiling(cfg)`` — with the default 100·γ tuning range that is an
inscription error ≤ 2e-4 (≈ 12 bits), far below the measured analog noise.

Everything here is plain ``jnp`` and differentiable; the signal chain that
composes these pieces into a weight-bank matmul lives in
``repro.hardware.channel``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# f32 cannot resolve weights closer to 1 than its epsilon — clip there even
# when the heater range allows more.
_W_EPS = 1e-7


@dataclasses.dataclass(frozen=True)
class MRRConfig:
    """Device-level nonidealities of one physical MRR weight bank.

    The defaults model a realistic thermally-tuned bank (drift ON): pair
    with ``TrainerConfig.recalibrate_every`` to study in-situ calibration.
    ``MRRConfig.ideal()`` zeroes every nonideality — used by the
    backend-equivalence tests and the ``emu_ideal`` preset.
    """

    gamma: float = 1.0  # Lorentzian half-width (detuning units)
    delta_max: float = 100.0  # heater tuning range, in gamma units · gamma
    heater_bits: int | None = 12  # heater-DAC resolution over [0, delta_max]
    adc_bits: int | None = None  # per-pass output ADC (full scale = bank_cols)
    crosstalk: float = 0.005  # nearest-neighbour thermal coupling coefficient
    # thermal coupling to the same (row, col) ring of the adjacent bus's
    # bank — multi-bus layouts stack the banks, so each ring also sees its
    # inter-bus neighbours (0 = buses thermally isolated)
    bus_crosstalk: float = 0.0
    compensate_crosstalk: bool = True  # calibration pre-inverts the coupling
    ct_iters: int = 2  # Jacobi iterations of the crosstalk inversion
    shot_noise: float = 0.0  # signal-dependent BPD noise: σ·sqrt(|p|) per pass
    drift_sigma: float = 0.05  # OU stationary detuning-drift std (gamma units)
    drift_tau: float = 1000.0  # OU relaxation time (training steps)
    cal_noise: float = 0.005  # detuning measurement noise of a calibration sweep
    # fabrication yield: fraction of rings dead on arrival (stuck dark —
    # their BPD contribution reads 0).  The dead set is a fixed property of
    # the chip, drawn deterministically from ``yield_seed``.
    dead_ring_rate: float = 0.0
    yield_seed: int = 0
    # heater thermal settling time [s] — the latency of re-inscribing a
    # ring's weight (repro.sim prices weight updates/recalibration with it;
    # the per-sample streaming path never waits on it)
    thermal_settle_s: float = 2e-6

    @classmethod
    def ideal(cls) -> "MRRConfig":
        """A bank with every nonideality off: exact Lorentzian round-trip
        only (inscription error ~1e-7, i.e. f32 epsilon)."""
        return cls(delta_max=1e6, heater_bits=None, adc_bits=None,
                   crosstalk=0.0, shot_noise=0.0, drift_sigma=0.0,
                   cal_noise=0.0)

    @property
    def stateful(self) -> bool:
        """True when the device drifts — training must carry hardware state."""
        return self.drift_sigma > 0.0


def dead_ring_mask(cfg: MRRConfig, shape: tuple):
    """1/0 survival mask over the physical ring grid (``shape`` is usually
    (n_buses, rows, cols)).  The dead set is chip-fixed: deterministic in
    ``yield_seed`` and independent of the training step or PRNG stream."""
    if cfg.dead_ring_rate <= 0.0:
        return jnp.ones(shape, jnp.float32)
    import jax

    key = jax.random.PRNGKey(cfg.yield_seed ^ 0xDEAD)
    alive = jax.random.bernoulli(key, 1.0 - cfg.dead_ring_rate, shape)
    return alive.astype(jnp.float32)


def ring_weight(delta, gamma: float = 1.0):
    """Lorentzian BPD transfer: detuning -> effective weight in [-1, 1)."""
    d2 = jnp.square(delta)
    g2 = gamma * gamma
    return (d2 - g2) / (d2 + g2)


def w_ceiling(cfg: MRRConfig) -> float:
    """Largest inscribable weight: the transfer at full heater range
    (python float — exact, config-static)."""
    d2 = cfg.delta_max * cfg.delta_max
    g2 = cfg.gamma * cfg.gamma
    return min((d2 - g2) / (d2 + g2), 1.0 - _W_EPS)


def inscribe(w, cfg: MRRConfig):
    """Weight -> heater detuning δ(w) = γ·sqrt((1+w)/(1-w)); the exact
    inverse of ``ring_weight`` after clipping to the reachable range."""
    w_c = jnp.clip(w, -1.0, w_ceiling(cfg))
    return cfg.gamma * jnp.sqrt((1.0 + w_c) / (1.0 - w_c))


def _shifted(x, axis: int, off: int):
    """x shifted by ``off`` along ``axis``, zero-filled at the edge.
    Static pad + slice (not a gather): this runs inside the inscription's
    Jacobi sweeps on megaring panel stacks, where an indexed ``take``
    costs ~10× the copy."""
    n = x.shape[axis]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (max(off, 0), max(-off, 0))
    lo = max(-off, 0)
    return jax.lax.slice_in_dim(jnp.pad(x, pad), lo, lo + n, axis=axis)


def grid_axes(x) -> tuple[int, int]:
    """(row_axis, col_axis) of the physical ring grid for the supported
    layouts: a bare (rows, cols) grid, the tiled (..., rows, nk, cols)
    panel stack where a k-tile axis sits between rows and cols, or the
    bus-stacked (..., n_buses, rows, nk, cols) layout — rows stay at -3
    in every stacked form."""
    return ((-3, -1) if x.ndim >= 3 else (-2, -1))


def bus_axis_of(x) -> int | None:
    """The bus axis of a panel stack, or None when the layout carries no
    bus dimension.  Only the full (nm, n_buses, rows, nk, cols) tiling
    (ndim >= 5, bus axis at -4) is inferable — a 4-D stack is ambiguous
    with the bus-free (nm, rows, nk, cols) layout, and bare
    (n_buses, rows, cols) state grids must pass the axis explicitly."""
    return -4 if x.ndim >= 5 else None


def _edge_pair_sum(x, axis: int):
    """x shifted +1 plus x shifted −1 along ``axis`` (zero edges) off ONE
    shared (1, 1)-padded buffer.  Numerically identical to two ``_shifted``
    calls added in (+1, −1) order, but XLA:CPU fuses the shared pad where
    separate pads get duplicated into every consumer of the Jacobi
    expansion — on megaring panel stacks that duplication is ~3× the whole
    inscription cost."""
    n = x.shape[axis]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (1, 1)
    xp = jnp.pad(x, pad)
    return (jax.lax.slice_in_dim(xp, 0, n, axis=axis)
            + jax.lax.slice_in_dim(xp, 2, 2 + n, axis=axis))


def neighbor_sum(delta, row_axis: int | None = None, col_axis: int | None = None):
    """Sum of the 4 nearest neighbours on the physical (rows, cols) ring
    grid — the thermal-crosstalk aggressor field.  Axes default to the
    layout inferred by ``grid_axes``."""
    if row_axis is None or col_axis is None:
        row_axis, col_axis = grid_axes(delta)
    return _edge_pair_sum(delta, row_axis) + _edge_pair_sum(delta, col_axis)


def crosstalk_leak(delta_cmd, cfg: MRRConfig, row_axis: int | None = None,
                   col_axis: int | None = None, bus_axis: int | None = None):
    """Thermal power leaked into each ring by its neighbours: the intra-bus
    (row, col) grid coupling plus — when the layout carries a bus axis —
    the inter-bus coupling to the same ring position on adjacent banks."""
    leak = None
    if cfg.crosstalk != 0.0:
        leak = cfg.crosstalk * neighbor_sum(delta_cmd, row_axis, col_axis)
    if cfg.bus_crosstalk != 0.0:
        if bus_axis is None:
            bus_axis = bus_axis_of(delta_cmd)
        if bus_axis is not None and delta_cmd.shape[bus_axis] > 1:
            bus = cfg.bus_crosstalk * _edge_pair_sum(delta_cmd, bus_axis)
            leak = bus if leak is None else leak + bus
    if leak is None:
        return jnp.zeros_like(delta_cmd)
    return leak
