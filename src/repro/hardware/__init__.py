"""``repro.hardware`` — physics-grade MRR weight-bank emulation.

Layout (one concern per module):

* ``mrr``       — Lorentzian ring transfer, weight→heater inscription,
  thermal-crosstalk geometry, and the ``MRRConfig`` device description
* ``channel``   — the composable signal chain (DAC → modulator → ring bank
  → balanced photodetector → ADC), tiled over bank panels and scheduled
  across the parallel WDM buses (``PhotonicConfig.n_buses``); the "emu"
  ``PhotonicBackend`` calls ``channel.emulated_matmul``
* ``drift``     — stateful per-ring resonance drift (OU process) + the
  context that threads the Trainer's carried hardware state into the chain
* ``calibrate`` — in-situ calibration: LUT inversion, crosstalk
  pre-compensation, periodic recalibration sweeps

Import discipline: ``core.photonics`` imports ``repro.hardware.mrr`` (for
``PhotonicConfig.mrr`` and the emu presets), and ``channel``/``calibrate``
import ``core.photonics`` back — so this ``__init__`` eagerly loads ONLY
the leaf ``mrr`` module and resolves the rest lazily (PEP 562), keeping the
package import-cycle-free from either direction.
"""

from __future__ import annotations

import importlib

from repro.hardware.mrr import MRRConfig

_SUBMODULES = ("mrr", "channel", "drift", "calibrate")


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.hardware.{name}")
    raise AttributeError(f"module 'repro.hardware' has no attribute {name!r}")


def __dir__():
    return sorted([*globals(), *_SUBMODULES])


__all__ = ["MRRConfig", *_SUBMODULES]
