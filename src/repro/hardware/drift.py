"""Stateful resonance drift: an Ornstein–Uhlenbeck process per physical
ring, carried through training as hardware state.

Real MRR banks drift — ambient temperature, heater aging, and slow laser
wander all move each ring's resonance between calibration sweeps.  We model
the per-ring detuning error as a discrete OU process

    d[t+1] = a · d[t] + σ·sqrt(1 - a²) · ε,    a = exp(-1 / τ)

whose stationary distribution is N(0, σ²) regardless of the step count —
so long runs degrade realistically instead of diverging.  The state dict

    {"drift": (n_buses, bank_rows, bank_cols),  # detuning error, per ring
     "cal":   (n_buses, bank_rows, bank_cols)}  # estimate at last sweep

is created by ``init_state`` (a freshly calibrated chip: both zero),
advanced once per train step by ``repro.hardware.calibrate.advance``, and
carried in the Trainer's state pytree (checkpointed, replicated, donated
like any other state).  Only the *residual* ``drift - cal`` is visible to
the signal chain: the controller subtracts its estimate when commanding
heaters, so calibration quality is exactly what bounds the realized error.

The active state reaches the emulated matmul through a context stack
(``use_state``): the Trainer pushes the step's state while tracing the
jitted train step, and ``repro.hardware.channel`` reads it from inside the
DFA projection without every intermediate API needing a new argument.
Outside any context the residual is zero — a drift-free (statically
calibrated) bank.
"""

from __future__ import annotations

import contextlib
import math

import jax
import jax.numpy as jnp

from repro.utils import prng


def init_state(cfg, key=None) -> dict:
    """Fresh hardware state for a ``PhotonicConfig``-shaped bank: a just-
    calibrated chip (zero drift, zero stored estimate).  The leading axis
    is the WDM bus — one physical (rows, cols) ring grid per bus, so the
    carried state is (n_buses, bank_rows, bank_cols).  ``key`` is unused
    today but kept so a future warm-start draw stays call-compatible."""
    shape = (max(getattr(cfg, "n_buses", 1), 1), cfg.bank_rows, cfg.bank_cols)
    return {"drift": jnp.zeros(shape, jnp.float32),
            "cal": jnp.zeros(shape, jnp.float32)}


def ou_step(x, key, sigma: float, tau: float):
    """One discrete OU step with stationary std ``sigma`` and relaxation
    time ``tau`` (in steps)."""
    a = math.exp(-1.0 / max(tau, 1e-9))
    s = sigma * math.sqrt(max(1.0 - a * a, 0.0))
    return a * x + s * jax.random.normal(prng.consume(key), x.shape, x.dtype)


def residual(state: dict):
    """The detuning error the controller has NOT compensated."""
    return state["drift"] - state["cal"]


# --------------------------------------------------------------------------
# Active-state context (threads drift through jit tracing)
# --------------------------------------------------------------------------

_ACTIVE: list = []


@contextlib.contextmanager
def use_state(state: dict):
    """Make ``state`` visible to ``channel.emulated_matmul`` for the dynamic
    extent of the block.  Safe under jit: the Trainer enters the context
    inside the traced step function, so the tracers it exposes are inputs of
    the same trace that consumes them."""
    _ACTIVE.append(state)
    try:
        yield state
    finally:
        _ACTIVE.pop()


def active_state() -> dict | None:
    return _ACTIVE[-1] if _ACTIVE else None
