"""The emulated analog signal chain: DAC → modulator → MRR bank (with
crosstalk + drift) → balanced photodetector → ADC, tiled over bank panels.

This is the device-fidelity twin of ``core.photonics.photonic_matmul``.
Both share ``photonics.normalise_operands`` (per-tensor amplitude encoding
into the photonic [-1, 1] range plus the input/weight fake-quant), so the
"emu" backend drops into every call site of the ``ref``/``pallas``
backends unchanged.  What differs is everything between encode and rescale:

1.  The GeMM compiler's tiling (paper §3): A:(T,K)·B:(M,K)ᵀ is split into
    ⌈M/bank_rows⌉ × ⌈K/bank_cols⌉ panels.  With ``cfg.n_buses`` WDM buses
    the contraction panels are scheduled round-robin across the buses —
    each bus is a full physical (rows, cols) bank with its own
    modulator/DAC and BPD/ADC chain, so ⌈panels / n_buses⌉ parallel
    cycles replace the single-bus panel sequence.  Per-ring
    drift/crosstalk state has shape (n_buses, bank_rows, bank_cols) and
    is shared across the panels each bus executes.
2.  Weight inscription (``calibrate.command_deltas``): Lorentzian LUT
    inversion, crosstalk pre-compensation, heater-DAC quantization.
3.  The physical leak + drift residual perturb the commanded detunings;
    ``mrr.ring_weight`` maps them back to the *realized* weights.
4.  Per-pass BPD noise: the thermal/read floor (``cfg.noise_std``, same
    convention as the abstract model — per-pass "absolute" or bank
    full-scale) plus signal-dependent shot noise, then the per-pass ADC.
5.  Passes accumulate digitally; the result is rescaled and the optional
    Hadamard mask (the TIA gain epilogue) applies after noise, as on chip.

With ``MRRConfig.ideal()`` and ``noise_std=0`` the chain is numerically the
plain matmul (inscription round-trips exactly); with nonzero ``noise_std``
and no device effects the accumulated noise is statistically identical to
the reference path's single draw — tests/test_hardware.py holds both.

Everything is pure jnp on tile-stacked arrays (the tile axes ride through
``einsum``, i.e. implicitly vmapped), so callers can jit/vmap/grad through
it; the Trainer jits it as part of the train step.
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.core import photonics
from repro.hardware import calibrate
from repro.hardware import drift as drift_lib
from repro.hardware import mrr
from repro.lint.runtime import check_finite
from repro.utils import prng


def _pad_axis(x, mult: int, axis: int):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def tile_operands(a_n, b_n, cfg):
    """Split normalised operands into bank-sized panels scheduled across
    the surviving parallel buses (``photonics.active_buses`` — failed
    buses carry no panels; the scheduler reroutes onto the alive ones).

    a_n: (T, K) -> (T, n_alive, nj, cols);
    b_n: (M, K) -> (nm, n_alive, rows, nj, cols);
    returns (a_t, b_t, n_panels) where n_panels = ⌈K/cols⌉ is the number
    of REAL contraction panels and nj = ⌈n_panels/n_alive⌉ the bus-cycle
    count — panel p runs as cycle p // n_alive on alive bus p % n_alive.
    Zero padding is harmless: padded K columns multiply zero inputs,
    padded M rows are sliced off the output, and bus-padded panels (idle
    buses in the last cycle) are noise-masked in ``bank_product``.
    """
    rows, cols = cfg.bank_rows, cfg.bank_cols
    n_buses = photonics.active_buses(cfg)
    t = a_n.shape[0]
    a_p = _pad_axis(a_n, cols, 1)
    nk = a_p.shape[1] // cols
    a_t = _pad_axis(a_p.reshape(t, nk, cols), n_buses, 1)
    nj = a_t.shape[1] // n_buses
    a_t = a_t.reshape(t, nj, n_buses, cols).transpose(0, 2, 1, 3)
    b_p = _pad_axis(_pad_axis(b_n, rows, 0), cols, 1)
    nm = b_p.shape[0] // rows
    b_t = _pad_axis(b_p.reshape(nm, rows, nk, cols), n_buses, 2)
    b_t = b_t.reshape(nm, rows, nj, n_buses, cols).transpose(0, 3, 1, 2, 4)
    return a_t, b_t, nk


def effective_deltas(w_target, cfg, residual=None):
    """The control-plane half of the inscription path: targets ->
    commanded heaters -> physical detunings (crosstalk leak + drift
    residual).  ``realized_weights`` maps these through the Lorentzian;
    the fused kernels (``kernels.emu_matmul``) take them as-is and apply
    the transfer in-kernel.

    ``w_target``: the bus-tiled (nm, n_alive, rows, nj, cols) layout, a
    bus-free (..., rows, nk, cols) panel stack, or a bare (rows, cols)
    grid; ``residual``: per-ring detuning error — (n_alive, rows, cols)
    for the bus-tiled layout, (rows, cols) for bare grids — broadcast
    over the (nm, nj) panel axes.
    """
    device = cfg.mrr or mrr.MRRConfig()
    if (cfg.failed_buses and device.bus_crosstalk != 0.0
            and w_target.ndim >= 5):
        # inter-bus thermal coupling follows the PHYSICAL bank stack, not
        # the compacted alive-bus schedule: a dead (undriven, δ=0) bank
        # between two survivors contributes no aggressor field but still
        # separates them, so both the Jacobi pre-compensation and the
        # leak must run on the physical bus axis
        delta_eff = _physical_bus_effective_deltas(w_target, cfg, device)
    else:
        delta_cmd = calibrate.command_deltas(w_target, device)
        delta_eff = delta_cmd + mrr.crosstalk_leak(delta_cmd, device)
    if residual is not None:
        if w_target.ndim >= 3:  # panel layout: broadcast over (nm, nj)
            delta_eff = delta_eff + residual[..., :, None, :]
        else:
            delta_eff = delta_eff + residual
    return delta_eff


def realized_weights(w_target, cfg, residual=None):
    """The full inscription path: targets -> commanded heaters -> physical
    detunings (leak + drift residual) -> realized Lorentzian weights.
    (See ``effective_deltas`` for the layout/residual conventions.)"""
    device = cfg.mrr or mrr.MRRConfig()
    return mrr.ring_weight(effective_deltas(w_target, cfg, residual),
                           device.gamma)


def _physical_bus_effective_deltas(w_target, cfg, device):
    """Effective (post-leak) detunings for a chip with failed buses and
    inter-bus crosstalk: the alive-layout targets are embedded into the
    physical (nm, n_buses, rows, nj, cols) stack with dead banks pinned
    undriven at δ=0, the controller's pre-compensation and the physical
    leak both act on that stack, and the alive slice is read back."""
    alive = jnp.asarray(photonics.alive_bus_indices(cfg))
    n_buses = max(cfg.n_buses, 1)

    def embed(x):
        shape = x.shape[:-4] + (n_buses,) + x.shape[-3:]
        return jnp.zeros(shape, x.dtype).at[..., alive, :, :, :].set(x)

    delta_target = embed(mrr.inscribe(w_target, device))
    delta_phys = delta_target
    if device.compensate_crosstalk and (
            device.crosstalk != 0.0 or device.bus_crosstalk != 0.0):
        # calibrate.compensate_crosstalk's Jacobi loop, with the dead
        # banks projected back to δ=0 each sweep — the controller never
        # drives them, so they must not accumulate phantom commands that
        # their alive neighbours would then pre-compensate against
        for _ in range(device.ct_iters):
            delta_phys = delta_target - mrr.crosstalk_leak(delta_phys, device)
            delta_phys = embed(jnp.take(delta_phys, alive, axis=-4))
    delta_phys = calibrate.quantize_command(
        jnp.clip(delta_phys, 0.0, device.delta_max), device)
    delta_eff = delta_phys + mrr.crosstalk_leak(delta_phys, device)
    return jnp.take(delta_eff, alive, axis=-4)


def _per_pass_sigma(cfg) -> float:
    """Per-bank-pass BPD read-noise σ in normalised units — the same
    convention switch as ``photonics.noise_sigma_total``."""
    if cfg.noise_convention == "absolute":
        return cfg.noise_std
    if cfg.noise_convention == "fullscale":
        return cfg.noise_std * cfg.bank_cols
    raise ValueError(cfg.noise_convention)


def alive_residual(residual, cfg):
    """Slice a carried drift/cal residual down to the panel schedule's
    alive buses: carried state spans the physical (n_buses, rows, cols)
    grid; the schedule only touches the surviving banks."""
    if residual is not None and cfg.failed_buses and residual.ndim == 3:
        residual = jnp.take(
            residual, jnp.asarray(photonics.alive_bus_indices(cfg)), axis=0)
    return residual


def alive_dead_ring_mask(cfg):
    """Fabrication yield: dead rings read 0 at the BPD whatever was
    commanded — a chip-fixed mask over the physical ring grid, sliced to
    the alive buses.  None when the device has no dead rings."""
    device = cfg.mrr or mrr.MRRConfig()
    if device.dead_ring_rate <= 0.0:
        return None
    phys = mrr.dead_ring_mask(
        device, (max(cfg.n_buses, 1), cfg.bank_rows, cfg.bank_cols))
    return jnp.take(phys, jnp.asarray(photonics.alive_bus_indices(cfg)), axis=0)


def bank_product(a_n, b_n, cfg, key=None, *, residual=None):
    """Noisy panel-accumulated product of normalised operands.

    a_n: (T, K), b_n: (M, K) in [-1, 1]  ->  (T, M) in bank output units.
    """
    device = cfg.mrr or mrr.MRRConfig()
    t, _k = a_n.shape
    m = b_n.shape[0]
    a_t, b_t, n_panels = tile_operands(a_n, b_n, cfg)
    residual = alive_residual(residual, cfg)
    w_eff = realized_weights(b_t, cfg, residual)
    dead = alive_dead_ring_mask(cfg)
    if dead is not None:
        w_eff = w_eff * dead[..., :, None, :]
    # one einsum over all (nm, bus, cycle) panels: p[t, i, r, q, j] is the
    # partial sum of output row block i, ring row r, bus q, bus-cycle j
    p = jnp.einsum("tqjc,iqrjc->tirqj", a_t, w_eff)
    n_buses, nj = a_t.shape[1], a_t.shape[2]
    sigma = _per_pass_sigma(cfg)
    if sigma > 0.0 or device.shot_noise > 0.0:
        if key is None:
            raise ValueError("noisy emulated bank requires a PRNG key")
        # final use of `key`: both physical noise sources draw from the
        # split halves; consume() makes any later reuse a lint error
        k_th, k_sh = jax.random.split(prng.consume(key))
        noise = jnp.zeros_like(p)
        if sigma > 0.0:
            # per-bus BPD/ADC chains: every (bus, cycle) element is an
            # independent draw of the same per-pass read-noise floor
            noise += sigma * jax.random.normal(k_th, p.shape, p.dtype)
        if device.shot_noise > 0.0:
            # shot noise scales with the *clean* per-pass optical signal —
            # independent of (not seeded by) the thermal/read draw
            noise += (device.shot_noise * jnp.sqrt(jnp.abs(p))
                      * jax.random.normal(k_sh, p.shape, p.dtype))
        if n_buses * nj != n_panels:
            # idle buses in the last parallel cycle never fire their BPD —
            # mask their draws so the accumulated noise counts the REAL
            # panels (matching ref's single draw), not the padded schedule
            valid = (jnp.arange(nj)[None, :] * n_buses
                     + jnp.arange(n_buses)[:, None]) < n_panels
            noise = noise * valid
        p = p + noise
    if device.adc_bits is not None:
        # each pass is digitised (per bus) before accumulating; ADC full
        # scale is the bank's maximal inner product, ±bank_cols normalised
        # (a config constant, not a tracer sync)
        p = photonics.fake_quant(p, device.adc_bits, amax=float(cfg.bank_cols))  # lint: disable=RL002
    out = jnp.sum(p, axis=(-2, -1))  # digital accumulation: buses × cycles
    return out.reshape(t, -1)[:, :m]


# ---------------------------------------------------------------------------
# Source-toggle seam (noise-budget attribution, ``repro.obs.attribution``).
# Each physical error source in the chain above can be isolated: a config
# twin with the SAME geometry (bank tiling, buses, failures — so panel
# schedules, padding and noise masks match the real run) but every other
# nonideality off.  Sole-source re-runs under the same PRNG key then see
# the same per-pass draws as the full chain, so their error powers are
# directly comparable.
# ---------------------------------------------------------------------------

NOISE_SOURCES: tuple[str, ...] = (
    "quantization",  # DAC/weight fake-quant + heater-DAC command quant
    "thermal",       # per-pass BPD read/thermal floor (cfg.noise_std)
    "shot",          # signal-dependent shot noise
    "adc",           # per-pass output ADC
    "drift",         # carried resonance-drift residual (needs `residual`)
    "crosstalk",     # intra-bank + inter-bus thermal crosstalk
    "dead_rings",    # fabrication-yield dead rings
)


def ideal_twin(cfg):
    """Nonideality-free twin of ``cfg``: identical geometry and schedule
    (bank_rows/cols, n_buses, failed_buses, f_s), every physical error
    source off.  The attribution probe's clean reference."""
    device = cfg.mrr or mrr.MRRConfig()
    return dataclasses.replace(
        cfg, noise_std=0.0, input_bits=None, weight_bits=None,
        mrr=dataclasses.replace(
            mrr.MRRConfig.ideal(), gamma=device.gamma,
            thermal_settle_s=device.thermal_settle_s))


def isolate_source(cfg, source: str):
    """``cfg`` with exactly one physical error source active.

    For "drift" the residual itself is the caller's to supply
    (``bank_product(..., residual=)``); the returned config only restores
    the device's command clipping so the perturbed detunings land where
    the real chain puts them.  Unknown names raise.
    """
    if source not in NOISE_SOURCES:
        raise ValueError(
            f"unknown noise source {source!r} (one of {NOISE_SOURCES})")
    device = cfg.mrr or mrr.MRRConfig()
    base = ideal_twin(cfg)
    ideal = base.mrr
    if source == "quantization":
        return dataclasses.replace(
            base, input_bits=cfg.input_bits, weight_bits=cfg.weight_bits,
            mrr=dataclasses.replace(ideal, heater_bits=device.heater_bits,
                                    delta_max=device.delta_max))
    if source == "thermal":
        return dataclasses.replace(base, noise_std=cfg.noise_std,
                                   noise_convention=cfg.noise_convention)
    if source == "shot":
        return dataclasses.replace(
            base, mrr=dataclasses.replace(ideal,
                                          shot_noise=device.shot_noise))
    if source == "adc":
        return dataclasses.replace(
            base, mrr=dataclasses.replace(ideal, adc_bits=device.adc_bits))
    if source == "drift":
        return dataclasses.replace(
            base, mrr=dataclasses.replace(ideal, delta_max=device.delta_max))
    if source == "crosstalk":
        return dataclasses.replace(
            base, mrr=dataclasses.replace(
                ideal, crosstalk=device.crosstalk,
                bus_crosstalk=device.bus_crosstalk,
                compensate_crosstalk=device.compensate_crosstalk,
                ct_iters=device.ct_iters, delta_max=device.delta_max))
    # dead_rings
    return dataclasses.replace(
        base, mrr=dataclasses.replace(ideal,
                                      dead_ring_rate=device.dead_ring_rate,
                                      yield_seed=device.yield_seed))


def resolve_emu_kernel(spec: str | None = None) -> str:
    """Resolve the emu execution kernel: an explicit "ref" | "pallas" |
    "xla" passes through; None/"auto" consults the ``REPRO_EMU_KERNEL``
    environment variable and then the platform default — the fused Pallas
    kernel on TPU, the unfused reference chain elsewhere (identical
    numerics to the pre-fusion emulator).  "xla" is the fused schedule
    compiled through lax.scan — the opt-in fast path off-TPU."""
    if spec in (None, "auto"):
        spec = os.environ.get("REPRO_EMU_KERNEL") or None
    if spec in (None, "auto"):
        spec = "pallas" if jax.default_backend() == "tpu" else "ref"
    if spec not in ("ref", "pallas", "xla"):
        raise ValueError(
            f"unknown emu kernel {spec!r} (auto | ref | pallas | xla)")
    return spec


def emulated_matmul(a, b, cfg, key=None, *, mask=None, state=None,
                    kernel: str | None = None):
    """Device-emulated C = A @ Bᵀ — drop-in for
    ``photonics.photonic_matmul`` (the "emu" backend entry point).

    a: (T, K) amplitude-encoded inputs; b: (M, K) target weights; mask:
    optional (T, M) post-detection Hadamard epilogue.  ``state`` overrides
    the drift state; by default the Trainer's active ``drift.use_state``
    context is consulted, and with neither the bank is drift-free.
    ``kernel`` picks the execution path (``resolve_emu_kernel``): "ref"
    is the unfused chain above; "pallas"/"xla" run the fused panel loop
    of ``kernels.emu_matmul`` (same physics, one kernel per GEMM).
    """
    if not cfg.enabled:
        out = jnp.einsum("tk,mk->tm", a, b)
        return out * mask if mask is not None else out
    kernel = resolve_emu_kernel(kernel)
    a_n, b_n, s_a, s_b = photonics.normalise_operands(a, b, cfg)
    if state is None:
        state = drift_lib.active_state()
    residual = drift_lib.residual(state) if state is not None else None
    if kernel == "ref":
        out = bank_product(a_n, b_n, cfg, key, residual=residual)
    else:
        from repro.kernels import emu_matmul  # lazy: kernels import us

        out = emu_matmul.fused_bank_product(a_n, b_n, cfg, key,
                                            residual=residual, impl=kernel)
    out = check_finite(out * (s_a * s_b), "emulated_matmul output")
    out = out * mask if mask is not None else out
    return out.astype(jnp.result_type(a, b))
