"""In-situ calibration: per-ring lookup-table inversion, crosstalk
pre-compensation, and the periodic recalibration sweep.

This is the paper's enabling systems idea (shared with Pai et al.'s in-situ
backpropagation): the controller never needs a perfect device, only a
*measured* one.  Three mechanisms:

* ``command_deltas`` — the per-ring LUT inversion: target weight →
  commanded heater detuning via the exact Lorentzian inverse
  (``mrr.inscribe``), a Jacobi pre-inversion of the known nearest-neighbour
  thermal coupling, and the heater-DAC quantization of the command.
* ``measure`` — a calibration sweep: reads the current per-ring drift with
  ``cal_noise`` measurement error (on chip: sweep each ring past resonance
  and locate the transmission minimum).
* ``advance`` — one train step of hardware evolution: OU-drift every ring,
  and on the recalibration cadence (``TrainerConfig.recalibrate_every``)
  replace the stored estimate with a fresh measurement.  Between sweeps the
  uncompensated residual grows as σ·sqrt(1 - exp(-2Δt/τ)) — the quantity
  ``benchmarks/drift_recovery.py`` studies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.hardware import drift as drift_lib
from repro.hardware import mrr
from repro.utils import prng


def quantize_command(delta_cmd, cfg: mrr.MRRConfig):
    """Heater-DAC quantization: the commanded detuning is driven through a
    ``heater_bits``-deep DAC spanning [0, delta_max].  ``heater_bits=1``
    clamps to a single on/off level ({0, delta_max}) instead of a
    zero-level division (the same degenerate-bits guard as
    ``photonics.fake_quant``)."""
    if cfg.heater_bits is None:
        return delta_cmd
    levels = max(2**cfg.heater_bits - 1, 1)
    d = jnp.clip(delta_cmd / cfg.delta_max, 0.0, 1.0) * levels
    return jnp.round(d) / levels * cfg.delta_max


def compensate_crosstalk(delta_target, cfg: mrr.MRRConfig, row_axis: int | None = None,
                         col_axis: int | None = None, bus_axis: int | None = None):
    """Solve (I + c·N)·δ_cmd = δ_target by Jacobi iteration so that after
    the physical leak the realized detuning is ≈ the target.  Converges
    geometrically for c·‖N‖ < 1 (c is a few 1e-3; ‖N‖ ≤ 4 intra-bus plus
    2 inter-bus neighbours)."""
    delta_cmd = delta_target
    for _ in range(cfg.ct_iters):
        delta_cmd = delta_target - mrr.crosstalk_leak(
            delta_cmd, cfg, row_axis, col_axis, bus_axis)
    return delta_cmd


def command_deltas(w_target, cfg: mrr.MRRConfig, row_axis: int | None = None,
                   col_axis: int | None = None, bus_axis: int | None = None):
    """Target weights -> commanded heater detunings (the controller's whole
    write path: LUT inversion, crosstalk pre-inversion, heater DAC)."""
    delta = mrr.inscribe(w_target, cfg)
    if cfg.compensate_crosstalk and (
            cfg.crosstalk != 0.0 or cfg.bus_crosstalk != 0.0):
        delta = compensate_crosstalk(delta, cfg, row_axis, col_axis, bus_axis)
    delta = jnp.clip(delta, 0.0, cfg.delta_max)
    return quantize_command(delta, cfg)


def measure(drift, key, cfg: mrr.MRRConfig):
    """One calibration sweep: the true per-ring drift plus measurement
    noise.  With ``cal_noise=0`` calibration is perfect."""
    if cfg.cal_noise == 0.0:
        return drift
    return drift + cfg.cal_noise * jax.random.normal(prng.consume(key),
                                                     drift.shape, drift.dtype)


def advance(state: dict, photonics_cfg, step, key,
            recalibrate_every: int = 0) -> dict:
    """Advance the carried hardware state by one train step.

    ``step`` may be a traced int32 (the Trainer calls this inside jit);
    ``recalibrate_every`` is static — 0 disables recalibration entirely, so
    the stored estimate stays frozen and the residual follows the raw OU
    drift."""
    cfg = photonics_cfg.mrr or mrr.MRRConfig()
    d = state["drift"]
    if cfg.drift_sigma > 0.0:
        d = drift_lib.ou_step(d, jax.random.fold_in(key, 1),
                              cfg.drift_sigma, cfg.drift_tau)
    cal = state["cal"]
    if recalibrate_every and recalibrate_every > 0:
        fresh = measure(d, jax.random.fold_in(key, 2), cfg)
        step = jnp.asarray(step)
        # skip step 0: a fresh chip is already calibrated (both grids zero),
        # and a sweep before any drift exists would make the first
        # recalibration window look like it recovered nothing
        do_recal = ((step % recalibrate_every) == 0) & (step > 0)
        cal = jnp.where(do_recal, fresh, cal)
    return {"drift": d, "cal": cal}
