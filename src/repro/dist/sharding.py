"""Sharding policy: mesh axes, parameter/batch placement rules, and the
activation annotations the models sprinkle through their forward passes.

Mesh axes (launch/mesh.py):

* ``data``  — FSDP axis: parameters are sharded along their first dim
  (ZeRO-3), gathered per-layer inside the scan by ``unshard_fsdp``.
* ``model`` — tensor-parallel axis: matmul output dims, embed vocab,
  expert dim, and the DFA tape's feature dim.
* ``pod``   — optional leading DCI axis (multi-pod); joins ``data`` for
  batch sharding only.

Single-host contract: every helper here is a **no-op without an active
mesh** — ``annotate``/``unshard_fsdp`` return their argument unchanged
(identity, not a copy) so the small-scale CPU paths trace exactly the same
HLO they did before sharding existed.  A mesh is activated with
``use_mesh(mesh)`` (a context manager), which is what the dry-run and the
subprocess tests do around ``jit``/``lower``.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils.tree import path_map

MODEL = "model"
FSDP = "data"
POD = "pod"

# ---------------------------------------------------------------------------
# active mesh
# ---------------------------------------------------------------------------

_ACTIVE: list = []


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for annotate/unshard within the block."""
    _ACTIVE.append(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE.pop()


def current_mesh():
    return _ACTIVE[-1] if _ACTIVE else None


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dim is sharded over (pod joins data if present)."""
    return (POD, FSDP) if POD in mesh.shape else (FSDP,)


# ---------------------------------------------------------------------------
# parameter placement rules
# ---------------------------------------------------------------------------
# Each rule is (substring, PartitionSpec); first match wins, "" is the
# catch-all.  Specs are written for the *trailing* dims of a leaf —
# ``_fit_spec`` right-aligns them (stacked layer axes get leading None) and
# the divisibility fallback drops any axis that does not divide the dim.

PARAM_RULES: tuple = (
    ("experts", P(MODEL, FSDP, None)),   # (E, d_in, d_out): expert parallel
    ("embed", P(MODEL, FSDP)),           # (V, d): vocab on model
    ("norm", P()),                       # tiny scale vectors: replicate
    ("/ln", P()),
    ("ln1", P()), ("ln2", P()), ("ln3", P()), ("ln_enc", P()),
    ("", P(FSDP, MODEL)),                # default 2D weight (d_in, d_out)
)

# Feedback matrices are (L, d_inject, d_tap): shard the injection dim on
# model (it is the photonic projection's output dim), replicate d_tap.
FEEDBACK_RULES: tuple = (
    ("", P(None, MODEL, None)),
)


def spec_for_path(path: str, rules: tuple = PARAM_RULES):
    """-> (PartitionSpec, rule_substring) for a "a/b/c" parameter path."""
    for pat, spec in rules:
        if pat in path:
            return spec, pat
    return P(), ""


def _fit_spec(spec, ndim: int):
    """Right-align ``spec`` to an ndim-rank leaf: pad leading None for
    stacked layer axes, drop leading entries when the leaf has fewer dims
    (a (d_out,) bias keeps the weight spec's trailing MODEL entry)."""
    entries = tuple(spec)
    if len(entries) > ndim:
        entries = entries[len(entries) - ndim:]
    elif len(entries) < ndim:
        entries = (None,) * (ndim - len(entries)) + entries
    return P(*entries)


def _axis_size(mesh, entry) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _divisible(spec, shape, mesh):
    """Drop spec entries whose mesh-axis product does not divide the dim —
    the odd-vocab fallback (73448 is not 16-way shardable)."""
    out = []
    for dim, entry in zip(shape, tuple(spec)):
        if entry is None:
            out.append(None)
        elif any(a not in mesh.shape for a in (entry if isinstance(entry, tuple) else (entry,))):
            out.append(None)
        elif dim % _axis_size(mesh, entry) != 0:
            out.append(None)
        else:
            out.append(entry)
    return P(*out)


def make_param_shardings(mesh, tree, rules: tuple = PARAM_RULES):
    """NamedSharding pytree for a parameter pytree (arrays or SDS leaves)."""

    def assign(path, leaf):
        spec, _ = spec_for_path(path, rules)
        spec = _fit_spec(spec, len(leaf.shape))
        spec = _divisible(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return path_map(assign, tree)


def make_batch_shardings(mesh, tree):
    """Batch inputs: dim 0 over (pod, data) when divisible, rest replicated."""
    b = batch_axes(mesh)
    n = 1
    for a in b:
        n *= mesh.shape[a]

    def assign(path, leaf):
        del path
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] % n == 0:
            spec[0] = b if len(b) > 1 else b[0]
        return NamedSharding(mesh, P(*spec))

    return path_map(assign, tree)


def replicated(mesh):
    return NamedSharding(mesh, P())


def replicate(mesh, tree):
    """device_put every leaf of ``tree`` fully replicated over ``mesh`` —
    the parameter/optimizer placement for pure data-parallel training."""
    return jax.device_put(tree, replicated(mesh))


def put_batch(mesh, batch):
    """Host→device transfer of one batch, dim 0 split over the data axes
    (``make_batch_shardings`` falls back to replication when the batch size
    does not divide the axis).  ``jax.device_put`` dispatch is async, so the
    Trainer's prefetcher uses this to overlap the next batch's transfer with
    the current step's compute."""
    return jax.device_put(batch, make_batch_shardings(mesh, batch))


# ---------------------------------------------------------------------------
# activation annotations
# ---------------------------------------------------------------------------
# Named constraint points used by the models.  _B marks the batch dim
# (bound to batch_axes(mesh) at call time).

_B = "__batch__"

ACT_RULES: dict[str, tuple] = {
    "act_btd": (_B, None, None),          # residual stream (B, S, D)
    "tape_lbsd": (None, _B, None, MODEL), # DFA tape: model-sharded feature
    "logits": (_B, None, MODEL),          # (B, S, V): vocab on model
    "delta_tm": (_B, MODEL),              # projected error (T, M)
    "expert_ecd": (MODEL, None, None),    # MoE buffers (E, C, D)
}


def annotate(x, name: str):
    """with_sharding_constraint by rule name; identity without a mesh."""
    mesh = current_mesh()
    if mesh is None or name not in ACT_RULES:
        return x
    b = batch_axes(mesh)
    entries = tuple(
        (b if len(b) > 1 else b[0]) if e is _B else e for e in ACT_RULES[name]
    )
    spec = _divisible(_fit_spec(P(*entries), x.ndim), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _strip_fsdp(entry):
    if entry == FSDP:
        return None
    if isinstance(entry, tuple):
        kept = tuple(a for a in entry if a != FSDP)
        return kept if kept else None
    return entry


def unshard_fsdp(tree):
    """ZeRO-3 gather: constrain param leaves to their rule spec with the
    FSDP axis removed (replicated over data, still split over model).
    Identity without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return tree

    def gather(path, x):
        spec, _ = spec_for_path(path)
        entries = tuple(_strip_fsdp(e) for e in tuple(spec))
        fit = _divisible(_fit_spec(P(*entries), x.ndim), x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fit))

    return path_map(gather, tree)
