from repro.dist import sharding
from repro.dist.sharding import (
    FSDP,
    MODEL,
    annotate,
    batch_axes,
    make_batch_shardings,
    make_param_shardings,
    replicated,
    unshard_fsdp,
    use_mesh,
)

__all__ = [
    "sharding", "FSDP", "MODEL", "annotate", "batch_axes",
    "make_batch_shardings", "make_param_shardings", "replicated",
    "unshard_fsdp", "use_mesh",
]
