"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for
scan-over-layers models that undercounts FLOPs/bytes/collectives by the
layer count.  This walker parses the optimized (post-SPMD) HLO text, builds
the computation call graph, extracts loop trip counts (from the
``known_trip_count`` backend_config, falling back to the loop-condition
constant), and accumulates per-device:

* dot FLOPs               (2 · |out| · contracted)
* HBM-traffic proxy bytes (operand+output bytes of non-bookkeeping ops at
  computation top level; fused-computation internals excluded — fusion
  intermediates stay on-core)
* collective bytes        (by kind: all-gather / all-reduce / …)

Operands in optimized HLO are name references (no inline types), so each
computation keeps a name → shape table and resolves references.

All numbers are PER-DEVICE (post-partitioning shapes).  The roofline terms
are therefore  t_x = per_device_x / per_chip_rate  — equivalent to the
global formulation global_x / (chips · rate).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.utils.hlo import _COLLECTIVES, shape_bytes

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OP_HEAD = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OP_NAME = re.compile(r"\s*([a-zA-Z0-9\-]+)\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"\bcalls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"\bbody=%?([\w.\-]+)")
_COND_RE = re.compile(r"\bcondition=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"\bto_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_BOOKKEEPING = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _type_sizes(type_str: str):
    """(total_bytes, dims_of_first_shape) for an HLO type string."""
    total = 0
    first_dims = None
    for m in _SHAPE_RE.finditer(type_str):
        total += shape_bytes(m.group(1), m.group(2))
        if first_dims is None:
            dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
            first_dims = dims
    return total, (first_dims or [])


@dataclass
class _Op:
    name: str
    op: str
    out_bytes: int
    out_dims: list
    line: str
    operands: list  # operand names (top-level call parens)


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)
    types: dict = field(default_factory=dict)  # name -> (bytes, dims)
    max_const: int = 1


def _split_operands(line: str, op_start: int) -> tuple[list, str]:
    """Operand names inside the op's call parens + the trailing attr text."""
    i = line.find("(", op_start)
    if i < 0:
        return [], ""
    depth = 0
    j = i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    inner = line[i + 1 : j]
    rest = line[j + 1 :]
    return _OPERAND_RE.findall(inner), rest


def _parse_op_line(line: str):
    """Parse '%name = TYPE op(...)' — TYPE may be a tuple containing
    '/*index=N*/' comments, so it is scanned with balanced parens."""
    mh = _OP_HEAD.match(line)
    if not mh:
        return None
    name = mh.group(1)
    i = mh.end()
    if i >= len(line):
        return None
    if line[i] == "(":  # tuple type: balance parens
        depth = 0
        j = i
        for j in range(i, len(line)):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        out_type = line[i : j + 1]
        rest = line[j + 1 :]
    else:  # scalar/array type token: up to whitespace before the op name
        m = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?", line[i:])
        if not m:
            return None
        out_type = m.group(0)
        rest = line[i + m.end():]
    mo = _OP_NAME.match(rest)
    if not mo:
        return None
    op = mo.group(1)
    return name, out_type, op


def parse_computations(text: str) -> dict:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        if not line.startswith(" ") and _COMP_HDR.match(line):
            cur = _Comp(_COMP_HDR.match(line).group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, out_type, op = parsed
        out_bytes, out_dims = _type_sizes(out_type)
        op_paren = line.find(op + "(", len(name))
        operands, _rest = _split_operands(line, op_paren + len(op))
        cur.types[name] = (out_bytes, out_dims)
        cur.ops.append(_Op(name, op, out_bytes, out_dims, line, operands))
        for mc in _CONST_RE.finditer(line):
            cur.max_const = max(cur.max_const, int(mc.group(1)))
    return comps


def _dot_flops(op: _Op, types: dict) -> float:
    out_elems = 1
    for d in op.out_dims:
        out_elems *= d
    lhs = types.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 2.0 * out_elems  # unknown contraction: floor estimate
    lhs_dims = lhs[1]
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contracted = 1
    if mc and mc.group(1):
        for i in mc.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    return 2.0 * out_elems * contracted


@dataclass
class HloCost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)

    @property
    def collective_total(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "mem_bytes": self.mem_bytes,
            "collective_bytes": self.collective_total,
            "coll_bytes_by_kind": dict(self.coll_bytes),
            "coll_count_by_kind": dict(self.coll_count),
        }


def _comp_local_cost(comp: _Comp):
    """(flops, mem_bytes, coll_bytes, coll_count, children) for one
    computation, children = [(name, trips|None, include_mem)]."""
    flops = 0.0
    mem = 0.0
    coll_b: dict = {}
    coll_c: dict = {}
    children = []

    def operand_bytes(op: _Op) -> float:
        return float(sum(comp.types.get(o, (0, []))[0] for o in op.operands))

    for op in comp.ops:
        base = op.op[:-6] if op.op.endswith("-start") else op.op
        if base.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            b = operand_bytes(op)
            coll_b[base] = coll_b.get(base, 0.0) + b
            coll_c[base] = coll_c.get(base, 0) + 1
            mem += b + op.out_bytes
            continue
        if op.op in _BOOKKEEPING:
            continue
        if op.op == "dot":
            flops += _dot_flops(op, comp.types)
            mem += operand_bytes(op) + op.out_bytes
            continue
        if op.op == "while":
            mt = _TRIP_RE.search(op.line)
            trips = int(mt.group(1)) if mt else None
            mb = _BODY_RE.search(op.line)
            mc = _COND_RE.search(op.line)
            if mb:
                children.append((mb.group(1), trips, True, mc.group(1) if mc else None))
            continue
        if op.op == "fusion":
            mcall = _CALLS_RE.search(op.line)
            if mcall:
                children.append((mcall.group(1), 1, False, None))
            mem += operand_bytes(op) + op.out_bytes
            continue
        if op.op == "call":
            ma = _APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
            if ma:
                children.append((ma.group(1), 1, True, None))
            continue
        if op.op == "conditional":
            mbr = _BRANCH_RE.search(op.line)
            names = []
            if mbr:
                names = [n.strip().lstrip("%") for n in mbr.group(1).split(",")]
            names += list(_TF_RE.findall(op.line))
            for n in names:
                children.append((n, 1, True, None))
            mem += operand_bytes(op) + op.out_bytes
            continue
        # generic op (HBM traffic proxy)
        mem += operand_bytes(op) + op.out_bytes
    return flops, mem, coll_b, coll_c, children


def analyze(text: str) -> HloCost:
    comps = parse_computations(text)
    local: dict[str, tuple] = {n: _comp_local_cost(c) for n, c in comps.items()
                               if n != "__entry__"}
    memo: dict[tuple, HloCost] = {}

    def walk(name: str, include_mem: bool) -> HloCost:
        key = (name, include_mem)
        if key in memo:
            return memo[key]
        out = HloCost()
        memo[key] = out
        if name not in local:
            return out
        flops, mem, coll_b, coll_c, children = local[name]
        out.flops += flops
        if include_mem:
            out.mem_bytes += mem
        for k, v in coll_b.items():
            out.coll_bytes[k] = out.coll_bytes.get(k, 0.0) + v
        for k, v in coll_c.items():
            out.coll_count[k] = out.coll_count.get(k, 0) + v
        for callee, trips, child_mem, cond_name in children:
            if trips is None:
                cond_comp = comps.get(cond_name or callee)
                trips = max(1, cond_comp.max_const if cond_comp else 1)
            sub = walk(callee, include_mem and child_mem)
            out.flops += trips * sub.flops
            out.mem_bytes += trips * sub.mem_bytes
            for k, v in sub.coll_bytes.items():
                out.coll_bytes[k] = out.coll_bytes.get(k, 0.0) + trips * v
            for k, v in sub.coll_count.items():
                out.coll_count[k] = out.coll_count.get(k, 0) + trips * v
        return out

    entry = comps.get("__entry__")
    if entry is None:
        return HloCost()
    return walk(entry.name, True)
