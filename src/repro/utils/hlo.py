"""HLO text analysis: collective-bytes accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the (stable-)HLO/optimized-HLO text and sum the operand
sizes of every communication op.  This powers the third roofline term:

    collective term = collective_bytes / (chips * link_bw)
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "s32": 4,
    "u32": 4,
    "s64": 8,
    "u64": 8,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "bf16": 2,
    "f16": 2,
    "f32": 4,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "token": 0,
}

# Collective op names; "-start" variants are the async forms (count those,
# skip the matching "-done" which carries the same payload).
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    """Per-op-kind byte and instance counts from one HLO module."""

    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    count_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    instances: list = field(default_factory=list)  # (kind, bytes, line excerpt)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> str:
        rows = [
            f"  {kind:24s} n={self.count_by_kind[kind]:4d} bytes={self.bytes_by_kind[kind]:.3e}"
            for kind in sorted(self.bytes_by_kind)
        ]
        rows.append(f"  {'TOTAL':24s} n={self.total_count:4d} bytes={self.total_bytes:.3e}")
        return "\n".join(rows)


def _op_kind(line: str) -> str | None:
    """Return the collective kind if this HLO line is a collective op."""
    # Lines look like:  %all-gather.3 = bf16[...]{...} all-gather(bf16[...] %x), ...
    m = re.search(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([a-z0-9-]+)\(", line)
    if not m:
        return None
    op = m.group(1)
    for kind in _COLLECTIVES:
        if op == kind or op == kind + "-start":
            return kind
        if op == kind + "-done":
            return "_done"
    return None


def _operand_bytes(line: str) -> int:
    """Sum the byte sizes of operand shapes (inside the call parens)."""
    paren = line.find("(")
    if paren < 0:
        return 0
    body = line[paren:]
    total = 0
    for m in _SHAPE_RE.finditer(body):
        total += shape_bytes(m.group(1), m.group(2))
    return total


def analyze_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        kind = _op_kind(line)
        if kind is None or kind == "_done":
            continue
        b = _operand_bytes(line)
        stats.bytes_by_kind[kind] += b
        stats.count_by_kind[kind] += 1
        stats.instances.append((kind, b, line.strip()[:160]))
    return stats


def count_op(hlo_text: str, op_name: str) -> int:
    """Count occurrences of an HLO op (e.g. 'dot', 'fusion') by kind."""
    n = 0
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([a-z0-9-]+)\(",
            line)
        if m and m.group(1) == op_name:
            n += 1
    return n
