"""Pytree utilities: parameter counting, casting, path-wise maps."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def cast(tree, dtype):
    def _c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_c, tree)


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_dot(a, b):
    """Sum over all leaves of <a, b> (float32 accumulation)."""
    parts = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return sum(jax.tree_util.tree_leaves(parts))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree)
        )
    )


def path_map(fn, tree):
    """tree_map where fn receives ("a/b/c", leaf)."""

    def _name(path) -> str:
        out = []
        for p in path:
            if hasattr(p, "key"):
                out.append(str(p.key))
            elif hasattr(p, "idx"):
                out.append(str(p.idx))
            else:
                out.append(str(p))
        return "/".join(out)

    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_name(p), x), tree)


def named_leaves(tree) -> list[tuple[str, jax.Array]]:
    out = []

    def _collect(name, x):
        out.append((name, x))
        return x

    path_map(_collect, tree)
    return out


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    if len(leaves_a) != len(leaves_b):
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(leaves_a, leaves_b)
    )
