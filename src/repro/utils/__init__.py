from repro.utils import hlo, prng, tree

__all__ = ["hlo", "prng", "tree"]
