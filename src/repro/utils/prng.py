"""PRNG helpers.

Keys are threaded explicitly everywhere; named folding keeps streams
reproducible and restart-safe (the data pipeline and the photonic noise
model both derive their randomness from (base_seed, step, name) so a
checkpoint-restart replays the identical stream).
"""

from __future__ import annotations

import hashlib

import jax
import jax.numpy as jnp


def key(seed: int) -> jax.Array:
    return jax.random.PRNGKey(seed)


def _name_to_int(name: str) -> int:
    # Stable across processes (unlike hash()).
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")


def fold_name(k: jax.Array, name: str) -> jax.Array:
    """Fold a string name into a key (stable across runs/hosts)."""
    return jax.random.fold_in(k, _name_to_int(name))


def fold(k: jax.Array, *names_or_ints) -> jax.Array:
    for item in names_or_ints:
        if isinstance(item, str):
            k = fold_name(k, item)
        else:
            k = jax.random.fold_in(k, item)
    return k


def split_dict(k: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    return {n: fold_name(k, n) for n in names}


def consume(k: jax.Array) -> jax.Array:
    """Mark ``k`` as spent: identity at runtime, a kill to the linter.

    Pass a key through ``consume`` at its FINAL use site —
    ``jax.random.normal(consume(k), ...)`` — and ``repro.lint`` (RL001)
    will flag any later use of the same binding instead of silently
    allowing one more draw from an already-correlated stream.
    """
    return k


def step_key(base_seed: int, step, name: str = "") -> jax.Array:
    """Key for a given training step — deterministic under restart.

    ``step`` may be a traced int32 (inside jit)."""
    k = jax.random.PRNGKey(base_seed)
    if name:
        k = fold_name(k, name)
    return jax.random.fold_in(k, jnp.asarray(step, dtype=jnp.uint32))
