"""Fault-tolerant checkpointing: atomic msgpack snapshots, keep-k rotation,
latest-pointer, sharding-agnostic restore.

Design for 1000+ nodes (DESIGN.md §5): checkpoints are written as
*logical* (fully-addressable) arrays; on restore they are re-placed under
whatever sharding the current mesh dictates — so an elastic restart on a
different device count resharding-restores cleanly.  Writes are atomic
(temp file + os.replace) so a node failure mid-write never corrupts the
latest checkpoint; the trainer auto-resumes from the newest valid snapshot.
"""

from __future__ import annotations

import os
import re

import jax
import msgpack
import numpy as np


def _flatten(tree):
    flat = {}

    def visit(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                visit(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                visit(f"{prefix}#{i}", v)
        else:
            flat[prefix] = node

    visit("", tree)
    return flat


def _np_dtype(name: str) -> np.dtype:
    """np.dtype from a saved name — including ml_dtypes (bfloat16, fp8)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _encode_leaf(x):
    arr = np.asarray(jax.device_get(x))
    # dtype NAME (not .str): extension dtypes like bfloat16 print '<V2' in
    # .str and cannot be re-viewed from raw bytes (hypothesis-found bug)
    return {
        b"dtype": str(arr.dtype).encode(),
        b"shape": list(arr.shape),
        b"data": arr.tobytes(),
    }


def save(path: str, tree, step: int | None = None, extra: dict | None = None):
    """Atomic write of a pytree snapshot."""
    flat = _flatten(tree)
    payload = {
        b"version": 1,
        b"step": -1 if step is None else int(step),
        b"extra": extra or {},
        b"leaves": {k.encode(): _encode_leaf(v) for k, v in flat.items()},
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load(path: str, template=None, shardings=None):
    """Restore. With a ``template`` pytree the result matches its structure
    (and dtypes are cast to the template's); ``shardings`` (same structure)
    re-places leaves with jax.device_put."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    leaves = {
        k.decode(): np.frombuffer(v[b"data"], dtype=_np_dtype(v[b"dtype"].decode()))
        .reshape(v[b"shape"])
        .copy()
        for k, v in payload[b"leaves"].items()
    }
    step = payload[b"step"]
    if template is None:
        return leaves, step

    flat_template = _flatten(template)
    missing = set(flat_template) - set(leaves)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]} …")
    flat_shard = _flatten(shardings) if shardings is not None else {}

    def rebuild(node, prefix=""):
        if isinstance(node, dict):
            return {
                k: rebuild(v, f"{prefix}/{k}" if prefix else str(k))
                for k, v in node.items()
            }
        if isinstance(node, (list, tuple)):
            vals = [rebuild(v, f"{prefix}#{i}") for i, v in enumerate(node)]
            return type(node)(vals)
        arr = leaves[prefix].astype(np.dtype(node.dtype))
        if prefix in flat_shard:
            return jax.device_put(arr, flat_shard[prefix])
        return jax.device_put(arr)

    return rebuild(template), step


class CheckpointManager:
    """step-tagged snapshots with keep-k rotation + auto-resume."""

    PAT = re.compile(r"ckpt_(\d+)\.msgpack$")

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:09d}.msgpack")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = self.PAT.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree, extra: dict | None = None):
        save(self._path(step), tree, step=step, extra=extra)
        for old in self.all_steps()[: -self.keep]:
            try:
                os.remove(self._path(old))
            except OSError:
                pass

    def restore(self, template, step: int | None = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        tree, saved_step = load(self._path(step), template, shardings)
        return tree, saved_step
