"""Optimizers (from scratch — no optax): SGD+momentum (the paper's choice:
lr 0.01, momentum 0.9) and AdamW for the LM examples.  Both support global
gradient-norm clipping and schedules (callable lr)."""

from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp

from repro.utils import tree as tree_util


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def clip_by_global_norm(grads, max_norm: float):
    norm = tree_util.global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


@dataclasses.dataclass(frozen=True)
class SGDM:
    lr: typing.Any = 0.01
    momentum: float = 0.9
    weight_decay: float = 0.0
    nesterov: bool = False
    clip_norm: float | None = None
    momentum_dtype: typing.Any = None  # None -> same as param dtype

    def init(self, params):
        dt = lambda p: self.momentum_dtype or p.dtype
        return {
            "mom": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dt(p)), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = _lr_at(self.lr, step)
        norm = None
        if self.clip_norm is not None:
            grads, norm = clip_by_global_norm(grads, self.clip_norm)

        def upd(g, m, p):
            g32 = g.astype(jnp.float32)
            if self.weight_decay:
                g32 = g32 + self.weight_decay * p.astype(jnp.float32)
            m_new = self.momentum * m.astype(jnp.float32) + g32
            d = (g32 + self.momentum * m_new) if self.nesterov else m_new
            p_new = p.astype(jnp.float32) - lr * d
            return p_new.astype(p.dtype), m_new.astype(m.dtype)

        flat = jax.tree_util.tree_map(upd, grads, state["mom"], params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_mom = jax.tree_util.tree_map(lambda t: t[1], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        info = {"lr": lr}
        if norm is not None:
            info["grad_norm"] = norm
        return new_params, {"mom": new_mom, "step": step}, info


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: typing.Any = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float | None = 1.0
    state_dtype: typing.Any = jnp.float32

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = _lr_at(self.lr, step)
        norm = None
        if self.clip_norm is not None:
            grads, norm = clip_by_global_norm(grads, self.clip_norm)
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = self.b1 * m + (1 - self.b1) * g32
            v_new = self.b2 * v + (1 - self.b2) * jnp.square(g32)
            mh = m_new / c1
            vh = v_new / c2
            d = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * d
            return (p_new.astype(p.dtype), m_new.astype(self.state_dtype),
                    v_new.astype(self.state_dtype))

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        leaf = lambda x: isinstance(x, tuple)
        new_params = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=leaf)
        new_m = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=leaf)
        new_v = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=leaf)
        info = {"lr": lr}
        if norm is not None:
            info["grad_norm"] = norm
        return new_params, {"m": new_m, "v": new_v, "step": step}, info
