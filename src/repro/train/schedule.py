"""Learning-rate schedules (callables of the int step)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak * step / max(1, warmup_steps)
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def linear_decay(peak: float, total_steps: int):
    def fn(step):
        t = jnp.clip(step / max(1, total_steps), 0.0, 1.0)
        return jnp.float32(peak * (1.0 - t))

    return fn
