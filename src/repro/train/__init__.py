from repro.train import checkpoint, optimizer, schedule
from repro.train.optimizer import AdamW, SGDM
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["checkpoint", "optimizer", "schedule", "AdamW", "SGDM", "Trainer", "TrainerConfig"]
