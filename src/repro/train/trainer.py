"""Trainer: jit'd train step (any algorithm registered in repro.algos:
bp, dfa, dfa-fused, dfa-layerwise, ...), microbatch accumulation,
fault-tolerant fit loop with checkpoint/auto-resume, straggler deadline
hooks, and CSV metric logging.

Fault-tolerance contract: all training randomness (photonic noise, data
order) is a pure function of (seed, step), so `restore()` + `fit()` replays
identically after a crash — verified by tests/test_checkpoint.py.
"""

from __future__ import annotations

import dataclasses
import os
import time
import typing

import jax
import jax.numpy as jnp

from repro import algos
from repro.algos.dfa import DFAConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import SGDM
from repro.utils import prng


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    algo: str = "dfa"  # any name in algos.list_algos()
    dfa: DFAConfig = dataclasses.field(default_factory=DFAConfig)
    optimizer: typing.Any = dataclasses.field(default_factory=SGDM)
    seed: int = 0
    microbatches: int = 1
    ckpt_dir: str | None = None
    ckpt_every: int = 500
    keep_ckpts: int = 3
    log_every: int = 50
    log_path: str | None = None
    # straggler mitigation: per-step wall deadline (None = off). On real
    # multi-host deployments a step exceeding the deadline raises through
    # the supervisor which restarts the slow host from the last snapshot.
    step_deadline_s: float | None = None


class Trainer:
    def __init__(self, model, cfg: TrainerConfig):
        self.model = model
        self.cfg = cfg
        self.algorithm = algos.get(cfg.algo)
        self._vg = self.algorithm.value_and_grad(model, cfg.dfa)
        self._step_fn = jax.jit(self._train_step)
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.keep_ckpts) if cfg.ckpt_dir else None
        self._log_file = None

    # ---------- state ----------
    def init_state(self, key=None):
        key = key if key is not None else prng.key(self.cfg.seed)
        params = self.model.init(key)
        fb = self.algorithm.init_extra_state(
            self.model, prng.fold_name(key, "feedback"), self.cfg.dfa)
        opt_state = self.cfg.optimizer.init(params)
        return {"params": params, "fb": fb, "opt": opt_state,
                "step": jnp.zeros((), jnp.int32)}

    # ---------- core step ----------
    def _grads(self, params, fb, batch, rng):
        mb = self.cfg.microbatches
        if mb <= 1:
            return self._vg(params, fb, batch, rng)

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        batches = jax.tree_util.tree_map(split, batch)

        def body(carry, xs):
            acc, metrics_acc = carry
            micro, i = xs
            (loss, metrics), grads = self._vg(params, fb, micro, jax.random.fold_in(rng, i))
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            metrics_acc = jax.tree_util.tree_map(jnp.add, metrics_acc, metrics)
            return (acc, metrics_acc), loss

        (l0, m0), g0 = self._vg(
            params, fb, jax.tree_util.tree_map(lambda x: x[0], batches),
            jax.random.fold_in(rng, 0))
        rest = jax.tree_util.tree_map(lambda x: x[1:], batches)
        (gsum, msum), losses = jax.lax.scan(
            body, (g0, m0), (rest, jnp.arange(1, mb)))
        grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
        metrics = jax.tree_util.tree_map(lambda m: m / mb, msum)
        loss = (l0 + jnp.sum(losses)) / mb
        return (loss, metrics), grads

    def _train_step(self, state, batch):
        rng = prng.step_key(self.cfg.seed, state["step"], "noise")
        (loss, metrics), grads = self._grads(state["params"], state["fb"], batch, rng)
        new_params, new_opt, info = self.cfg.optimizer.update(
            grads, state["opt"], state["params"])
        metrics = dict(metrics)
        metrics.update(info)
        new_state = {"params": new_params, "fb": state["fb"], "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    def step(self, state, batch):
        t0 = time.monotonic()
        state, metrics = self._step_fn(state, batch)
        if self.cfg.step_deadline_s is not None:
            jax.block_until_ready(state["step"])
            dt = time.monotonic() - t0
            if dt > self.cfg.step_deadline_s:
                raise TimeoutError(
                    f"step {int(state['step'])} exceeded deadline "
                    f"({dt:.1f}s > {self.cfg.step_deadline_s}s) — straggler")
        return state, metrics

    # ---------- loop ----------
    def restore_or_init(self, key=None):
        state = self.init_state(key)
        if self.ckpt is not None:
            restored, step = self.ckpt.restore(state)
            if restored is not None:
                return restored, int(step)
        return state, 0

    def _log(self, step, metrics):
        if self.cfg.log_path is None:
            return
        row = {k: float(v) for k, v in metrics.items()}
        if self._log_file is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.cfg.log_path)), exist_ok=True)
            new = not os.path.exists(self.cfg.log_path)
            self._log_file = open(self.cfg.log_path, "a")
            if new:
                self._log_file.write("step," + ",".join(sorted(row)) + "\n")
        self._log_file.write(
            f"{step}," + ",".join(str(row[k]) for k in sorted(row)) + "\n")
        self._log_file.flush()

    def fit(self, data_fn, total_steps: int, eval_fn=None, verbose=True):
        """data_fn(step) -> batch (deterministic — restart-safe)."""
        state, start = self.restore_or_init()
        metrics = {}
        for step in range(start, total_steps):
            batch = data_fn(step)
            state, metrics = self.step(state, batch)
            if (step + 1) % self.cfg.log_every == 0 or step + 1 == total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                self._log(step + 1, metrics)
                if verbose:
                    txt = " ".join(f"{k}={v:.4f}" for k, v in sorted(m.items()))
                    print(f"[step {step + 1}/{total_steps}] {txt}", flush=True)
            if self.ckpt is not None and (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        if self.ckpt is not None:
            self.ckpt.save(total_steps, state)
        if eval_fn is not None:
            return state, eval_fn(state)
        return state, metrics

    # ---------- eval ----------
    def evaluate(self, state, batches) -> dict:
        loss_fn = jax.jit(lambda p, b: self.model.loss(p, b))
        total = {}
        n = 0
        for batch in batches:
            _, metrics = loss_fn(state["params"], batch)
            for k, v in metrics.items():
                total[k] = total.get(k, 0.0) + float(v)
            n += 1
        return {k: v / max(n, 1) for k, v in total.items()}
