"""Trainer: jit'd train step (any algorithm registered in repro.algos:
bp, dfa, dfa-fused, dfa-layerwise, ...), microbatch accumulation,
data-parallel batch sharding over the local device mesh, fault-tolerant
fit loop with checkpoint/auto-resume, straggler deadline hooks, CSV
metric logging, and optional throughput telemetry (repro.bench).

Data-parallel contract: with ``data_parallel`` on (default "auto": enabled
whenever more than one local device exists) the Trainer builds a 1-D data
mesh (launch/mesh.make_data_mesh), replicates the carried state, shards the
batch dim via dist.sharding.make_batch_shardings, and jits the fit step with
the carried state donated.  DFA's feedback projection is per-example, so the
only cross-device communication is the mean all-reduce over per-shard
gradients that the SPMD partitioner inserts — numerics match single-device
training up to float reduction order (tests/test_data_parallel.py).
Microbatch accumulation composes: the global batch is split over devices
first, microbatches second.

Hardware-in-the-loop contract: when the photonic backend consumes device
state (``PhotonicBackend.stateful_hardware``, e.g. the "emu" MRR emulation)
the Trainer carries a per-ring hardware pytree in ``state["hw"]`` —
resonance drift (OU process) plus the controller's calibration estimate.
Each step advances it (``repro.hardware.calibrate.advance``; recalibration
sweeps every ``TrainerConfig.recalibrate_every`` steps) and exposes it to
the projection via ``repro.hardware.drift.use_state``, all inside the same
jitted step — so long runs degrade (and recover) realistically, and the
state checkpoints/replicates/donates like any other training state.

Fault-tolerance contract: all training randomness (photonic noise, data
order) is a pure function of (seed, step), so `restore()` + `fit()` replays
identically after a crash — verified by tests/test_checkpoint.py.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time
import typing

import jax
import jax.numpy as jnp

from repro import algos
from repro import obs as obs_lib
from repro.algos.dfa import DFAConfig
from repro.core import photonics
from repro.data.pipeline import DevicePrefetcher
from repro.dist import sharding
from repro.hardware import calibrate as hw_calibrate
from repro.hardware import drift as hw_drift
from repro.lint import runtime as lint_runtime
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import SGDM
from repro.utils import prng


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    algo: str = "dfa"  # any name in algos.list_algos()
    dfa: DFAConfig = dataclasses.field(default_factory=DFAConfig)
    optimizer: typing.Any = dataclasses.field(default_factory=SGDM)
    seed: int = 0
    microbatches: int = 1
    # data-parallel scale-out: "auto" shards the batch over all local
    # devices when more than one exists; True forces a mesh (even of one
    # device); False keeps the original single-device path bit-for-bit.
    data_parallel: bool | str = "auto"
    # host->device pipeline depth for fit's input feeding (0 disables).
    prefetch: int = 2
    # in-situ calibration cadence for stateful photonic hardware (the "emu"
    # backend): a calibration sweep re-measures per-ring drift every this
    # many steps (0 = never — drift accumulates uncompensated).
    recalibrate_every: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 500
    keep_ckpts: int = 3
    log_every: int = 50
    log_path: str | None = None
    # straggler mitigation: per-step wall deadline (None = off). On real
    # multi-host deployments a step exceeding the deadline raises through
    # the supervisor which restarts the slow host from the last snapshot.
    step_deadline_s: float | None = None
    # in-situ diagnostics cadence (obs.introspect.AlignmentProbe): every
    # this many steps fit() computes the true BP gradient on the step's
    # own batch and logs DFA-vs-BP alignment (plus the emu noise budget)
    # through the observer.  None/0 = off — the probe never consumes
    # training PRNG keys, so probed and unprobed runs are bit-identical.
    probe_every: int | None = None
    # opt-in runtime sanitizers (repro.lint.runtime): checkify the jitted
    # train step (NaN/Inf, div-by-zero, OOB indexing + the emu channel's
    # check_finite assertions) and fail on any retrace after warmup.
    debug_checks: bool = False


def _resolve_data_parallel(flag) -> bool:
    if isinstance(flag, str):
        if flag == "auto":
            return jax.local_device_count() > 1
        if flag in ("on", "true"):
            return True
        if flag in ("off", "false"):
            return False
        raise ValueError(
            "data_parallel must be a bool, 'auto', 'on', or 'off'; "
            f"got {flag!r}")
    return bool(flag)


class Trainer:
    def __init__(self, model, cfg: TrainerConfig):
        self.model = model
        self.cfg = cfg
        self.algorithm = algos.get(cfg.algo)
        self._vg = self.algorithm.value_and_grad(model, cfg.dfa)
        self.mesh = None
        if _resolve_data_parallel(cfg.data_parallel):
            from repro.launch.mesh import make_data_mesh

            self.mesh = make_data_mesh()
        # stateful photonic hardware (drift + calibration): only backends
        # that consume device state get a carried "hw" pytree
        self._hw_stateful = photonics.get_backend(
            cfg.dfa.backend).stateful_hardware
        # step() keeps a non-donating jit — callers re-use the state they
        # pass in (metrics probes, tests); fit() owns its carried state and
        # donates it so XLA updates parameters in place.
        self._sentinels: dict = {}
        if cfg.debug_checks:
            step_body, s_step = lint_runtime.instrument(
                self._train_step, "Trainer.step")
            fit_body, s_fit = lint_runtime.instrument(
                self._train_step, "Trainer.fit_step")
            self._step_fn = jax.jit(step_body)
            self._fit_step_fn = jax.jit(fit_body, donate_argnums=(0,))
            self._sentinels = {"step": s_step, "fit_step": s_fit}
        else:
            self._step_fn = jax.jit(self._train_step)
            self._fit_step_fn = jax.jit(self._train_step, donate_argnums=(0,))
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.keep_ckpts) if cfg.ckpt_dir else None
        self._log_file = None
        self._log_keys = None
        self._probe = None  # lazily-built AlignmentProbe (jit cache survives fits)

    def _mesh_ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        return sharding.use_mesh(self.mesh)

    # ---------- state ----------
    def init_state(self, key=None):
        key = key if key is not None else prng.key(self.cfg.seed)
        params = self.model.init(key)
        fb = self.algorithm.init_extra_state(
            self.model, prng.fold_name(key, "feedback"), self.cfg.dfa)
        opt_state = self.cfg.optimizer.init(params)
        state = {"params": params, "fb": fb, "opt": opt_state,
                 "step": jnp.zeros((), jnp.int32)}
        if self._hw_stateful:
            state["hw"] = hw_drift.init_state(
                self.cfg.dfa.photonics, prng.fold_name(key, "hardware"))
        return state

    # ---------- core step ----------
    def _grads(self, params, fb, batch, rng):
        mb = self.cfg.microbatches
        if mb <= 1:
            return self._vg(params, fb, batch, rng)

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        batches = jax.tree_util.tree_map(split, batch)

        def body(carry, xs):
            acc, metrics_acc = carry
            micro, i = xs
            (loss, metrics), grads = self._vg(params, fb, micro, jax.random.fold_in(rng, i))
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            metrics_acc = jax.tree_util.tree_map(jnp.add, metrics_acc, metrics)
            return (acc, metrics_acc), loss

        (l0, m0), g0 = self._vg(
            params, fb, jax.tree_util.tree_map(lambda x: x[0], batches),
            jax.random.fold_in(rng, 0))
        rest = jax.tree_util.tree_map(lambda x: x[1:], batches)
        (gsum, msum), losses = jax.lax.scan(
            body, (g0, m0), (rest, jnp.arange(1, mb)))
        grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
        metrics = jax.tree_util.tree_map(lambda m: m / mb, msum)
        loss = (l0 + jnp.sum(losses)) / mb
        return (loss, metrics), grads

    def _train_step(self, state, batch):
        rng = prng.step_key(self.cfg.seed, state["step"], "noise")
        hw = state.get("hw")
        if hw is not None:
            # advance the physical device (drift + calibration sweeps) and
            # expose it to the photonic projections inside this trace
            hw = hw_calibrate.advance(
                hw, self.cfg.dfa.photonics, state["step"],
                prng.step_key(self.cfg.seed, state["step"], "hardware"),
                recalibrate_every=self.cfg.recalibrate_every)
            hw_ctx = hw_drift.use_state(hw)
        else:
            hw_ctx = contextlib.nullcontext()
        with hw_ctx:
            (loss, metrics), grads = self._grads(state["params"], state["fb"], batch, rng)
        new_params, new_opt, info = self.cfg.optimizer.update(
            grads, state["opt"], state["params"])
        metrics = dict(metrics)
        metrics.update(info)
        new_state = {"params": new_params, "fb": state["fb"], "opt": new_opt,
                     "step": state["step"] + 1}
        if hw is not None:
            new_state["hw"] = hw
            device = self.cfg.dfa.photonics.mrr
            # hw gauges only when the device actually drifts: a drift-free
            # bank (emu_ideal, or an abstract-noise emu config) carries hw
            # state that is identically zero, and emitting all-zero
            # hw_residual_rms rows would just feed hwmon vacuous data
            if device is not None and device.stateful:
                resid = hw_drift.residual(hw)
                metrics["hw_drift_rms"] = jnp.sqrt(jnp.mean(jnp.square(hw["drift"])))
                metrics["hw_residual_rms"] = jnp.sqrt(jnp.mean(jnp.square(resid)))
                # rings whose uncompensated detuning left the usable range —
                # the hwmon dead-ring gauge, computed on device so the host
                # never touches the full (n_buses, rows, cols) grid
                thresh = obs_lib.hwmon.DEAD_RING_FACTOR * device.drift_sigma
                metrics["hw_dead_rings"] = jnp.sum(
                    jnp.abs(resid) > thresh).astype(jnp.float32)
        return new_state, metrics

    def _dispatch(self, state, batch, step_fn):
        t0 = time.monotonic()
        with self._mesh_ctx():
            if self.cfg.debug_checks:
                err, (state, metrics) = step_fn(state, batch)
                err.throw()  # surfaces checkify findings as JaxRuntimeError
            else:
                state, metrics = step_fn(state, batch)
        if self.cfg.step_deadline_s is not None:
            jax.block_until_ready(state["step"])
            dt = time.monotonic() - t0
            if dt > self.cfg.step_deadline_s:
                raise TimeoutError(
                    f"step {int(state['step'])} exceeded deadline "
                    f"({dt:.1f}s > {self.cfg.step_deadline_s}s) — straggler")
        return state, metrics

    def step(self, state, batch):
        if self.mesh is not None:
            batch = sharding.put_batch(self.mesh, batch)
        return self._dispatch(state, batch, self._step_fn)

    # ---------- cost model ----------
    def step_cost(self, state, batch):
        """Trip-count-aware HLO cost of one train step (utils.hlo_cost):
        PER-DEVICE flops / HBM-proxy bytes / collective bytes of the
        optimized, post-SPMD module.  Feeds the bench MACs/s metric."""
        from repro.utils import hlo_cost

        if self.mesh is not None:
            state = sharding.replicate(self.mesh, state)
            batch = sharding.put_batch(self.mesh, batch)
        with self._mesh_ctx():
            compiled = self._step_fn.lower(state, batch).compile()
        return hlo_cost.analyze(compiled.as_text())

    # ---------- loop ----------
    def restore_or_init(self, key=None):
        state = self.init_state(key)
        if self.ckpt is not None:
            restored, step = self.ckpt.restore(state)
            if restored is not None:
                return restored, int(step)
        return state, 0

    def _log(self, step, row):
        """Append one CSV row of already-host-side floats (the fit loop
        drains device metrics with one batched ``jax.device_get`` before
        calling this — never one blocking transfer per scalar)."""
        if self.cfg.log_path is None:
            return
        if self._log_file is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.cfg.log_path)), exist_ok=True)
            new = not os.path.exists(self.cfg.log_path)
            self._log_file = open(self.cfg.log_path, "a")
            self._log_keys = sorted(row)
            if new:
                self._log_file.write("step," + ",".join(self._log_keys) + "\n")
        self._log_file.write(
            f"{step}," + ",".join(str(row.get(k, "nan"))
                                  for k in self._log_keys) + "\n")
        self._log_file.flush()

    def _make_feed(self, data_fn, total_steps: int):
        """Wrap data_fn with the device-put (sharded under a mesh) and the
        double-buffered prefetcher so fit's input feeding is off-path."""
        if self.mesh is not None:
            put = lambda batch: sharding.put_batch(self.mesh, batch)  # noqa: E731
        else:
            put = jax.device_put
        if self.cfg.prefetch <= 0:
            return lambda step: put(data_fn(step))
        return DevicePrefetcher(data_fn, put_fn=put, depth=self.cfg.prefetch,
                                limit=total_steps)

    def fit(self, data_fn, total_steps: int, eval_fn=None, verbose=True,
            timer=None, observer=None):
        """data_fn(step) -> batch (deterministic — restart-safe).

        ``timer`` is an optional repro.bench.StepTimer; when given, each
        step is synced (block_until_ready) and its wall time recorded —
        bench-only, since the sync serializes dispatch.

        ``observer`` is an optional ``repro.obs.Observer``: every step
        gets a dispatch span, recalibration steps an instant event, and
        each logging interval drains the device metrics through
        ``observer.log_step`` (one batched ``jax.device_get``, hwmon
        gauges + drift-budget alerts included).  ``None`` resolves to the
        shared null observer — a constant-cost no-op path.

        With ``cfg.probe_every`` set, every probe_every-th step first
        runs the ``obs.introspect.AlignmentProbe`` on the step's own
        (state, batch): DFA-vs-BP alignment, grad norms, and (on
        stateful hardware) the ``obs.attribution`` noise budget land as
        an extra observer row at that step.  The probe re-derives its
        keys from (seed, step) and never donates, so training states are
        bit-identical with the probe on or off.
        """
        observer = obs_lib.resolve(observer)
        probe = None
        if self.cfg.probe_every:
            if self._probe is None:
                from repro.obs.introspect import AlignmentProbe

                self._probe = AlignmentProbe(self)
            probe = self._probe
            if not observer.enabled:
                # probe rows need somewhere to land: an in-memory observer
                # (MemorySink ring) keeps the no-observer call signature
                observer = obs_lib.Observer()
        state, start = self.restore_or_init()
        if self.mesh is not None:
            state = sharding.replicate(self.mesh, state)
        feed = self._make_feed(data_fn, total_steps)
        metrics = {}
        recal = self.cfg.recalibrate_every if self._hw_stateful else 0
        if timer is not None:
            timer.start()
        try:
            for step in range(start, total_steps):
                batch = feed(step)
                if timer is not None and timer.examples_per_step is None:
                    leaves = jax.tree_util.tree_leaves(batch)
                    if leaves and getattr(leaves[0], "ndim", 0) >= 1:
                        timer.examples_per_step = int(leaves[0].shape[0])
                if probe is not None and step % self.cfg.probe_every == 0:
                    # diagnostics BEFORE the update: alignment of the DFA
                    # update this step is about to apply, on its own batch
                    with observer.span("probe", step=step):
                        with self._mesh_ctx():
                            probed = probe(state, batch)
                        probe_host = observer.log_step(step, probed)
                    if verbose:
                        print(f"[probe {step}] align_global="
                              f"{probe_host.get('align_global', float('nan')):.4f}",
                              flush=True)
                if observer.enabled:
                    # the span covers dispatch (async under jit — device time
                    # shows up in the logging-interval drain span instead)
                    with observer.span("step", step=step,
                                       microbatches=self.cfg.microbatches):
                        state, metrics = self._dispatch(state, batch,
                                                        self._fit_step_fn)
                    if recal > 0 and step > 0 and step % recal == 0:
                        # mirrors hw_calibrate.advance's cadence inside the step
                        observer.event("recalibration", cat="hwmon", step=step)
                else:
                    state, metrics = self._dispatch(state, batch,
                                                    self._fit_step_fn)
                if timer is not None:
                    timer.tick(state["step"])
                if (step + 1) % self.cfg.log_every == 0 or step + 1 == total_steps:
                    if observer.enabled:
                        with observer.span("drain", step=step + 1):
                            host = observer.log_step(step + 1, metrics)
                    else:
                        # one batched transfer for the whole dict — never one
                        # blocking float() per metric; the floats below read
                        # host memory, not the device
                        host = {k: float(v) for k, v in  # lint: disable=RL002
                                jax.device_get(dict(metrics)).items()}  # lint: disable=RL002
                    self._log(step + 1, host)
                    if verbose:
                        txt = " ".join(f"{k}={v:.4f}"
                                       for k, v in sorted(host.items()))
                        print(f"[step {step + 1}/{total_steps}] {txt}", flush=True)
                if self.ckpt is not None and (step + 1) % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step + 1, state)
        finally:
            # interrupted or not, buffered JSONL rows reach disk — an
            # aborted run leaves a parseable metrics file
            observer.flush()
        if self.ckpt is not None:
            self.ckpt.save(total_steps, state)
        if eval_fn is not None:
            return state, eval_fn(state)
        return state, metrics

    # ---------- eval ----------
    def evaluate(self, state, batches) -> dict:
        loss_fn = jax.jit(lambda p, b: self.model.loss(p, b))
        total = {}
        n = 0
        for batch in batches:
            if self.mesh is not None:
                batch = sharding.put_batch(self.mesh, batch)
            with self._mesh_ctx():
                _, metrics = loss_fn(state["params"], batch)
            for k, v in metrics.items():
                # accumulate on device; a float() here would block per batch
                total[k] = total.get(k, 0.0) + v
            n += 1
        host = jax.device_get(total)  # one batched transfer for the run
        return {k: float(v) / max(n, 1) for k, v in host.items()}
