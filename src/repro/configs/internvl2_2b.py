"""internvl2-2b [vlm] — 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92553,
InternViT frontend STUBBED (precomputed patch embeds, d_vision=1024).
[arXiv:2404.16821]"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.transformer import TransformerConfig, TransformerLM, VisionSettings

N_PATCHES = 256
D_VISION = 1024


def full(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="internvl2-2b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=8192, vocab_size=92553, head_dim=128,
        vision=VisionSettings(d_vision=D_VISION, n_patches=N_PATCHES),
        rope_theta=1e6, dtype=dtype,
    ))


def smoke() -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
        vision=VisionSettings(d_vision=32, n_patches=8),
        dtype=jnp.float32,
    ))


@dataclasses.dataclass(frozen=True)
class _InternVLArch(Arch):
    def input_extras(self, batch: int, kind: str, dtype=jnp.bfloat16) -> dict:
        if kind == "train":
            return {"patch_embeds": jax.ShapeDtypeStruct((batch, N_PATCHES, D_VISION), dtype)}
        return {}


def opt(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="internvl2-2b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=8192, vocab_size=92553, pad_vocab_to=92672,
        head_dim=128,
        vision=VisionSettings(d_vision=D_VISION, n_patches=N_PATCHES),
        rope_theta=1e6, dtype=dtype,
    ))


ARCH = _InternVLArch(
    name="internvl2-2b", family="vlm", make_model=full, make_smoke=smoke,
    make_opt=opt,
    source="arXiv:2404.16821",
    notes="ViT tower stubbed per assignment; serve paths are text-decode",
)
