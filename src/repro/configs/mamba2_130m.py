"""mamba2-130m [ssm] — 24L d=768 (attention-free) vocab=50280,
ssm_state=128, SSD.  [arXiv:2405.21060; unverified]"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.mamba import MambaConfig, MambaLM


def full(dtype=jnp.bfloat16) -> MambaLM:
    return MambaLM(MambaConfig(
        name="mamba2-130m", n_layers=24, d_model=768, vocab_size=50280,
        d_state=128, head_dim=64, expand=2, chunk=256, dtype=dtype,
    ))


def smoke() -> MambaLM:
    return MambaLM(MambaConfig(
        name="mamba2-smoke", n_layers=2, d_model=32, vocab_size=128,
        d_state=16, head_dim=16, expand=2, chunk=8, dtype=jnp.float32,
    ))


def opt(dtype=jnp.bfloat16) -> MambaLM:
    """§Perf M1+M2: shard-aligned split projections (kills per-layer
    collective-permutes from the fused in_proj split) + vocab padded to
    50432 (kills the unsharded-unembedding logits all-reduce)."""
    return MambaLM(MambaConfig(
        name="mamba2-130m", n_layers=24, d_model=768, vocab_size=50280,
        d_state=128, head_dim=64, expand=2, chunk=256,
        split_proj=True, pad_vocab_to=50432, dtype=dtype,
    ))


ARCH = Arch(
    name="mamba2-130m", family="ssm", make_model=full, make_smoke=smoke,
    make_opt=opt,
    sub_quadratic=True, source="arXiv:2405.21060 (unverified)",
    notes="SSD; O(1) decode state -> long_500k runnable",
)
