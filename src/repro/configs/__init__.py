"""Architecture registry: the 10 assigned archs + the paper's own MLP."""

from __future__ import annotations

from repro.configs import (
    granite_8b,
    internvl2_2b,
    kimi_k2_1t_a32b,
    mamba2_130m,
    minicpm3_4b,
    mnist_mlp,
    qwen1_5_0_5b,
    qwen2_moe_a2_7b,
    qwen3_1_7b,
    recurrentgemma_9b,
    whisper_small,
)
from repro.configs.base import SHAPES, Arch, ShapeCase, token_specs

_MODULES = [
    qwen1_5_0_5b,
    minicpm3_4b,
    qwen3_1_7b,
    granite_8b,
    qwen2_moe_a2_7b,
    kimi_k2_1t_a32b,
    mamba2_130m,
    internvl2_2b,
    recurrentgemma_9b,
    whisper_small,
    mnist_mlp,
]

REGISTRY: dict[str, Arch] = {m.ARCH.name: m.ARCH for m in _MODULES}

ASSIGNED: tuple[str, ...] = tuple(
    m.ARCH.name for m in _MODULES if m.ARCH.name != "mnist_mlp"
)


def get(name: str) -> Arch:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> list[str]:
    return list(REGISTRY)


__all__ = ["Arch", "ShapeCase", "SHAPES", "REGISTRY", "ASSIGNED", "get",
           "list_archs", "token_specs"]
