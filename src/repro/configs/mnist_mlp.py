"""The paper's own architecture: 784×800×800×10 ReLU MLP (Fig. 5),
error_tap = logits, exact DFA per Eq. 1."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.mlp import MLPClassifier


def full(dtype=jnp.float32) -> MLPClassifier:
    return MLPClassifier(in_dim=784, hidden=(800, 800), n_classes=10, dtype=dtype)


def smoke() -> MLPClassifier:
    return MLPClassifier(in_dim=64, hidden=(32, 32), n_classes=10, dtype=jnp.float32)


ARCH = Arch(
    name="mnist_mlp", family="paper", make_model=full, make_smoke=smoke,
    has_decoder=False, source="paper §4",
)
