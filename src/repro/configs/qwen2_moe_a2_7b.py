"""qwen2-moe-a2.7b [moe] — 24L d=2048 16H (GQA kv=16) expert d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.transformer import MoESettings, TransformerConfig, TransformerLM


def full(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab_size=151936, head_dim=128,
        moe=MoESettings(n_experts=60, top_k=4, d_ff_expert=1408,
                        n_shared_experts=4, d_ff_shared=1408),
        rope_theta=1e6, dtype=dtype,
    ))


def smoke() -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=96, vocab_size=128, head_dim=16,
        moe=MoESettings(n_experts=8, top_k=2, d_ff_expert=96,
                        n_shared_experts=2, d_ff_shared=96,
                        capacity_factor=2.0),
        dtype=jnp.float32,
    ))


def opt(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab_size=151936, head_dim=128,
        moe=MoESettings(n_experts=60, top_k=4, d_ff_expert=1408,
                        n_shared_experts=4, d_ff_shared=1408, dispatch="einsum"),
        rope_theta=1e6, dtype=dtype,
    ))


ARCH = Arch(
    name="qwen2-moe-a2.7b", family="moe", make_model=full, make_smoke=smoke,
    make_opt=opt,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B", notes="4 shared + 60 routed top-4",
)
