"""granite-8b [dense] — 36L d=4096 32H (GQA kv=8) d_ff=14336 vocab=49152,
llama-arch code model.  [arXiv:2405.04324]"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.transformer import TransformerConfig, TransformerLM


def full(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="granite-8b", n_layers=36, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab_size=49152, head_dim=128,
        rope_theta=1e4, dtype=dtype,
    ))


def smoke() -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="granite-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab_size=128, head_dim=16,
        dtype=jnp.float32,
    ))


ARCH = Arch(
    name="granite-8b", family="dense", make_model=full, make_smoke=smoke,
    source="arXiv:2405.04324",
)
