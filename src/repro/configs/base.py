"""Architecture registry plumbing: every assigned arch registers an
``Arch`` with a full-size model factory (dry-run only — never allocated),
a reduced smoke-test factory, and its input-spec extras."""

from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    family: str  # dense | moe | ssm | vlm | hybrid | audio
    make_model: typing.Callable  # (dtype) -> DFAModel, full public config
    make_smoke: typing.Callable  # () -> DFAModel, reduced same-family config
    make_opt: typing.Callable | None = None  # perf-optimised variant (§Perf)
    sub_quadratic: bool = False  # long_500k runnable?
    has_decoder: bool = True
    source: str = ""
    notes: str = ""

    def input_extras(self, batch: int, kind: str, dtype=jnp.bfloat16) -> dict:
        """Arch-specific extra inputs (modality-frontend stubs) as
        ShapeDtypeStructs. kind: train | prefill | decode."""
        return {}


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524288, 1),
}


def token_specs(batch: int, seq: int) -> dict:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
