"""minicpm3-4b [dense] — 62L d=2560 40H d_ff=6400 vocab=73448, MLA.
[hf:openbmb/MiniCPM3-4B]  MLA dims follow the HF config family:
q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v_head 64."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.transformer import MLASettings, TransformerConfig, TransformerLM


def full(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40,
        n_kv_heads=40, d_ff=6400, vocab_size=73448,
        mla=MLASettings(q_lora_rank=768, kv_lora_rank=256,
                        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
        dtype=dtype,
    ))


def smoke() -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="minicpm3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=128,
        mla=MLASettings(q_lora_rank=32, kv_lora_rank=16,
                        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        dtype=jnp.float32,
    ))


def opt(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40,
        n_kv_heads=40, d_ff=6400, vocab_size=73448, pad_vocab_to=73728,
        mla=MLASettings(q_lora_rank=768, kv_lora_rank=256,
                        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
        dtype=dtype,
    ))


ARCH = Arch(
    name="minicpm3-4b", family="dense", make_model=full, make_smoke=smoke,
    make_opt=opt,
    source="hf:openbmb/MiniCPM3-4B", notes="MLA latent cache; absorbed decode",
)
