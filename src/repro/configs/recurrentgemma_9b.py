"""recurrentgemma-9b [hybrid] — 38L d=4096 16H (GQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention 1:2 pattern, window 2048.
[arXiv:2402.19427; unverified]"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.recurrentgemma import RecurrentGemmaConfig, RecurrentGemmaLM


def full(dtype=jnp.bfloat16) -> RecurrentGemmaLM:
    return RecurrentGemmaLM(RecurrentGemmaConfig(
        name="recurrentgemma-9b", n_layers=38, d_model=4096, n_heads=16,
        n_kv_heads=1, d_ff=12288, vocab_size=256000, d_rnn=4096,
        window=2048, dtype=dtype,
    ))


def smoke() -> RecurrentGemmaLM:
    return RecurrentGemmaLM(RecurrentGemmaConfig(
        name="recurrentgemma-smoke", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab_size=128, d_rnn=64,
        window=16, dtype=jnp.float32,
    ))


ARCH = Arch(
    name="recurrentgemma-9b", family="hybrid", make_model=full, make_smoke=smoke,
    sub_quadratic=True, source="arXiv:2402.19427 (unverified)",
    notes="ring-buffer window cache + O(1) RG-LRU state -> long_500k runnable",
)
