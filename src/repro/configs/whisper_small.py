"""whisper-small [audio] — 12+12L d=768 12H d_ff=3072 vocab=51865, enc-dec,
conv frontend STUBBED (precomputed frame embeds).  [arXiv:2212.04356;
unverified]"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.whisper import WhisperConfig, WhisperModel

N_FRAMES = 1500
D_MODEL = 768


def full(dtype=jnp.bfloat16) -> WhisperModel:
    return WhisperModel(WhisperConfig(
        name="whisper-small", n_enc_layers=12, n_dec_layers=12,
        d_model=D_MODEL, n_heads=12, d_ff=3072, vocab_size=51865,
        n_frames=N_FRAMES, dtype=dtype,
    ))


def smoke() -> WhisperModel:
    return WhisperModel(WhisperConfig(
        name="whisper-smoke", n_enc_layers=2, n_dec_layers=2,
        d_model=48, n_heads=4, d_ff=96, vocab_size=128,
        n_frames=32, max_target=64, dtype=jnp.float32,
    ))


@dataclasses.dataclass(frozen=True)
class _WhisperArch(Arch):
    def input_extras(self, batch: int, kind: str, dtype=jnp.bfloat16) -> dict:
        # precomputed frame embeddings at backbone width (frontend stub)
        return {"frames": jax.ShapeDtypeStruct((batch, N_FRAMES, D_MODEL), dtype)}


def opt(dtype=jnp.bfloat16) -> WhisperModel:
    """§Perf W1: vocab padded to 51968 (÷16) — the raw 51865 vocab falls
    back to a model-replicated unembedding whose f32 logits copies dominate
    the train cell's memory."""
    return WhisperModel(WhisperConfig(
        name="whisper-small", n_enc_layers=12, n_dec_layers=12,
        d_model=D_MODEL, n_heads=12, d_ff=3072, vocab_size=51865,
        pad_vocab_to=51968, n_frames=N_FRAMES, dtype=dtype,
    ))


ARCH = _WhisperArch(
    name="whisper-small", family="audio", make_model=full, make_smoke=smoke,
    make_opt=opt,
    source="arXiv:2212.04356 (unverified)",
    notes="enc-dec DFA: encoder gets pooled-error feedback (DESIGN.md §6)",
)
