"""qwen1.5-0.5b [dense] — 24L d=1024 16H (GQA kv=16) d_ff=2816 vocab=151936,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B]"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.transformer import TransformerConfig, TransformerLM


def full(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="qwen1.5-0.5b", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=2816, vocab_size=151936, head_dim=64,
        qkv_bias=True, rope_theta=1e6, dtype=dtype,
    ))


def smoke() -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="qwen1.5-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=128, head_dim=16,
        qkv_bias=True, rope_theta=1e6, dtype=jnp.float32,
    ))


ARCH = Arch(
    name="qwen1.5-0.5b", family="dense", make_model=full, make_smoke=smoke,
    source="hf:Qwen/Qwen1.5-0.5B",
)
