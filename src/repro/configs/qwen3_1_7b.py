"""qwen3-1.7b [dense] — 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936,
qk_norm.  [hf:Qwen/Qwen3-family]"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.transformer import TransformerConfig, TransformerLM


def full(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=6144, vocab_size=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6, dtype=dtype,
    ))


def smoke() -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
        qk_norm=True, rope_theta=1e6, dtype=jnp.float32,
    ))


ARCH = Arch(
    name="qwen3-1.7b", family="dense", make_model=full, make_smoke=smoke,
    source="hf:Qwen/Qwen3-8B (family)", notes="qk_norm, GQA",
)
