"""kimi-k2-1t-a32b [moe] — 61L d=7168 64H (GQA kv=8 per assignment)
expert d_ff=2048 vocab=163840, 384 routed experts top-8 + 1 shared.
Trillion-parameter paper-table entry.  [arXiv:2501.kimi2; unverified]

head_dim is set to 128 (MXU-aligned; the assignment leaves it unspecified
and 7168/64=112 would misalign the MXU)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch
from repro.models.transformer import MoESettings, TransformerConfig, TransformerLM


def full(dtype=jnp.bfloat16) -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
        n_kv_heads=8, d_ff=2048, vocab_size=163840, head_dim=128,
        moe=MoESettings(n_experts=384, top_k=8, d_ff_expert=2048,
                        n_shared_experts=1, d_ff_shared=2048,
                        capacity_factor=1.25),
        rope_theta=5e4, dtype=dtype,
    ))


def smoke() -> TransformerLM:
    return TransformerLM(TransformerConfig(
        name="kimi-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=16,
        moe=MoESettings(n_experts=16, top_k=4, d_ff_expert=64,
                        n_shared_experts=1, d_ff_shared=64,
                        capacity_factor=2.0),
        dtype=jnp.float32,
    ))


def opt(dtype=jnp.bfloat16) -> TransformerLM:
    """§Perf K1 (REFUTED, kept for the record): gather-based dispatch was
    hypothesised to cut the one-hot routing matmuls; under GSPMD the
    expert-sharded gather/scatter lowered to ~8 TB of all-to-all instead
    (EXPERIMENTS.md §Perf). einsum dispatch retained; the gather path
    remains available for single-device / shard_map use."""
    return TransformerLM(TransformerConfig(
        name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
        n_kv_heads=8, d_ff=2048, vocab_size=163840, head_dim=128,
        moe=MoESettings(n_experts=384, top_k=8, d_ff_expert=2048,
                        n_shared_experts=1, d_ff_shared=2048,
                        capacity_factor=1.25, dispatch="einsum"),
        rope_theta=5e4, dtype=dtype,
    ))


ARCH = Arch(
    name="kimi-k2-1t-a32b", family="moe", make_model=full, make_smoke=smoke,
    make_opt=opt,
    source="arXiv:2501.kimi2 (unverified)",
    notes="1T total / 32B active; fits 256 v5e only fully 2-D sharded",
)
