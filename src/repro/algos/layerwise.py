"""``dfa-layerwise`` — the shallow-DFA ablation with a *per-layer error tap*.

Standard DFA taps the error once at the top and broadcasts it to every
layer.  The layerwise ablation instead taps an error at *each layer's own
output*: block k's output is read out through its fixed feedback bank run
forward (t_k = y_k·B(k), the same inscribed MRR weights used twice — once
as a random readout, once as the feedback projection), the loss is evaluated
at that local tap, and the resulting local error is projected back through
B(k) as usual:

    t_k   = y_k · B(k)                      # fixed random readout, d_tap-dim
    e_k   = ∂L(t_k)/∂t_k                    # layer-local error
    δ(k)  = photonic_project(e_k, B(k)) ⊙ g'(a(k))

Each layer therefore trains greedily against its own shallow loss — this is
the ablation that isolates how much of DFA's performance comes from the
*shared* top error versus purely local credit assignment, while keeping the
layer-parallel, dependency-free backward structure (and the photonic noise
model) identical to ``dfa``.

For ``error_tap == "logits"`` models the tap feeds ``loss_from_logits``
directly (t_k has the logits dimension); for ``error_tap == "hidden"``
models the tap is treated as a d_model-dim pseudo-hidden state pushed
through the (frozen, exactly-trained) head.  Segments with a non-trivial
error adapter/expander (pooled encoder paths in enc-dec models) fall back
to the global broadcast error for that segment.  Head and embed updates are
identical to ``dfa``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algos import base
from repro.algos import dfa as dfa_lib


def value_and_grad(model, cfg: dfa_lib.DFAConfig):
    """fn(params, fb, batch, rng) -> ((loss, metrics), grads) with layer-
    local error taps for every segment block."""

    def fn(params, fb, batch, rng):
        fwd = dfa_lib.forward_with_error(model, params, cfg, batch)
        global_delta = dfa_lib.dfa_delta(cfg)

        def local_error(tap):
            """∂L/∂tap at the layer-local readout (d_tap-dim)."""
            if model.error_tap == "logits":
                _, lvjp, _ = jax.vjp(
                    lambda lg: model.loss_from_logits(lg, batch), tap,
                    has_aux=True)
                (e,) = lvjp(jnp.float32(1.0))
                return e

            def head_loss(h):
                logits = model.head_logits(params, h, batch)
                loss, _metrics = model.loss_from_logits(logits, batch)
                return loss

            return jax.grad(head_loss)(tap)

        def delta_fn(spec, e_seg, bmat, key, y):
            if spec.adapt_error is not None or spec.expand_delta is not None:
                # pooled/adapted injection point: local tap shapes don't
                # line up with the loss — use the global error for this
                # segment (plain DFA behaviour)
                return global_delta(spec, e_seg, bmat, key, y)
            tap = jax.lax.stop_gradient(y.astype(jnp.float32)) @ bmat.astype(
                jnp.float32)
            e_loc = local_error(tap)
            e_loc = dfa_lib.compress_error(e_loc, cfg.error_compress)
            e_loc = jax.lax.stop_gradient(e_loc.astype(y.dtype))
            delta = dfa_lib._project(e_loc, bmat, cfg, key)
            return delta.reshape(y.shape)

        grads = {"head": fwd["g_head"]}
        grads.update(dfa_lib.segment_grads(
            model, params, cfg, fwd, fb, rng, delta_fn))
        g_embed = dfa_lib.embed_grads(model, params, cfg, fwd, fb, rng)
        if g_embed is not None:
            grads["embed"] = g_embed
        total, metrics = dfa_lib._totals(fwd)
        return (total, metrics), grads

    return fn


class LayerwiseDFAAlgorithm(base.Algorithm):
    name = "dfa-layerwise"

    def init_extra_state(self, model, key, cfg):
        return dfa_lib.init_feedback(model, key, cfg)

    def value_and_grad(self, model, cfg):
        return value_and_grad(model, cfg)


base.register(LayerwiseDFAAlgorithm())
