"""Pluggable training algorithms.

The paper's contribution is an *algorithm × hardware* matrix; this package
is the algorithm axis.  Adding an algorithm is a registration::

    from repro.algos import Algorithm, register

    class MyAlgo(Algorithm):
        name = "my-algo"
        def value_and_grad(self, model, cfg): ...

    register(MyAlgo())

Built-in registrations (import side effect of the submodules below):

* ``bp``            — exact backprop baseline (algos/bp.py)
* ``dfa``           — the paper's Eq. 1 engine (algos/dfa.py)
* ``dfa-fused``     — same gradients, update fused into the backward map
* ``dfa-layerwise`` — per-layer error tap, the shallow-DFA ablation

The hardware axis is ``core.photonics.PRESETS`` and the execution axis is
``core.photonics`` backends (``ref`` | ``pallas``); ``repro.api`` composes
all three into a Session.
"""

from repro.algos.base import Algorithm, get, list_algos, register
from repro.algos import bp, dfa, layerwise  # noqa: F401  (register built-ins)
from repro.algos.dfa import DFAConfig

__all__ = ["Algorithm", "DFAConfig", "get", "list_algos", "register",
           "bp", "dfa", "layerwise"]
