"""Direct Feedback Alignment training engine (the paper's algorithm).

For every block k the gradient is computed from the *output error only*
(paper Eq. 1):   δ(k) = B(k)·e  ⊙ local-derivative, realised as

    δ(k) = photonic_project(e, B(k))       # the MRR weight-bank product,
                                           # with measured analog noise
    grads(k) = local_vjp(block_k, x_k)(δ(k))   # exact *within* the block

The per-layer loop is a ``lax.map`` with **no loop-carried dependency** —
unlike backprop there is no sequential chain, which is the systems property
the paper exploits (all layers updated in parallel during the backward
pass).  The error is computed once and broadcast; under a sharded mesh this
is ONE collective instead of backprop's L chained backward matmuls.

For an MLP of DenseBlocks this reduces *exactly* to the paper's update:
local vjp through the activation contributes the ⊙ g'(a) Hadamard, and
grad_W = (B e ⊙ g'(a)) · h_inᵀ.

Error compression (`ternary` per the paper's ref [48], or `int8`) is applied
to e before projection/broadcast — the gradient-compression knob for
distributed training.

This module registers two algorithms:

* ``dfa``       — value_and_grad per Eq. 1 (+ the generic fused fallback)
* ``dfa-fused`` — same gradients, but ``fused_step`` consumes each layer's
  gradient immediately inside the backward map (SGDM fused into the layer
  loop) so stacked segment gradients never materialise.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.algos import base
from repro.core import feedback as fb_lib
from repro.core import photonics
from repro.dist.sharding import unshard_fsdp
from repro.utils import prng
from repro.utils.tree import path_map


@dataclasses.dataclass(frozen=True)
class DFAConfig:
    """Config for the whole DFA algorithm family (bp ignores it)."""

    photonics: photonics.PhotonicConfig = dataclasses.field(
        default_factory=lambda: photonics.PRESETS["ideal"]
    )
    feedback: fb_lib.FeedbackConfig = dataclasses.field(
        default_factory=fb_lib.FeedbackConfig
    )
    error_compress: str = "none"  # none | ternary | int8
    # photonic execution backend: auto | ref | pallas | a PhotonicBackend
    # instance (see core.photonics.register_backend / get_backend)
    backend: str | photonics.PhotonicBackend = "auto"
    sequential: bool = False  # lax.map (False: still sequential in schedule,
    # but dependency-free; kept for clarity/ablation hooks)
    # Freeze norm scales in DFA blocks.  The cotangent at each norm output
    # exists ONLY to produce the norm-scale gradient (DFA discards input
    # cotangents), yet it costs a (B,S,D) model-axis all-reduce per matmul
    # group per layer.  Freezing norms DCEs those all-reduces (§Perf G1);
    # norm scales stay at init (a documented training-semantics trade).
    freeze_norms: bool = False


_NORM_PAT = ("norm", "ln1", "ln2", "ln3", "ln_enc", "/ln/")


def _is_norm_path(path: str) -> bool:
    return any(p in path for p in _NORM_PAT)


def freeze_norm_leaves(tree):
    """stop_gradient on norm-scale leaves: their grads become zero and XLA
    dead-code-eliminates the (B,S,D) all-reduces that fed them."""
    return path_map(
        lambda p, x: jax.lax.stop_gradient(x) if _is_norm_path(p) else x, tree)


def compress_error(e, mode: str):
    """Compress the error before broadcast/projection (ref [48])."""
    if mode == "none":
        return e
    if mode == "ternary":
        # sparse ternarisation: keep only errors well above the mean
        # (swept in EXPERIMENTS.md — tau=2.0 best at 0.25 B/element;
        # denser ternary loses more accuracy at equal steps)
        a = jnp.abs(e)
        tau = 2.0 * jnp.mean(a)
        keep = a > tau
        scale = jnp.sum(a * keep) / jnp.maximum(jnp.sum(keep), 1.0)
        return jnp.sign(e) * keep * scale
    if mode == "int8":
        amax = jnp.maximum(jnp.max(jnp.abs(e)), 1e-12)
        q = jnp.round(jnp.clip(e / amax, -1, 1) * 127.0)
        return (q / 127.0 * amax).astype(e.dtype)
    raise ValueError(f"unknown error_compress {mode!r}")


def init_feedback(model, key, cfg: DFAConfig):
    """Fixed random feedback for every segment + the embed path."""
    d_tap = model.d_tap
    fb = {}
    for spec in model.segment_specs():
        fb[spec.name] = fb_lib.make_feedback(
            prng.fold_name(key, spec.name), spec.n_layers, spec.d_inject, d_tap,
            cfg.feedback,
        )
    # embed feedback: inject at embed output (d_inject of first segment)
    first = model.segment_specs()[0]
    fb["embed"] = fb_lib.make_feedback(
        prng.fold_name(key, "embed"), 1, first.d_inject, d_tap, cfg.feedback
    )[0]
    return fb


def _project(e, bmat, cfg: DFAConfig, key):
    """δ = e·Bᵀ through the photonic execution model."""
    return photonics.photonic_project(
        e, bmat, cfg.photonics, key, backend=cfg.backend)


def forward_with_error(model, params, cfg: DFAConfig, batch):
    """Shared forward: embed → segments → head → loss, returning everything
    the DFA-family backwards need.  Head gradients are exact; the error is
    tapped per model.error_tap, compressed, and stop_gradient'd (on hardware
    e is fetched from SRAM & re-encoded each cycle — never differentiated).
    """
    has_embed_params = len(jax.tree_util.tree_leaves(params.get("embed", {}))) > 0
    if has_embed_params:
        x0, embed_vjp = jax.vjp(
            lambda pe: model.embed({**params, "embed": pe}, batch),
            params["embed"],
        )
    else:
        x0 = model.embed(params, batch)
        embed_vjp = None

    x_final, saved, auxes = model.run_segments(params, x0)

    logits, head_vjp = jax.vjp(
        lambda ph, xf: model.head_logits({**params, "head": ph}, xf, batch),
        params["head"], x_final,
    )
    loss, loss_vjp, metrics = jax.vjp(
        lambda lg: model.loss_from_logits(lg, batch), logits, has_aux=True
    )
    (e_logits,) = loss_vjp(jnp.float32(1.0))
    g_head, e_hidden = head_vjp(e_logits)

    e_tap = e_logits if model.error_tap == "logits" else e_hidden
    if model.error_tap == "hidden":
        # broadcast e in the model's compute dtype (the analog encoding
        # is <= 7 effective bits anyway — f32 error transport is waste)
        e_tap = e_tap.astype(x_final.dtype)
    e_tap = compress_error(e_tap, cfg.error_compress)
    e_tap = jax.lax.stop_gradient(e_tap)
    return dict(x0=x0, embed_vjp=embed_vjp, saved=saved, auxes=auxes,
                g_head=g_head, e_tap=e_tap, loss=loss, metrics=metrics)


def segment_grads(model, params, cfg: DFAConfig, fwd, fb, rng, delta_fn):
    """Layer-parallel backward over every segment (no loop-carried deps).

    ``delta_fn(spec, e_seg, bmat, key, y)`` produces the cotangent injected
    at the block output — the only point where DFA variants differ."""
    grads = {}
    for spec in model.segment_specs():
        tape = fwd["saved"][spec.name]
        fb_seg = fb[spec.name]
        seg_key = prng.fold_name(rng, spec.name)
        e_seg = spec.adapt_error(fwd["e_tap"]) if spec.adapt_error else fwd["e_tap"]

        def per_layer(xs, spec=spec, fb_seg=fb_seg, seg_key=seg_key,
                      extras=tape.extras, e_seg=e_seg):
            bp, xk, idx = xs
            bmat = fb_lib.feedback_for(fb_seg, idx)
            kk = jax.random.fold_in(seg_key, idx)

            def local(p):
                if cfg.freeze_norms:
                    p = freeze_norm_leaves(p)
                return spec.apply(unshard_fsdp(p), xk, extras)

            (y, _aux), vjp = jax.vjp(local, bp)
            delta = delta_fn(spec, e_seg, bmat, kk, y)
            (g,) = vjp((delta.astype(y.dtype), jnp.float32(1.0)))
            return g

        xs = (params[spec.name], tape.inputs, jnp.arange(spec.n_layers))
        grads[spec.name] = jax.lax.map(per_layer, xs)
    return grads


def dfa_delta(cfg: DFAConfig):
    """Eq. 1's cotangent: the global error projected through B(k)."""

    def delta_fn(spec, e_seg, bmat, key, y):
        delta = _project(e_seg, bmat, cfg, key)
        if spec.expand_delta is not None:
            return spec.expand_delta(delta, y.shape)
        return delta.reshape(y.shape)

    return delta_fn


def embed_grads(model, params, cfg: DFAConfig, fwd, fb, rng):
    """DFA cotangent at the embed output (or zeros if embed has params but
    no feedback path applies)."""
    if fwd["embed_vjp"] is not None:
        delta0 = model.embed_feedback(
            fwd["e_tap"], fb["embed"], fwd["x0"],
            lambda e, b: _project(e, b, cfg, prng.fold_name(rng, "embed")),
        )
        (g_embed,) = fwd["embed_vjp"](delta0)
        return g_embed
    if "embed" in params:
        return jax.tree_util.tree_map(jnp.zeros_like, params["embed"])
    return None


def _totals(fwd):
    aux_total = sum(fwd["auxes"].values()) if fwd["auxes"] else 0.0
    total = fwd["loss"] + aux_total
    metrics = dict(fwd["metrics"])
    metrics["loss"] = total
    if fwd["auxes"]:
        metrics["aux_loss"] = aux_total
    return total, metrics


def value_and_grad(model, cfg: DFAConfig):
    """Returns fn(params, fb, batch, rng) -> ((loss, metrics), grads).

    ``grads`` matches the structure of ``params``.  Head gradients are exact;
    segment/embed gradients are DFA (photonic-noisy) per Eq. 1.
    """

    def fn(params, fb, batch, rng):
        fwd = forward_with_error(model, params, cfg, batch)
        grads = {"head": fwd["g_head"]}
        grads.update(segment_grads(model, params, cfg, fwd, fb, rng,
                                   dfa_delta(cfg)))
        g_embed = embed_grads(model, params, cfg, fwd, fb, rng)
        if g_embed is not None:
            grads["embed"] = g_embed
        total, metrics = _totals(fwd)
        return (total, metrics), grads

    return fn


def make_fused_train_step(model, cfg: DFAConfig, optimizer):
    """DFA backward with the SGD-momentum update FUSED into the per-layer
    map: each layer's gradient is consumed immediately by its parameter /
    momentum update, so the stacked segment gradients never materialise
    (at kimi-k2 scale that is ~8 GB/device of peak memory).  This is only
    possible because the DFA backward has no inter-layer dependency — the
    update can't invalidate any later backward step.

    optimizer must be SGDM-shaped (lr, momentum, weight_decay fields).
    Returns step(params, fb, opt_state, batch, rng) ->
    (new_params, new_opt_state, loss).
    """
    specs = model.segment_specs()

    def _upd(p, m, g, lr):
        g32 = g.astype(jnp.float32)
        if optimizer.weight_decay:
            g32 = g32 + optimizer.weight_decay * p.astype(jnp.float32)
        m_new = optimizer.momentum * m.astype(jnp.float32) + g32
        p_new = p.astype(jnp.float32) - lr * m_new
        return p_new.astype(p.dtype), m_new.astype(m.dtype)

    def _apply(params_t, mom_t, grads_t, lr):
        """(params', mom') from a matching (params, mom, grads) subtree."""
        pm = jax.tree_util.tree_map(
            lambda p_, m_, g_: _upd(p_, m_, g_, lr), params_t, mom_t, grads_t)
        leaf = lambda x: isinstance(x, tuple)
        return (jax.tree_util.tree_map(lambda t: t[0], pm, is_leaf=leaf),
                jax.tree_util.tree_map(lambda t: t[1], pm, is_leaf=leaf))

    def step(params, fb, opt_state, batch, rng):
        opt_step = opt_state["step"] + 1
        lr = optimizer.lr(opt_step) if callable(optimizer.lr) else jnp.float32(optimizer.lr)

        fwd = forward_with_error(model, params, cfg, batch)
        delta_fn = dfa_delta(cfg)

        new_params = dict(params)
        new_mom = dict(opt_state["mom"])
        for spec in specs:
            tape = fwd["saved"][spec.name]
            fb_seg = fb[spec.name]
            seg_key = prng.fold_name(rng, spec.name)
            e_seg = spec.adapt_error(fwd["e_tap"]) if spec.adapt_error else fwd["e_tap"]

            def per_layer(xs, spec=spec, fb_seg=fb_seg, seg_key=seg_key,
                          extras=tape.extras, e_seg=e_seg):
                bp, mom_p, xk, idx = xs
                bmat = fb_lib.feedback_for(fb_seg, idx)
                kk = jax.random.fold_in(seg_key, idx)

                def local(p):
                    if cfg.freeze_norms:
                        p = freeze_norm_leaves(p)
                    return spec.apply(unshard_fsdp(p), xk, extras)

                (y, _aux), vjp = jax.vjp(local, bp)
                delta = delta_fn(spec, e_seg, bmat, kk, y)
                (g,) = vjp((delta.astype(y.dtype), jnp.float32(1.0)))
                return _apply(bp, mom_p, g, lr)

            xs = (params[spec.name], opt_state["mom"][spec.name], tape.inputs,
                  jnp.arange(spec.n_layers))
            new_params[spec.name], new_mom[spec.name] = jax.lax.map(per_layer, xs)

        # head (exact grads) + embed (DFA) updated out-of-loop
        new_params["head"], new_mom["head"] = _apply(
            params["head"], opt_state["mom"]["head"], fwd["g_head"], lr)
        g_embed = embed_grads(model, params, cfg, fwd, fb, rng)
        if g_embed is not None:
            new_params["embed"], new_mom["embed"] = _apply(
                params["embed"], opt_state["mom"]["embed"], g_embed, lr)

        total, _metrics = _totals(fwd)
        new_opt = {"mom": new_mom, "step": opt_step}
        return new_params, new_opt, total

    return step


def tree_cosine(a, b):
    """cos(a, b) over all leaves of two same-structure pytrees, in f32.
    0.0 for leafless trees (a parameter-free segment has no direction)."""
    f32 = lambda t: t.astype(jnp.float32)
    la = [f32(x) for x in jax.tree_util.tree_leaves(a)]
    lb = [f32(x) for x in jax.tree_util.tree_leaves(b)]
    if not la or not lb:
        return jnp.float32(0.0)
    num = sum(jnp.vdot(x, y) for x, y in zip(la, lb))
    na = jnp.sqrt(sum(jnp.vdot(x, x) for x in la))
    nb = jnp.sqrt(sum(jnp.vdot(x, x) for x in lb))
    return num / jnp.maximum(na * nb, 1e-12)


def grad_alignment(dfa_grads, bp_grads):
    """Per-subtree cosine(DFA, BP) — the 'alignment' diagnostic (the theory
    in the paper's ref [29] predicts this grows during the align phase).
    ``obs.introspect.AlignmentProbe`` samples this in-situ during fit."""
    return {name: tree_cosine(dfa_grads[name], bp_grads[name])
            for name in dfa_grads}


class DFAAlgorithm(base.Algorithm):
    """The paper's algorithm, Eq. 1."""

    name = "dfa"

    def init_extra_state(self, model, key, cfg: DFAConfig):
        return init_feedback(model, key, cfg)

    def value_and_grad(self, model, cfg: DFAConfig):
        return value_and_grad(model, cfg)


class FusedDFAAlgorithm(DFAAlgorithm):
    """Identical gradients to ``dfa``; the fused step consumes each layer's
    gradient inside the backward map (SGDM-shaped optimizers only)."""

    name = "dfa-fused"

    def fused_step(self, model, cfg: DFAConfig, optimizer):
        return make_fused_train_step(model, cfg, optimizer)


base.register(DFAAlgorithm())
base.register(FusedDFAAlgorithm())
