"""The Algorithm protocol: what a training algorithm must provide to plug
into the trainer, the launchers, and the benchmarks.

An Algorithm is a *strategy object* over the DFAModel protocol
(models/base.py): it decides how gradients are produced, while the model
decides what the forward computation is and the PhotonicBackend decides how
feedback projections execute.  The three axes — algorithm × hardware preset
× execution backend — are the paper's experiment matrix, and each is now an
independent registry (algos.register / photonics.PRESETS /
photonics.register_backend).

Contract:

* ``init_extra_state(model, key, cfg)`` — algorithm-owned state that is not
  a parameter and not optimizer state (DFA: the fixed feedback matrices).
  Must be deterministic in ``key``.  Returned pytree is threaded through
  ``value_and_grad`` unchanged and checkpointed alongside params.
* ``value_and_grad(model, cfg)`` — returns
  ``fn(params, extra, batch, rng) -> ((loss, metrics), grads)`` with
  ``grads`` matching ``params``'s structure.  Pure; jit-able.
* ``fused_step(model, cfg, optimizer)`` — optional memory-optimised
  step ``(params, extra, opt_state, batch, rng) -> (params', opt_state',
  loss)``.  The base class provides a generic compose-with-optimizer
  fallback so only algorithms with a genuinely fused path override it.

``cfg`` is the algorithm config (algos.dfa.DFAConfig for the whole DFA
family; BP ignores it).  Keeping one config type across the family lets the
trainer switch algorithms without reshaping its own config.
"""

from __future__ import annotations


class Algorithm:
    """Base class: subclasses override value_and_grad (and optionally the
    rest); instances are registered by name in repro.algos."""

    name = "base"

    def init_extra_state(self, model, key, cfg):
        """Algorithm-owned non-parameter state (default: none)."""
        del model, key, cfg
        return {}

    def value_and_grad(self, model, cfg):
        raise NotImplementedError

    def fused_step(self, model, cfg, optimizer):
        """Generic fallback: value_and_grad composed with optimizer.update.
        Algorithms with a real fused path (dfa-fused) override this."""
        vg = self.value_and_grad(model, cfg)

        def step(params, extra, opt_state, batch, rng):
            (loss, _metrics), grads = vg(params, extra, batch, rng)
            new_params, new_opt, _info = optimizer.update(grads, opt_state, params)
            return new_params, new_opt, loss

        return step


_REGISTRY: dict[str, Algorithm] = {}


def register(algo: Algorithm) -> Algorithm:
    """Register an Algorithm instance under its ``name``."""
    if not isinstance(algo, Algorithm):
        raise TypeError(f"expected an Algorithm instance, got {type(algo)!r}")
    _REGISTRY[algo.name] = algo
    return algo


def get(name: str) -> Algorithm:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_algos() -> list[str]:
    return sorted(_REGISTRY)
