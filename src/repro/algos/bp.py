"""Exact-backprop baseline under the identical harness/loss (paper §1's
comparison partner).  Registered as ``bp``."""

from __future__ import annotations

import jax

from repro.algos import base
from repro.algos import dfa as dfa_lib


def bp_value_and_grad(model, *, aux_metrics: bool = True):
    """Exact-backprop baseline under the identical harness/loss."""
    del aux_metrics

    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def fn(params, fb, batch, rng):
        del fb, rng
        (loss, metrics), grads = grad_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return (loss, metrics), grads

    return fn


class BPAlgorithm(base.Algorithm):
    name = "bp"

    def init_extra_state(self, model, key, cfg):
        """BP needs no feedback, but building the same matrices keeps the
        training-state layout identical across algorithms — checkpoints can
        be restored under a different ``algo`` and the (seed, step) RNG
        contract is unchanged from the pre-registry trainer."""
        return dfa_lib.init_feedback(model, key, cfg)

    def value_and_grad(self, model, cfg):
        del cfg
        return bp_value_and_grad(model)


base.register(BPAlgorithm())
