import os
# 512 placeholder devices for the production meshes.  The disabled pass is a
# CPU-backend artifact guard: XLA-CPU upcasts bf16 dots to f32 and LICM then
# hoists those converts out of the layer scan, materializing f32 copies of
# ALL stacked layer params/tape (+100s of GB at kimi-k2 scale).  On TPU bf16
# is MXU-native and no such converts exist, so disabling the hoist gives the
# memory profile the real machine would see.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell and record memory/cost/collective analysis.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices form the production meshes
(single-pod 16×16, multi-pod 2×16×16); every cell must ``.lower().compile()``
under its real shardings.  Outputs per-cell JSON consumed by
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch qwen3-1.7b ...] [--shape train_4k ...] [--mesh single|multi|both]
      [--out results/dryrun.json] [--hlo-dir results/hlo]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import algos, configs
from repro.algos.dfa import DFAConfig
from repro.core import photonics
from repro.dist import sharding
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.serve.decode import cache_shardings, make_prefill, make_serve_step
from repro.train.optimizer import SGDM
from repro.utils import hlo_cost as hlo_cost_lib


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


VARIANT = {"name": "baseline"}  # mutated by main() — variant is process-wide


def _make_model(arch):
    if VARIANT["name"] == "opt" and arch.make_opt is not None:
        return arch.make_opt(jnp.bfloat16)
    return arch.make_model(jnp.bfloat16)


def _dfa_config() -> DFAConfig:
    # paper-system training config: off-chip BPD noise in the feedback path
    from repro.core.feedback import FeedbackConfig

    return DFAConfig(
        photonics=photonics.preset("offchip_bpd"), backend="ref",
        feedback=FeedbackConfig(dtype=jnp.bfloat16),
        # §Perf G1: norm scales frozen in the optimised variant — the
        # (B,S,D) all-reduces that exist only to feed them are DCE'd
        freeze_norms=(VARIANT["name"] == "opt"),
    )


def build_train(arch, mesh):
    model = _make_model(arch)
    cfg = _dfa_config()
    opt = SGDM(lr=0.01, momentum=0.9)
    algo = algos.get("dfa")
    vg = algo.value_and_grad(model, cfg)
    # §Perf K3: microbatch accumulation for the 1T cell — the DFA tape,
    # error tensor, logits and MoE transients all scale with the microbatch
    # (grads/optimizer state do not), trading a k× longer step for ~k× less
    # activation memory.  (K2, fusing the update into the backward map, was
    # REFUTED: old+new param/momentum stacks stay live inside the loop.)
    microbatches = 4 if (VARIANT["name"] == "opt"
                         and arch.name == "kimi-k2-1t-a32b") else 1

    def train_step(params, fb, opt_state, batch, seed):
        rng = jax.random.PRNGKey(seed)
        if microbatches == 1:
            (loss, _metrics), grads = vg(params, fb, batch, rng)
        else:
            split = lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)

            def body(carry, xs):
                acc, lacc = carry
                micro, i = xs
                (l, _m), g = vg(params, fb, micro, jax.random.fold_in(rng, i))
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, lacc + l), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zero, jnp.float32(0.0)),
                (mbs, jnp.arange(microbatches)))
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        new_params, new_opt, _ = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    shape = configs.SHAPES["train_4k"]
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    fb_s = jax.eval_shape(
        lambda k: algo.init_extra_state(model, k, cfg), jax.random.PRNGKey(0)
    )
    opt_s = jax.eval_shape(opt.init, params_s)
    batch = dict(configs.token_specs(shape.global_batch, shape.seq_len))
    batch.update(arch.input_extras(shape.global_batch, "train"))
    seed = jax.ShapeDtypeStruct((), jnp.int32)

    params_sh = sharding.make_param_shardings(mesh, params_s)
    fb_sh = sharding.make_param_shardings(mesh, fb_s, sharding.FEEDBACK_RULES)
    opt_sh = sharding.make_param_shardings(mesh, opt_s)
    batch_sh = sharding.make_batch_shardings(mesh, batch)
    rep = sharding.replicated(mesh)

    fn = jax.jit(
        train_step,
        in_shardings=(params_sh, fb_sh, opt_sh, batch_sh, rep),
        out_shardings=(params_sh, opt_sh, rep),
        donate_argnums=(0, 2),
    )
    args = (params_s, fb_s, opt_s, batch, seed)
    extra = {"params": params_s, "model": model,
             "tokens": shape.global_batch * shape.seq_len, "kind": "train"}
    return fn, args, extra


def build_prefill(arch, mesh):
    model = _make_model(arch)
    shape = configs.SHAPES["prefill_32k"]
    prefill = make_prefill(model)
    batch = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)}
    batch.update(arch.input_extras(shape.global_batch, "prefill"))
    if arch.name == "whisper-small":
        # decoder prefill over target tokens; encoder consumes frame stubs
        batch["labels"] = batch["tokens"]  # unused by prefill, spec only
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = sharding.make_param_shardings(mesh, params_s)
    batch_sh = sharding.make_batch_shardings(mesh, batch)
    fn = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
    extra = {"params": params_s, "model": model,
             "tokens": shape.global_batch * shape.seq_len, "kind": "prefill"}
    return fn, (params_s, batch), extra


def build_decode(arch, mesh, shape_name):
    model = _make_model(arch)
    shape = configs.SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    caches_s = jax.eval_shape(lambda: model.init_caches(b, s))
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cache_len = jax.ShapeDtypeStruct((b,), jnp.int32)

    params_sh = sharding.make_param_shardings(mesh, params_s)
    caches_sh = cache_shardings(mesh, caches_s)
    batch_sh = sharding.make_batch_shardings(mesh, {"t": token})["t"]
    len_sh = sharding.make_batch_shardings(mesh, {"t": cache_len})["t"]
    rep = sharding.replicated(mesh)

    whisper = arch.name == "whisper-small"
    step = make_serve_step(model, whisper_enc=whisper)
    if whisper:
        enc = jax.ShapeDtypeStruct((b, model.cfg.n_frames, model.cfg.d_model), jnp.bfloat16)
        enc_sh = sharding.make_batch_shardings(mesh, {"t": enc})["t"]
        fn = jax.jit(step,
                     in_shardings=(params_sh, batch_sh, caches_sh, len_sh, enc_sh),
                     out_shardings=(batch_sh, rep, caches_sh),
                     donate_argnums=(2,))
        args = (params_s, token, caches_s, cache_len, enc)
    else:
        fn = jax.jit(step,
                     in_shardings=(params_sh, batch_sh, caches_sh, len_sh),
                     out_shardings=(batch_sh, rep, caches_sh),
                     donate_argnums=(2,))
        args = (params_s, token, caches_s, cache_len)
    extra = {"params": params_s, "model": model, "tokens": b, "kind": "decode"}
    return fn, args, extra


def run_cell(arch_name: str, shape_name: str, mesh_kind: str, hlo_dir=None) -> dict:
    arch = configs.get(arch_name)
    shape = configs.SHAPES[shape_name]
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "variant": VARIANT["name"]}

    if shape_name == "long_500k" and not arch.sub_quadratic:
        rec["status"] = "skip"
        rec["reason"] = ("full-attention arch: 512k dense-KV decode is "
                        "infeasible by design (DESIGN.md §6)")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.monotonic()  # duration measurement: immune to wall-clock steps
    try:
        with sharding.use_mesh(mesh):
            if shape.kind == "train":
                fn, args, extra = build_train(arch, mesh)
            elif shape.kind == "prefill":
                fn, args, extra = build_prefill(arch, mesh)
            else:
                fn, args, extra = build_decode(arch, mesh, shape_name)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
    except Exception as ex:
        rec["status"] = "error"
        rec["reason"] = f"{type(ex).__name__}: {ex}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["seconds"] = round(time.monotonic() - t0, 1)
        return rec

    rec["seconds"] = round(time.monotonic() - t0, 1)
    rec["status"] = "ok"
    rec["chips"] = int(mesh.devices.size)
    rec["tokens"] = extra["tokens"]
    rec["n_params"] = analysis.tree_param_count(extra["params"])
    rec["n_params_active"] = analysis.active_param_count(extra["params"], extra["model"])
    rec["param_bytes"] = analysis.tree_param_bytes(extra["params"])
    rec["cost"] = analysis.cost_analysis_dict(compiled)
    rec["memory"] = analysis.memory_analysis_dict(compiled)
    try:
        text = compiled.as_text()
        # trip-count-aware per-device accounting (XLA's cost_analysis counts
        # loop bodies once — see utils/hlo_cost.py)
        cost = hlo_cost_lib.analyze(text)
        rec["hlo_cost"] = cost.as_dict()
        rec["collectives"] = {
            "total_bytes": cost.collective_total,
            "total_count": int(sum(cost.coll_count.values())),
            "bytes_by_kind": dict(cost.coll_bytes),
            "count_by_kind": dict(cost.coll_count),
        }
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            with open(os.path.join(
                    hlo_dir, f"{arch_name}__{shape_name}__{mesh_kind}.hlo.txt"), "w") as f:
                f.write(text)
        del text
    except Exception as ex:  # HLO text can be unavailable on some backends
        rec["collectives"] = {"error": str(ex)[:200]}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=list(configs.ASSIGNED))
    ap.add_argument("--shape", nargs="*", default=list(configs.SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--variant", choices=["baseline", "opt"], default="baseline")
    args = ap.parse_args()
    VARIANT["name"] = args.variant

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    for arch_name in args.arch:
        for shape_name in args.shape:
            for mesh_kind in meshes:
                key = (arch_name, shape_name, mesh_kind)
                if key in done:
                    print(f"[skip-done] {key}", flush=True)
                    continue
                print(f"[cell] {arch_name} × {shape_name} × {mesh_kind} …", flush=True)
                rec = run_cell(arch_name, shape_name, mesh_kind, args.hlo_dir)
                status = rec["status"]
                info = rec.get("reason", "")[:120] if status != "ok" else (
                    f"{rec.get('seconds', 0)}s "
                    f"flops={rec.get('cost', {}).get('flops', 0):.3g} "
                    f"coll={rec.get('collectives', {}).get('total_bytes', 0):.3g}B")
                print(f"  -> {status} {info}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
