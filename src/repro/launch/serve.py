"""Serving launcher: continuous batching with prefill/decode split on the
digital or photonic (emulated MRR) forward.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --requests 8 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --backend emu --arrival-rate 4 --bench-json serve-out

``--smoke`` (default on) builds the shrunk smoke config; ``--no-smoke``
serves the full-size model.  ``--arrival-rate`` switches from
serve-everything-at-once to Poisson open-loop arrivals, reporting
measured p50/p99 TTFT and end-to-end latency; ``--bench-json DIR``
writes the measurements as ``BENCH_serve_live.json``.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import api, configs
from repro.serve import Request


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ASSIGNED))
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction, default=True,
                    help="shrunk smoke config (default); --no-smoke for full size")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "ref", "emu", "pallas"],
                    help="forward execution: auto = exact digital; emu runs "
                         "projections through the MRR device emulation")
    ap.add_argument("--hardware", default=None,
                    help="photonics preset for a photonic backend "
                         "(default: digital for auto, emu_ideal for emu)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrivals at this rate (req/s); default: "
                         "submit all requests up front")
    ap.add_argument("--bench-json", default=None, metavar="DIR",
                    help="write BENCH_serve_live.json with the measured "
                         "latency distribution to DIR")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of the "
                         "request lifecycles and engine ticks to PATH")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append obs metrics rows (JSONL) to PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    photonic = args.backend not in ("auto",)
    hardware = args.hardware or ("emu_ideal" if photonic else "digital")
    # bp session: serving is forward-only — the facade still owns model
    # construction and the photonics/backend pairing
    session = api.build_session(arch=args.arch, smoke=args.smoke, algo="bp",
                                hardware=hardware, backend=args.backend,
                                seed=args.seed)
    observer = None
    if args.trace_out or args.metrics_out:
        observer = session.observe(metrics_path=args.metrics_out,
                                   trace_path=args.trace_out)
    model = session.model
    params = model.init(jax.random.PRNGKey(args.seed))
    vocab = model.cfg.vocab_size

    eng = session.engine(params, batch_slots=args.slots, max_len=args.max_len,
                         prefill_chunk=args.prefill_chunk, seed=args.seed)
    reqs = [Request(prompt=[(7 * i + 3 + 13 * j) % vocab
                            for j in range(max(1, args.prompt_len))],
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.monotonic()  # duration: monotonic, immune to wall-clock steps
    if args.arrival_rate:
        rng = np.random.default_rng(args.seed)
        arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                             size=len(reqs)))
        done, ticks = eng.run_arrivals(reqs, arrivals.tolist())
    else:
        done, ticks = eng.run(reqs)
    dt = time.monotonic() - t0

    total_tokens = sum(len(r.out) for r in done)
    ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
    lats = [r.latency_s for r in done if r.latency_s is not None]
    print(f"[serve] {len(done)} requests, {total_tokens} tokens, "
          f"{ticks} ticks, {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s) "
          f"backend={args.backend}")
    print(f"[serve] ttft p50 {_pct(ttfts, 50) * 1e3:.1f}ms "
          f"p99 {_pct(ttfts, 99) * 1e3:.1f}ms | latency "
          f"p50 {_pct(lats, 50) * 1e3:.1f}ms p99 {_pct(lats, 99) * 1e3:.1f}ms")
    print(f"[serve] engine stats: {eng.stats}")
    for r in done[:4]:
        print(f"  prompt={r.prompt[:4]}{'...' if len(r.prompt) > 4 else ''} "
              f"-> {r.out}")

    if args.bench_json:
        from repro.bench import write_bench

        metrics = {
            "requests": float(len(done)),
            "tokens": float(total_tokens),
            "wall_s": dt,
            "tok_per_s": total_tokens / max(dt, 1e-9),
            "ttft_p50_ms": _pct(ttfts, 50) * 1e3,
            "ttft_p99_ms": _pct(ttfts, 99) * 1e3,
            "latency_p50_ms": _pct(lats, 50) * 1e3,
            "latency_p99_ms": _pct(lats, 99) * 1e3,
            "prefill_steps": float(eng.stats["prefill_steps"]),
            "decode_steps": float(eng.stats["decode_steps"]),
        }
        meta = {"arch": args.arch, "backend": args.backend,
                "hardware": hardware, "smoke": args.smoke,
                "slots": args.slots, "prefill_chunk": args.prefill_chunk,
                "arrival_rate": args.arrival_rate or 0.0}
        path = write_bench("serve_live", metrics, meta, args.bench_json)
        print(f"[serve] wrote {path}")

    if observer is not None:
        trace_path = observer.close()
        if trace_path:
            print(f"[obs] wrote trace {trace_path}")
        if args.metrics_out:
            print(f"[obs] wrote metrics {args.metrics_out}")


if __name__ == "__main__":
    main()
