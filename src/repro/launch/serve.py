"""Serving launcher: batched greedy generation with the continuous-batching
engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import api, configs
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ASSIGNED))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # bp/digital session: serving is forward-only — the facade still owns
    # model construction so arch plugins flow through one entry point
    session = api.build_session(arch=args.arch, smoke=args.smoke, algo="bp",
                                hardware="digital", seed=args.seed)
    model = session.model
    params = model.init(jax.random.PRNGKey(args.seed))
    vocab = model.cfg.vocab_size

    eng = Engine(model, params, batch_slots=args.slots, max_len=args.max_len)
    reqs = [Request(prompt=[(7 * i + 3) % vocab, (11 * i + 5) % vocab],
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done, ticks = eng.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens, "
          f"{ticks} ticks, {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    for r in done[:4]:
        print(f"  prompt={r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
