"""Production mesh definitions.

A *function*, not a module-level constant — importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips (v5e pod);
multi-pod: 2×16×16 = 512 chips with a leading "pod" axis (DCI-connected).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(n_devices: int | None = None, model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    devs = jax.devices() if n_devices is None else jax.devices()[:n_devices]
    n = len(devs)
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"), devices=devs[: data * model_axis])


def make_data_mesh(n_devices: int | None = None):
    """Pure data-parallel mesh: every local device on the ``data`` axis and a
    size-1 ``model`` axis so the dist.sharding placement rules still resolve.

    This is the mesh the Trainer activates for data-parallel ``fit``:
    parameters are replicated, only the batch dim is split, and XLA's SPMD
    partitioner inserts the mean all-reduce over per-shard gradients."""
    devs = jax.devices() if n_devices is None else jax.devices()[:n_devices]
    return jax.make_mesh((len(devs), 1), ("data", "model"), devices=devs)
