"""Training launcher — a thin CLI over ``repro.api.build_session``.

Small-scale (this container): runs real steps on the host devices.

  PYTHONPATH=src python -m repro.launch.train --arch mnist_mlp \
      --steps 500 --preset offchip_bpd --ckpt-dir runs/mlp

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 100 --algo dfa

``--algo`` accepts any name registered in ``repro.algos`` (bp, dfa,
dfa-fused, dfa-layerwise, plus anything a plugin registers); ``--preset``
is the photonic hardware model (including the device-level ``emu_*``
presets) and ``--backend`` the execution path (ref | pallas | emu | auto).
``--recal-every`` sets the in-situ recalibration cadence for drifting
hardware under the emu backend; ``--autotune`` (optionally with
``--power-budget-w``) lets the ``repro.sim`` schedule autotuner pick the
fastest (n_buses, tiling, f_s) for the model's DFA backward before
training starts.  Adding an algorithm or backend is a registration —
this launcher picks it up without edits.

Production-scale posture: the same step function is what launch/dryrun.py
lowers against the (pod, data, model) mesh; on a real multi-host cluster
this entrypoint would be invoked once per host under jax.distributed with
the dry-run's shardings (see DESIGN.md §5).
"""

from __future__ import annotations

import argparse

from repro import algos, api, configs
from repro.core import photonics
from repro.data import mnist, pipeline, tokens
from repro.train import SGDM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (full configs are dry-run-only on CPU)")
    ap.add_argument("--algo", choices=algos.list_algos(), default="dfa")
    ap.add_argument("--preset", choices=list(photonics.PRESETS), default="ideal")
    ap.add_argument("--backend", choices=["auto", *photonics.BACKENDS], default="auto")
    ap.add_argument("--error-compress", choices=["none", "ternary", "int8"], default="none")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log", default=None)
    ap.add_argument("--data-parallel", choices=["auto", "on", "off"],
                    default="auto",
                    help="shard the batch dim across all local devices "
                         "(auto: whenever >1 device exists)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host->device input pipeline depth (0 disables)")
    ap.add_argument("--recal-every", type=int, default=None,
                    help="in-situ recalibration cadence (steps) for stateful "
                         "emu hardware; default: 500 when the device drifts")
    ap.add_argument("--n-buses", type=int, default=None,
                    help="parallel WDM buses (multi-wavelength scale-out); "
                         "default: the preset's bus count (1)")
    ap.add_argument("--autotune", action="store_true",
                    help="repro.sim schedule autotuning: pick the fastest "
                         "(n_buses, tiling, f_s) for this model's DFA "
                         "backward under --power-budget-w")
    ap.add_argument("--power-budget-w", type=float, default=None,
                    help="wall-plug power budget [W] for --autotune "
                         "(default: unconstrained)")
    ap.add_argument("--bench-json", default=None, metavar="DIR",
                    help="measure throughput and write "
                         "BENCH_train_throughput.json into DIR")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of the run "
                         "(step spans, recal events, hwmon gauges) to PATH")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append obs metrics rows (JSONL) to PATH; render "
                         "with python -m repro.obs.summarize")
    ap.add_argument("--probe-every", type=int, default=None, metavar="N",
                    help="in-situ diagnostics cadence: every N steps log "
                         "DFA-vs-BP alignment per layer (and the emu "
                         "noise budget) as observer rows — see the "
                         "alignment/noise-budget tables in summarize")
    args = ap.parse_args()
    if args.power_budget_w is not None and not args.autotune:
        ap.error("--power-budget-w only steers --autotune")

    session = api.build_session(
        arch=args.arch,
        smoke=(args.smoke or args.arch != "mnist_mlp"),
        algo=args.algo,
        hardware=args.preset,
        backend=args.backend,
        error_compress=args.error_compress,
        optimizer=SGDM(lr=args.lr, momentum=args.momentum),
        seed=args.seed, ckpt_dir=args.ckpt_dir, log_path=args.log,
        log_every=max(1, args.steps // 20),
        data_parallel={"auto": "auto", "on": True, "off": False}[args.data_parallel],
        prefetch=args.prefetch,
        recalibrate_every=args.recal_every,
        n_buses=args.n_buses,
        schedule="auto" if args.autotune else None,
        power_budget_w=args.power_budget_w,
        schedule_batch=args.batch if args.autotune else None,
        probe_every=args.probe_every,
    )
    model = session.model
    observer = None
    if args.trace_out or args.metrics_out:
        observer = session.observe(metrics_path=args.metrics_out,
                                   trace_path=args.trace_out)
    if session.mesh is not None:
        print(f"[dist] data-parallel over {session.mesh.devices.size} devices")
    if session.schedule is not None:
        print(f"[sim] autotuned schedule: {session.schedule.describe()}")

    timer = None
    if args.bench_json is not None:
        from repro.bench import StepTimer, clamped_warmup

        timer = StepTimer(warmup=clamped_warmup(args.steps, 4))

    if args.arch == "mnist_mlp":
        data = mnist.load(seed=args.seed)
        print(f"[data] source={data['source']}")
        xtr, ytr = data["train"]
        xte, yte = data["test"]
        if xtr.shape[1] != model.in_dim:  # --smoke shrinks in_dim
            xtr, xte = xtr[:, :model.in_dim], xte[:, :model.in_dim]
        pipe = pipeline.ArrayClassification(xtr, ytr, args.batch, args.seed)
        state, _ = session.fit(pipe.batch, total_steps=args.steps, timer=timer)
        _report_bench(args, session, state, pipe.batch(0), timer)
        ev = session.evaluate(state, pipe.eval_batches(xte, yte, 256))
        print(f"[eval] {ev}")
    else:
        vocab = model.cfg.vocab_size
        gen = tokens.MarkovTokens(vocab, args.seq, args.batch, args.seed)

        def batch_fn(step):
            b = gen.batch(step)
            if args.arch == "whisper-small":
                import numpy as np

                rng = np.random.default_rng((args.seed, step, 7))
                b["frames"] = rng.normal(size=(args.batch, model.cfg.n_frames,
                                               model.cfg.d_model)).astype("float32") * 0.1
            if args.arch == "internvl2-2b":
                import numpy as np

                rng = np.random.default_rng((args.seed, step, 8))
                v = model.cfg.vision
                b["patch_embeds"] = rng.normal(size=(args.batch, v.n_patches,
                                                     v.d_vision)).astype("float32") * 0.1
            return b

        state, metrics = session.fit(batch_fn, total_steps=args.steps, timer=timer)
        _report_bench(args, session, state, batch_fn(0), timer)
        print(f"[final] {({k: float(v) for k, v in metrics.items()})}")

    if observer is not None:
        trace_path = observer.close()
        if trace_path:
            print(f"[obs] wrote trace {trace_path}")
        if args.metrics_out:
            print(f"[obs] wrote metrics {args.metrics_out}")
        if observer.alerts:
            print(f"[obs] {len(observer.alerts)} alert(s) "
                  "(hwmon + anomaly); first: "
                  f"{observer.alerts[0].message}")


def _report_bench(args, session, state, batch, timer):
    if timer is None:
        return
    if timer.recorded_steps == 0:
        # e.g. a checkpoint-restored fit that had nothing left to run
        print("[bench] no steps executed — skipping throughput report",
              flush=True)
        return
    from repro.bench import report_throughput

    report_throughput(
        session, state, batch, timer,
        meta={"arch": args.arch, "algo": args.algo, "preset": args.preset,
              "batch": args.batch, "steps": args.steps},
        out_dir=args.bench_json)


if __name__ == "__main__":
    main()
