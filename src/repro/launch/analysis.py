"""Shared analysis helpers for the dry-run and the roofline benchmark."""

from __future__ import annotations

import numpy as np

import jax

# TPU v5e hardware constants (per chip) — the assignment's roofline basis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def tree_param_count(tree) -> int:
    return int(sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)))


def tree_param_bytes(tree) -> int:
    return int(sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
                   for x in jax.tree_util.tree_leaves(tree)))


def active_param_count(params_tree, model) -> int:
    """MoE-aware active parameter count (routed experts scaled by top_k/E)."""
    from repro.utils.tree import named_leaves

    moe = getattr(getattr(model, "cfg", None), "moe", None)
    total = 0.0
    for path, leaf in named_leaves(params_tree):
        n = float(np.prod(leaf.shape))
        if moe is not None and "experts/" in path:
            n *= moe.top_k / moe.n_experts
        total += n
    return int(total)


def model_flops_reference(n_params_active: int, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS yardstick: 6·N·D for training, 2·N·D for fwd-only.

    (For DFA the backward differs structurally from BP — the ratio
    HLO_FLOPs / MODEL_FLOPS in the report surfaces exactly that.)"""
    if kind == "train":
        return 6.0 * n_params_active * n_tokens
    return 2.0 * n_params_active * n_tokens


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float, chips: int) -> dict:
    t_compute = flops / (chips * PEAK_FLOPS_BF16)
    t_memory = hbm_bytes / (chips * HBM_BW)
    t_coll = coll_bytes / (chips * ICI_BW)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        # roofline fraction: how much of the bound is useful compute
        "compute_fraction": (t_compute / bound) if bound > 0 else 0.0,
    }


def cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_hbm_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out
