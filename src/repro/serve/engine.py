"""Batched serving engine: continuous-batching-lite request loop.

Holds a fixed pool of batch slots with per-slot cache length; requests are
admitted into free slots, prompts are consumed token-by-token (teacher
forcing into the cache), then generation proceeds greedily until EOS or
max_new.  Single jit'd decode_step per tick for the whole batch — the
serving analogue of the paper's "single operational cycle" claim.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.decode import make_serve_step


@dataclasses.dataclass
class Request:
    prompt: list
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, *, batch_slots: int = 8, max_len: int = 512,
                 eos_id: int | None = None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.caches = model.init_caches(batch_slots, max_len)
        self.cache_len = jnp.zeros((batch_slots,), jnp.int32)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self._step = jax.jit(make_serve_step(model))
        self._requests: list[Request | None] = [None] * batch_slots
        self._pending: list[Request] = []
        # per-slot queue of forced (prompt) tokens remaining
        self._forced: list[list] = [[] for _ in range(batch_slots)]

    def submit(self, req: Request):
        self._pending.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self._requests[i] is None and self._pending:
                req = self._pending.pop(0)
                self._requests[i] = req
                self._forced[i] = list(req.prompt[1:])
                self.tokens = self.tokens.at[i, 0].set(req.prompt[0])
                self.cache_len = self.cache_len.at[i].set(0)
                # reset this slot's cache (zeros are fine: length mask guards)
                self.caches = jax.tree_util.tree_map(
                    lambda c: c.at[:, i].set(0), self.caches)

    def tick(self):
        """One synchronous decode step across all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self._requests) if r is not None]
        if not active:
            return False
        nxt, logits, self.caches = self._step(
            self.params, self.tokens, self.caches, self.cache_len)
        del logits
        nxt = np.asarray(nxt)
        self.cache_len = self.cache_len + jnp.array(
            [1 if self._requests[i] is not None else 0 for i in range(self.slots)],
            jnp.int32)
        new_tokens = np.asarray(self.tokens).copy()
        for i in active:
            req = self._requests[i]
            if self._forced[i]:
                new_tokens[i, 0] = self._forced[i].pop(0)  # teacher-force prompt
                continue
            tok = int(nxt[i, 0])
            req.out.append(tok)
            new_tokens[i, 0] = tok
            done = (self.eos is not None and tok == self.eos) or len(req.out) >= req.max_new
            if done or int(self.cache_len[i]) >= self.max_len - 1:
                req.done = True
                self._requests[i] = None
        self.tokens = jnp.asarray(new_tokens)
        return True

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        for r in requests:
            self.submit(r)
        ticks = 0
        while (self._pending or any(r is not None for r in self._requests)) and ticks < max_ticks:
            if not self.tick():
                break
            ticks += 1
        return requests, ticks
