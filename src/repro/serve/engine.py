"""Continuous-batching serving engine with a prefill/decode split.

A fixed pool of ``batch_slots`` KV-cache slots is fed from an admission
queue.  Each request walks QUEUED → PREFILL → DECODE → DONE:

* **prefill** — the prompt is consumed in chunks of ``prefill_chunk``
  tokens, each chunk one batched forward that scatters straight into the
  slot's cache (⌈S/chunk⌉ forwards for a length-S prompt, never S decode
  ticks).  The logits after the last prompt token yield the first output
  token, stamping ``first_token_s``.
* **decode** — one jit'd greedy step per tick across all decoding slots.
  Finished/empty slots are masked out of the cache update and their
  emitted token is discarded, so a dead slot costs no state corruption
  and no stats skew.

Forward projections optionally run through a photonic backend
(``backend="ref" | "emu" | "pallas"``): every ``forward_matmul`` inside
the jit'd steps is routed through ``photonics.forward_execution``, so
inference inherits MRR drift / crosstalk / quantisation when the
emulated hardware backend is selected.  ``backend=None`` keeps the exact
digital forward — bit-identical to the seed engine.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_lib
from repro.lint import runtime as lint_runtime
from repro.serve.decode import make_prefill_step, make_serve_step, select_slots

QUEUED = "QUEUED"
PREFILL = "PREFILL"
DECODE = "DECODE"
DONE = "DONE"


@dataclasses.dataclass
class Request:
    prompt: list
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    state: str = QUEUED
    submit_s: float | None = None
    first_token_s: float | None = None
    finish_s: float | None = None

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def ttft_s(self) -> float | None:
        if self.submit_s is None or self.first_token_s is None:
            return None
        return self.first_token_s - self.submit_s

    @property
    def latency_s(self) -> float | None:
        if self.submit_s is None or self.finish_s is None:
            return None
        return self.finish_s - self.submit_s


@contextlib.contextmanager
def _maybe_drift(hw):
    if hw is None:
        yield
    else:
        from repro.hardware import drift

        with drift.use_state(hw):
            yield


class Engine:
    """Continuous-batching engine over ``model.decode_step`` caches.

    Parameters
    ----------
    backend : None | "ref" | "emu" | "pallas"
        ``None`` (or ``"auto"``) keeps the exact digital forward; a named
        backend routes every forward projection through
        ``photonics.forward_execution`` with ``photonics`` as the config.
    photonics : PhotonicConfig | None
        Required knobs for a photonic backend; defaults to the "digital"
        preset flipped on.  When the backend emulates stateful hardware
        and no ``mrr`` model is attached, an ``MRRConfig()`` is attached
        (mirroring ``api.build_session``).
    hw_state : drift-state pytree | None
        In-situ MRR drift/calibration state threaded through the jit'd
        steps; defaults to pristine state for stateful backends.
    observer : repro.obs.Observer | None
        When given (or ``True``), every request gets one async trace
        track (QUEUED → PREFILL → DECODE → DONE with a FIRST_TOKEN
        instant), each prefill/decode tick a span, and slot occupancy /
        queue depth a counter series.  ``None`` resolves to the shared
        null observer — the engine pays a few attribute lookups.
    """

    def __init__(self, model, params, *, batch_slots: int = 8, max_len: int = 512,
                 eos_id: int | None = None, prefill_chunk: int = 16,
                 backend: str | None = None, photonics=None, hw_state=None,
                 seed: int = 0, observer=None, debug_checks: bool = False):
        self.model = model
        self.params = params
        self.observer = obs_lib.resolve(observer)
        self._req_seq = 0
        self._track_ids: dict[int, int] = {}  # id(request) -> async track id
        if self.observer.enabled:
            from repro.obs.trace import HOST_PID, HOST_TID

            self.observer.trace.name_thread(HOST_PID, HOST_TID, "serve.Engine")
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.prefill_chunk = max(1, int(prefill_chunk))
        self.caches = model.init_caches(batch_slots, max_len)
        self._cache_len = np.zeros((batch_slots,), np.int64)
        self._tokens = np.zeros((batch_slots, 1), np.int32)
        self._requests: list[Request | None] = [None] * batch_slots
        self._prompt_pos = [0] * batch_slots
        self._pending: list[Request] = []
        self._tick_no = 0
        self.stats = {"ticks": 0, "prefill_steps": 0, "prefill_tokens": 0,
                      "decode_steps": 0, "decode_tokens": 0}

        self._photonic = backend not in (None, "auto")
        self.hw_state = None
        self._key = None
        if self._photonic:
            from repro.core import photonics as ph

            cfg = photonics if photonics is not None else dataclasses.replace(
                ph.PRESETS["digital"], enabled=True)
            if not cfg.enabled:
                cfg = dataclasses.replace(cfg, enabled=True)
            bk = ph.get_backend(backend)
            if getattr(bk, "stateful_hardware", False) and cfg.mrr is None:
                from repro.hardware.mrr import MRRConfig

                cfg = dataclasses.replace(cfg, mrr=MRRConfig())
            self.photonics = cfg
            if cfg.mrr is not None and cfg.mrr.stateful:
                from repro.hardware import drift

                self.hw_state = hw_state if hw_state is not None else drift.init_state(cfg)
            self._key = jax.random.PRNGKey(seed)
        else:
            self.photonics = None

        prefill_step = make_prefill_step(model)
        serve_step = make_serve_step(model)
        pcfg, bname = self.photonics, backend

        def prefill_fn(params, tokens, n_valid, caches, cache_len, key, hw):
            def run():
                return prefill_step(params, tokens, n_valid, caches, cache_len)

            if not self._photonic:
                return run()
            with _maybe_drift(hw):
                from repro.core.photonics import forward_execution

                with forward_execution(pcfg, bname, key):
                    return run()

        def decode_fn(params, token, caches, cache_len, active, key, hw):
            def run():
                return serve_step(params, token, caches, cache_len)

            if self._photonic:
                with _maybe_drift(hw):
                    from repro.core.photonics import forward_execution

                    with forward_execution(pcfg, bname, key):
                        nxt, logits, upd = run()
            else:
                nxt, logits, upd = run()
            new_caches = select_slots(active, upd, caches)
            nxt = jnp.where(active[:, None], nxt, token)
            return nxt, logits[:, -1, :], new_caches

        self.debug_checks = debug_checks
        self._sentinels: dict = {}
        if debug_checks:
            # checkified twins + recompile sentinels: prefill chunks and the
            # decode batch are fixed-shape, so steady serving never retraces
            pf_body, s_pf = lint_runtime.instrument(prefill_fn, "Engine.prefill")
            dc_body, s_dc = lint_runtime.instrument(decode_fn, "Engine.decode")
            self._prefill = jax.jit(pf_body)
            self._decode = jax.jit(dc_body)
            self._sentinels = {"prefill": s_pf, "decode": s_dc}
        else:
            self._prefill = jax.jit(prefill_fn)
            self._decode = jax.jit(decode_fn)
        # seed-era alias used by older callers/tests
        self._step = jax.jit(serve_step)

    def _run(self, fn, *args):
        """Dispatch one jitted phase, unwrapping checkify when debugging."""
        if self.debug_checks:
            err, out = fn(*args)
            err.throw()
            return out
        return fn(*args)

    # ------------------------------------------------------------------ admin
    @property
    def cache_len(self):
        return jnp.asarray(self._cache_len.astype(np.int32))

    @property
    def tokens(self):
        return jnp.asarray(self._tokens)

    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: a request must carry >= 1 prompt token")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens does not fit max_len={self.max_len}")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new}")
        req.state = QUEUED
        req.submit_s = time.monotonic()
        self._pending.append(req)
        if self.observer.enabled:
            rid = self._req_seq
            self._req_seq += 1
            self._track_ids[id(req)] = rid
            tr = self.observer.trace
            tr.async_begin(f"request-{rid}", rid, cat="serve",
                           prompt_len=len(req.prompt), max_new=req.max_new)
            tr.async_begin(QUEUED, rid, cat="serve")

    def _admit(self):
        for i in range(self.slots):
            if self._requests[i] is None and self._pending:
                req = self._pending.pop(0)
                req.state = PREFILL
                if self.observer.enabled:
                    rid = self._track_ids.get(id(req))
                    if rid is not None:
                        tr = self.observer.trace
                        tr.async_end(QUEUED, rid, cat="serve")
                        tr.async_begin(PREFILL, rid, cat="serve", slot=i)
                self._requests[i] = req
                self._prompt_pos[i] = 0
                self._cache_len[i] = 0
                self._tokens[i, 0] = 0
                # reset this slot's cache (zeros are fine: length mask guards)
                self.caches = jax.tree_util.tree_map(
                    lambda c: c.at[:, i].set(0), self.caches)

    def _finish(self, i: int):
        req = self._requests[i]
        if self.observer.enabled:
            rid = self._track_ids.pop(id(req), None)
            if rid is not None:
                tr = self.observer.trace
                tr.async_end(req.state, rid, cat="serve")
                tr.async_end(f"request-{rid}", rid, cat="serve",
                             new_tokens=len(req.out))
        req.state = DONE
        req.finish_s = time.monotonic()
        self._requests[i] = None

    def _next_key(self):
        if self._key is None:
            return None
        self._tick_no += 1
        return jax.random.fold_in(self._key, self._tick_no)

    # ------------------------------------------------------------------ phases
    def _prefill_tick(self):
        slots = [i for i, r in enumerate(self._requests)
                 if r is not None and r.state == PREFILL]
        if not slots:
            return False
        c = self.prefill_chunk
        chunk = np.zeros((self.slots, c), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        for i in slots:
            req = self._requests[i]
            pos = self._prompt_pos[i]
            take = min(c, len(req.prompt) - pos)
            chunk[i, :take] = req.prompt[pos:pos + take]
            n_valid[i] = take
        with self.observer.span("prefill_tick", cat="serve", slots=len(slots),
                                tokens=int(n_valid.sum())):
            last, self.caches, _ = self._run(
                self._prefill,
                self.params, jnp.asarray(chunk), jnp.asarray(n_valid),
                self.caches,
                jnp.asarray(self._cache_len.astype(np.int32)),
                self._next_key(), self.hw_state)
        self.stats["prefill_steps"] += 1
        self.stats["prefill_tokens"] += int(n_valid.sum())
        self._cache_len[slots] += n_valid[slots]
        completed = [i for i in slots
                     if self._prompt_pos[i] + int(n_valid[i]) == len(self._requests[i].prompt)]
        for i in slots:
            self._prompt_pos[i] += int(n_valid[i])
        if completed:
            # intentional sync: finished prompts must surface their first
            # token to the host scheduler this tick
            first = np.asarray(jnp.argmax(last, axis=-1))  # lint: disable=RL002
            now = time.monotonic()
            for i in completed:
                req = self._requests[i]
                tok = int(first[i])
                req.out.append(tok)
                req.first_token_s = now
                req.state = DECODE
                if self.observer.enabled:
                    rid = self._track_ids.get(id(req))
                    if rid is not None:
                        tr = self.observer.trace
                        tr.async_end(PREFILL, rid, cat="serve")
                        tr.async_instant("FIRST_TOKEN", rid, cat="serve",
                                         token=tok)
                        tr.async_begin(DECODE, rid, cat="serve")
                self._tokens[i, 0] = tok
                if ((self.eos is not None and tok == self.eos)
                        or len(req.out) >= req.max_new
                        or self._cache_len[i] >= self.max_len):
                    self._finish(i)
        return True

    def _decode_tick(self):
        slots = [i for i, r in enumerate(self._requests)
                 if r is not None and r.state == DECODE]
        if not slots:
            return False
        active = np.zeros((self.slots,), bool)
        active[slots] = True
        with self.observer.span("decode_tick", cat="serve", slots=len(slots)):
            nxt, _, self.caches = self._run(
                self._decode,
                self.params, jnp.asarray(self._tokens), self.caches,
                jnp.asarray(self._cache_len.astype(np.int32)),
                jnp.asarray(active),
                self._next_key(), self.hw_state)
        # intentional sync: sampled tokens feed the host-side streams/stop
        # logic; one transfer covers the whole decode batch
        nxt = np.asarray(nxt)  # lint: disable=RL002
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += len(slots)
        self._cache_len[slots] += 1
        for i in slots:
            req = self._requests[i]
            tok = int(nxt[i, 0])
            req.out.append(tok)
            self._tokens[i, 0] = tok
            if ((self.eos is not None and tok == self.eos)
                    or len(req.out) >= req.max_new
                    or self._cache_len[i] >= self.max_len):
                self._finish(i)
        return True

    # ------------------------------------------------------------------ loop
    def tick(self):
        """One engine step: admit, one chunked-prefill forward over all
        prefilling slots, one batched decode step over all decoding slots."""
        self._admit()
        did_prefill = self._prefill_tick()
        did_decode = self._decode_tick()
        if self.observer.enabled:
            self.observer.counter("engine", {
                "active_slots": sum(r is not None for r in self._requests),
                "queued": len(self._pending)})
        if did_prefill or did_decode:
            self.stats["ticks"] += 1
            return True
        return False

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        for r in requests:
            self.submit(r)
        ticks = 0
        try:
            while (self._pending or any(r is not None for r in self._requests)) and ticks < max_ticks:
                if not self.tick():
                    break
                ticks += 1
        finally:
            # interrupted or not, buffered observer JSONL reaches disk
            self.observer.flush()
        return requests, ticks

    def run_arrivals(self, requests: list[Request], arrivals, max_ticks: int = 1_000_000):
        """Serve ``requests`` submitted at wall-clock offsets ``arrivals``
        (seconds from start, sorted or not).  Returns (requests, ticks)."""
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        t0 = time.monotonic()
        idx, ticks = 0, 0
        try:
            while ticks < max_ticks:
                now = time.monotonic() - t0
                while idx < len(order) and arrivals[order[idx]] <= now:
                    self.submit(requests[order[idx]])
                    idx += 1
                if self.tick():
                    ticks += 1
                elif idx < len(order):
                    time.sleep(min(1e-3, max(0.0, arrivals[order[idx]] - (time.monotonic() - t0))))
                else:
                    break
        finally:
            self.observer.flush()
        return requests, ticks
