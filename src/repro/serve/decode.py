"""Distributed decode: KV-cache sharding policy + jit'd serve_step builders.

Cache placement policy (per leaf, by rank/shape — applied uniformly across
arch families):

* rank-4 attention caches (B, S, KVH, D):
    - KVH % model_axis == 0  → shard heads on `model` (zero-collective decode)
    - elif D % model_axis == 0 → shard head_dim on `model` (§Perf K4): the
      per-token cache scatter stays shard-local (no SPMD full-remat of the
      cache) and the QK/AV contractions become clean partial-sum psums
    - else                   → shard the *sequence* dim on `model`
      (flash-decode style: per-shard partial attention, the softmax over the
      sharded axis lowers to max/sum all-reduces — GSPMD's logsumexp combine)
* rank-3 MLA latent caches (B, S, R): sequence dim on `model`
* SSM / RG-LRU / conv states: batch on (pod, data); replicate feature dims
  (they are small constants per sequence)
* batch dim always on (pod, data) when divisible (decode_32k: 128 over 32;
  long_500k: batch 1 → latency-bound, batch unsharded by design)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import MODEL, batch_axes
from repro.utils import tree as tree_util


def cache_shardings(mesh, caches):
    """NamedShardings for a stacked (L leading axis) cache pytree."""
    b = batch_axes(mesh)
    bsz_div = lambda n: n % _size(mesh, b) == 0
    m = mesh.shape[MODEL]

    def assign(path, leaf):
        del path
        # leaves are stacked: (L, B, ...) — index 1 is batch
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 2 and bsz_div(shape[1]):
            spec[1] = b
        if len(shape) == 5:  # (L, B, S, KVH, D) attention cache
            if shape[3] % m == 0:
                spec[3] = MODEL
            elif shape[4] % m == 0:
                spec[4] = MODEL  # head_dim sharding (K4)
            elif shape[2] % m == 0:
                spec[2] = MODEL
        elif len(shape) == 4:  # (L, B, S, R) MLA latent / (L,B,H,D) misc
            if shape[2] % m == 0 and shape[2] >= 1024:  # sequence-like dim
                spec[2] = MODEL
        return NamedSharding(mesh, P(*spec))

    return tree_util.path_map(assign, caches)


def _size(mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def make_serve_step(model, *, sample: str = "greedy", whisper_enc=False):
    """Returns step(params, token, caches, cache_len[, enc]) ->
    (next_token, logits, new_caches)."""

    def step(params, token, caches, cache_len, *extra):
        if whisper_enc:
            logits, new_caches = model.decode_step(params, token, extra[0], caches, cache_len)
        else:
            logits, new_caches = model.decode_step(params, token, caches, cache_len)
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        else:
            raise ValueError(sample)
        return nxt, logits, new_caches

    return step


def make_prefill(model):
    """Forward over the prompt producing logits (B, S, V).  (The engine's
    cache-filling path decodes incrementally; large-batch prefill compute is
    exercised by this function — the dry-run's `prefill` kind.)"""

    def prefill(params, batch):
        x0 = model.embed(params, batch)
        x_final, _, _ = model.run_segments(params, x0)
        return model.head_logits(params, x_final, batch)

    return prefill


def select_slots(active, new, old):
    """Per-slot cache select over stacked (L, B, ...) pytrees: slot i takes
    ``new`` where ``active[i]``, else keeps ``old`` — the mask that stops
    finished/empty slots from burning state updates in a batched step."""

    def sel(n, o):
        m = active.reshape((1, -1) + (1,) * (n.ndim - 2))
        return jnp.where(m, n, o)

    return jax.tree_util.tree_map(sel, new, old)


def make_prefill_step(model):
    """Chunked-prefill builder: step(params, tokens (B, C), n_valid (B,),
    caches, cache_len) -> (last_logits (B, V), new_caches, new_cache_len).

    Fills each slot's KV cache with its next ≤C prompt tokens in ONE
    batched forward (⌈S/C⌉ forwards for a length-S prompt, not S decode
    ticks).  ``last_logits[i]`` is the logits after slot i's final valid
    token — the distribution the first generated token is sampled from
    when the prompt completes.  Slots with ``n_valid == 0`` are untouched.

    Models exposing ``prefill_step`` (+ ``supports_parallel_prefill``) get
    the truly parallel path (one scatter + causal attention over the whole
    cache); recurrent / ring-buffer models fall back to a masked
    ``lax.scan`` of ``decode_step`` over the chunk — still one jitted
    forward per chunk, with per-token state advance."""
    parallel = getattr(model, "supports_parallel_prefill", False)
    vocab = getattr(model.cfg, "v_padded", None) or model.cfg.vocab_size

    def step(params, tokens, n_valid, caches, cache_len):
        b, c = tokens.shape
        if parallel:
            logits, new_caches = model.prefill_step(
                params, tokens, caches, cache_len, n_valid)
            idx = jnp.clip(n_valid - 1, 0, c - 1)
            last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
            new_caches = select_slots(n_valid > 0, new_caches, caches)
        else:
            def body(carry, xs):
                caches, clen, last = carry
                t, tok_t = xs
                valid = t < n_valid
                logits, upd = model.decode_step(params, tok_t[:, None], caches, clen)
                caches = select_slots(valid, upd, caches)
                clen = clen + valid.astype(clen.dtype)
                last = jnp.where(valid[:, None], logits[:, -1, :].astype(last.dtype), last)
                return (caches, clen, last), None

            init = (caches, cache_len, jnp.zeros((b, vocab), jnp.float32))
            (new_caches, _, last), _ = jax.lax.scan(
                body, init, (jnp.arange(c), tokens.T))
        return last, new_caches, cache_len + n_valid

    return step
