from repro.serve import decode, engine
from repro.serve.decode import (cache_shardings, make_prefill, make_prefill_step,
                                make_serve_step, select_slots)
from repro.serve.engine import DECODE, DONE, PREFILL, QUEUED, Engine, Request

__all__ = ["decode", "engine", "cache_shardings", "make_prefill",
           "make_prefill_step", "make_serve_step", "select_slots",
           "Engine", "Request", "QUEUED", "PREFILL", "DECODE", "DONE"]
