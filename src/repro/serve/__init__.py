from repro.serve import decode, engine
from repro.serve.decode import cache_shardings, make_prefill, make_serve_step
from repro.serve.engine import Engine, Request

__all__ = ["decode", "engine", "cache_shardings", "make_prefill",
           "make_serve_step", "Engine", "Request"]
