"""Synthetic LM token streams (no text corpora ship with the container).

A mixture of a deterministic successor chain (t' = (a·t + b) mod V with
prob. p) and zipf-ish noise — an LM that learns reduces loss well below
log V, so training curves are meaningful.  Fully deterministic per
(seed, step): restart-safe.
"""

from __future__ import annotations

import numpy as np


class MarkovTokens:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, p_follow: float = 0.8, a: int = 31, b: int = 7):
        self.v = vocab_size
        self.s = seq_len
        self.b = batch_size
        self.seed = seed
        self.p = p_follow
        self.mult, self.add = a, b

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.b, self.s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.v, size=self.b)
        follow = rng.random((self.b, self.s)) < self.p
        noise = rng.integers(0, self.v, size=(self.b, self.s))
        for t in range(self.s):
            nxt = (toks[:, t] * self.mult + self.add) % self.v
            toks[:, t + 1] = np.where(follow[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
