"""MNIST (paper §4) — IDX loader with a procedural fallback.

If real MNIST IDX files exist under $REPRO_MNIST_DIR (train-images-idx3-ubyte
etc., optionally .gz), they are used.  This container ships no datasets, so
the default is **procedural digits**: 28×28 renderings of a 5×7 digit font
with random shift / scale / shear / pixel noise — same shapes, same
protocol, a genuinely learnable 10-class problem.  The paper's *validated*
claim (noise-robustness ordering clean > off-chip > on-chip, Fig. 5) is
dataset-independent; absolute MNIST numbers are reported when IDX files are
supplied (README §Data).
"""

from __future__ import annotations

import gzip
import os
import struct

import numpy as np

# 5x7 bitmap font for digits 0-9
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyphs() -> np.ndarray:
    g = np.zeros((10, 7, 5), np.float32)
    for d, rows in _FONT.items():
        for i, row in enumerate(rows):
            for j, c in enumerate(row):
                g[d, i, j] = float(c == "1")
    return g


def procedural_digits(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(images (n, 784) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    glyphs = _glyphs()
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.zeros((n, 28, 28), np.float32)
    scales = rng.uniform(2.4, 3.4, size=n)
    dx = rng.integers(-3, 4, size=n)
    dy = rng.integers(-3, 4, size=n)
    shear = rng.uniform(-0.25, 0.25, size=n)
    for i in range(n):
        g = glyphs[labels[i]]
        s = scales[i]
        h, w = int(round(7 * s)), int(round(5 * s))
        ys = np.clip((np.arange(h) / s).astype(int), 0, 6)
        xs = np.clip((np.arange(w) / s).astype(int), 0, 4)
        big = g[np.ix_(ys, xs)]
        # shear: shift each row proportionally
        sh = shear[i]
        for r in range(h):
            big[r] = np.roll(big[r], int(round(sh * (r - h / 2))))
        y0 = max(0, (28 - h) // 2 + dy[i])
        x0 = max(0, (28 - w) // 2 + dx[i])
        y1, x1 = min(28, y0 + h), min(28, x0 + w)
        imgs[i, y0:y1, x0:x1] = big[: y1 - y0, : x1 - x0]
    imgs += rng.normal(0, 0.08, size=imgs.shape).astype(np.float32)
    imgs = np.clip(imgs, 0.0, 1.0)
    return imgs.reshape(n, 784), labels


def _read_idx(path: str) -> np.ndarray:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rb") as f:
        magic = struct.unpack(">I", f.read(4))[0]
        ndim = magic & 0xFF
        dims = [struct.unpack(">I", f.read(4))[0] for _ in range(ndim)]
        data = np.frombuffer(f.read(), dtype=np.uint8)
    return data.reshape(dims)


def _find(directory: str, stem: str) -> str | None:
    for suffix in ("", ".gz"):
        p = os.path.join(directory, stem + suffix)
        if os.path.exists(p):
            return p
    return None


def load(split_sizes=(60000, 10000), seed: int = 0):
    """Returns dict(train=(x, y), test=(x, y)). Real MNIST if available."""
    d = os.environ.get("REPRO_MNIST_DIR", "")
    if d:
        ti = _find(d, "train-images-idx3-ubyte")
        tl = _find(d, "train-labels-idx1-ubyte")
        vi = _find(d, "t10k-images-idx3-ubyte")
        vl = _find(d, "t10k-labels-idx1-ubyte")
        if all([ti, tl, vi, vl]):
            xtr = _read_idx(ti).reshape(-1, 784).astype(np.float32) / 255.0
            ytr = _read_idx(tl).astype(np.int32)
            xte = _read_idx(vi).reshape(-1, 784).astype(np.float32) / 255.0
            yte = _read_idx(vl).astype(np.int32)
            return {"train": (xtr, ytr), "test": (xte, yte), "source": "mnist-idx"}
    ntr, nte = split_sizes
    xtr, ytr = procedural_digits(ntr, seed=seed)
    xte, yte = procedural_digits(nte, seed=seed + 10_000)
    return {"train": (xtr, ytr), "test": (xte, yte), "source": "procedural"}
