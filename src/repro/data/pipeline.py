"""Deterministic, restart-safe data pipelines.

All batching is a pure function of (seed, step): after a crash+restore at
step k the pipeline replays the identical stream — no iterator state to
checkpoint.  On a real multi-host deployment each host slices its
data-parallel shard out of the global batch by process_index (noted here;
this container is single-process).
"""

from __future__ import annotations

import numpy as np


class ArrayClassification:
    """Epoch-shuffled minibatcher over an in-memory (x, y) dataset."""

    def __init__(self, x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
        self.x = x
        self.y = y
        self.bs = batch_size
        self.seed = seed
        self.steps_per_epoch = len(x) // batch_size

    def batch(self, step: int) -> dict:
        epoch = step // self.steps_per_epoch
        i = step % self.steps_per_epoch
        rng = np.random.default_rng((self.seed, epoch))
        perm = rng.permutation(len(self.x))
        idx = perm[i * self.bs : (i + 1) * self.bs]
        return {"x": self.x[idx], "y": self.y[idx]}

    def eval_batches(self, x, y, batch_size: int | None = None):
        bs = batch_size or self.bs
        for i in range(0, len(x) - bs + 1, bs):
            yield {"x": x[i : i + bs], "y": y[i : i + bs]}
