"""Deterministic, restart-safe data pipelines.

All batching is a pure function of (seed, step): after a crash+restore at
step k the pipeline replays the identical stream — no iterator state to
checkpoint.  On a real multi-host deployment each host slices its
data-parallel shard out of the global batch by process_index (noted here;
this container is single-process).
"""

from __future__ import annotations

import numpy as np


class ArrayClassification:
    """Epoch-shuffled minibatcher over an in-memory (x, y) dataset."""

    def __init__(self, x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
        self.x = x
        self.y = y
        self.bs = batch_size
        self.seed = seed
        self.steps_per_epoch = len(x) // batch_size

    def batch(self, step: int) -> dict:
        epoch = step // self.steps_per_epoch
        i = step % self.steps_per_epoch
        rng = np.random.default_rng((self.seed, epoch))
        perm = rng.permutation(len(self.x))
        idx = perm[i * self.bs : (i + 1) * self.bs]
        return {"x": self.x[idx], "y": self.y[idx]}

    def eval_batches(self, x, y, batch_size: int | None = None):
        bs = batch_size or self.bs
        for i in range(0, len(x) - bs + 1, bs):
            yield {"x": x[i : i + bs], "y": y[i : i + bs]}


class DevicePrefetcher:
    """Double-buffered host→device feeder over a ``data_fn(step) -> batch``.

    Keeps up to ``depth`` future batches (beyond the current one) already
    enqueued through ``put_fn`` (default ``jax.device_put``, whose dispatch
    is async): the transfer for step k+1 overlaps the compute of step k,
    taking input feeding off the training hot path; ``depth=1`` is the
    minimum lookahead.  Stateless with respect to the stream itself —
    ``data_fn`` stays a pure function of step, so crash+restore replays
    identically and a restart at step k just refills the buffer."""

    def __init__(self, data_fn, put_fn=None, depth: int = 2,
                 limit: int | None = None):
        if put_fn is None:
            import jax

            put_fn = jax.device_put
        self.data_fn = data_fn
        self.put = put_fn
        self.depth = max(1, int(depth))
        self.limit = limit  # first step NOT to enqueue (fit's total_steps)
        self._buf: dict = {}

    def _enqueue(self, step: int) -> None:
        if step not in self._buf:
            self._buf[step] = self.put(self.data_fn(step))

    def __call__(self, step: int):
        self._enqueue(step)
        for k in range(step + 1, step + self.depth + 1):
            if self.limit is not None and k >= self.limit:
                break
            self._enqueue(k)
        batch = self._buf.pop(step)
        for k in [k for k in self._buf if k <= step]:  # restart / seek
            del self._buf[k]
        return batch
