from repro.data import mnist, pipeline, tokens

__all__ = ["mnist", "pipeline", "tokens"]
