"""Mamba-2 language model (mamba2-130m): attention-free SSD blocks.

DFA applicability (DESIGN.md §6): block-granular — each (norm → SSD →
residual) block is the DFA unit; the intra-block recurrence gets exact
local vjp.  Decode is O(1) state update, so long_500k lowers serve_step
with a constant-size cache.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.photonics import forward_matmul
from repro.dist.sharding import annotate, unshard_fsdp
from repro.models.base import DFAModel, SavedSegment, SegmentSpec, cross_entropy_loss
from repro.nn.embeddings import Embedding
from repro.nn.linear import Linear
from repro.nn.module import Module, named_key, stack_init
from repro.nn.norms import RMSNorm
from repro.nn.ssm import Mamba2Block


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    norm_eps: float = 1e-5
    split_proj: bool = False
    pad_vocab_to: int | None = None
    dtype: jnp.dtype = jnp.float32

    @property
    def v_padded(self) -> int:
        return self.pad_vocab_to or self.vocab_size


@dataclasses.dataclass(frozen=True)
class MambaLayer(Module):
    cfg: MambaConfig

    def _mixer(self):
        c = self.cfg
        return Mamba2Block(
            d_model=c.d_model, d_state=c.d_state, head_dim=c.head_dim,
            expand=c.expand, conv_width=c.conv_width, chunk=c.chunk,
            split_proj=c.split_proj, dtype=c.dtype,
        )

    def init(self, key):
        c = self.cfg
        return {
            "norm": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "norm")),
            "mixer": self._mixer().init(named_key(key, "mixer")),
        }

    def __call__(self, params, x, positions=None):
        del positions
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["norm"], x)
        y = annotate(x + self._mixer()(params["mixer"], h), "act_btd")
        return y, jnp.float32(0.0)

    def init_cache(self, batch: int, max_len: int = 0, dtype=None):
        return self._mixer().init_cache(batch, max_len, dtype)

    def decode(self, params, x, cache, cache_len):
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["norm"], x)
        y, cache = self._mixer().decode(params["mixer"], h, cache, cache_len)
        return x + y, cache


@dataclasses.dataclass(frozen=True)
class MambaLM(DFAModel):
    cfg: MambaConfig

    @property
    def layer(self) -> MambaLayer:
        return MambaLayer(self.cfg)

    @property
    def d_tap(self) -> int:
        return self.cfg.d_model

    def segment_specs(self):
        def apply(p, x, extras):
            del extras
            return self.layer(p, x)

        return (SegmentSpec("blocks", self.cfg.n_layers, self.cfg.d_model, apply),)

    def init(self, key):
        c = self.cfg
        return {
            "embed": {"tok": Embedding(c.v_padded, c.d_model, c.dtype).init(named_key(key, "tok"))},
            "blocks": stack_init(self.layer, named_key(key, "blocks"), c.n_layers),
            "head": {
                "norm": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "fnorm")),
                "out": Linear(c.d_model, c.v_padded, dtype=c.dtype).init(named_key(key, "out")),
            },
        }

    def embed(self, params, batch):
        c = self.cfg
        return annotate(
            Embedding(c.v_padded, c.d_model, c.dtype)(params["embed"]["tok"], batch["tokens"]),
            "act_btd",
        )

    def run_segments(self, params, x0):
        def body(x, bp):
            bp = unshard_fsdp(bp)
            y, aux = self.layer(bp, x)
            return y, (x, aux)

        x_final, (inputs, auxes) = jax.lax.scan(body, x0, params["blocks"])
        inputs = annotate(inputs, "tape_lbsd")
        return x_final, {"blocks": SavedSegment(inputs=inputs)}, {"blocks": jnp.sum(auxes)}

    def head_logits(self, params, x_final, batch):
        del batch
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["head"]["norm"], x_final)
        logits = h @ params["head"]["out"]["w"]
        if c.pad_vocab_to:
            pad_mask = jnp.arange(c.v_padded) >= c.vocab_size
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
        return annotate(logits, "logits")

    def loss_from_logits(self, logits, batch):
        return cross_entropy_loss(logits, batch["labels"], mask=batch.get("mask"))

    # ---- serving ----------------------------------------------------------
    def init_caches(self, batch: int, max_len: int = 0, dtype=None):
        cache = self.layer.init_cache(batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.cfg.n_layers,) + x.shape).copy(), cache
        )

    def decode_step(self, params, token, caches, cache_len):
        c = self.cfg
        x = Embedding(c.v_padded, c.d_model, c.dtype)(params["embed"]["tok"], token)

        def body(x, xs):
            bp, cache = xs
            bp = unshard_fsdp(bp)
            y, new_cache = self.layer.decode(bp, x, cache, cache_len)
            return y, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["head"]["norm"], x)
        logits = forward_matmul(h, params["head"]["out"]["w"])
        if c.pad_vocab_to:
            pad_mask = jnp.arange(c.v_padded) >= c.vocab_size
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
        return logits, new_caches

    def forward_gemm_specs(self):
        """(name, m, k) of the per-token forward projections (see
        ``sim.pipeline.forward_workload``): the fused input projection,
        the output projection, and the unembedding.  Convolutions and the
        diagonal SSD recurrence are not bank products."""
        c = self.cfg
        d_inner = c.expand * c.d_model
        n_heads = d_inner // c.head_dim
        conv_dim = d_inner + 2 * c.d_state  # n_groups == 1
        per_layer = [
            ("mixer.in_proj", d_inner + conv_dim + n_heads, c.d_model),
            ("mixer.out_proj", c.d_model, d_inner),
        ]
        specs = []
        for i in range(c.n_layers):
            specs += [(f"blocks[{i}].{n}", m, k) for (n, m, k) in per_layer]
        specs.append(("head.unembed", c.v_padded, c.d_model))
        return specs
