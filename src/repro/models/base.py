"""Model protocol consumed by the DFA engine (core/dfa.py).

A DFA-trainable model decomposes into:

    embed  →  segments (stacks of homogeneous blocks, scanned)  →  head

with parameters laid out as ``{"embed": …, <segment name>: stacked…, "head": …}``.

The forward pass (``run_segments``) *saves each block's input* — the only
activation state DFA needs (backprop would need the full chain).  The head
is split into ``head_logits`` (parameterised) and ``loss_from_logits``
(pure) so the engine can tap the error either at the logits (paper-faithful
MLP: e = ∂L/∂logits, dim = n_classes) or below the unembedding
(``hidden`` tap: e = ∂L/∂x_final, dim = d_model — the at-scale choice).
Head parameters always receive *exact* gradients, matching the paper
("the output layer weight matrix W(l) is updated using the error vector e").
"""

from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp

from repro.nn.module import Module


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """Static description of one stack of homogeneous blocks."""

    name: str
    n_layers: int
    d_inject: int  # feature dim at the injection point (block output)
    # apply(params_slice, x, extras) -> (y, weighted_aux_loss_scalar)
    apply: typing.Callable = dataclasses.field(compare=False)
    # optional: transform the error before projection (e.g. pool decoder
    # positions for encoder segments in enc-dec models)
    adapt_error: typing.Callable | None = dataclasses.field(default=None, compare=False)
    # optional: expand the projected delta to the block-output shape
    # (default: reshape) — e.g. broadcast a pooled delta over positions
    expand_delta: typing.Callable | None = dataclasses.field(default=None, compare=False)


@dataclasses.dataclass(frozen=True)
class SavedSegment:
    """Per-segment forward tape: stacked block inputs + shared extras."""

    inputs: typing.Any  # (L, ...) leaves — input to each block
    extras: typing.Any = None  # shared across layers (positions, enc_out, …)


class DFAModel(Module):
    """Interface — concrete models implement the five methods below."""

    # --- static info ---
    @property
    def error_tap(self) -> str:  # "hidden" | "logits"
        return "hidden"

    @property
    def d_tap(self) -> int:
        raise NotImplementedError

    def segment_specs(self) -> tuple[SegmentSpec, ...]:
        raise NotImplementedError

    def forward_gemm_specs(self) -> list:
        """(name, m, k) of every weight-stationary forward projection of one
        streamed token — the serving analogue of ``segment_specs``, consumed
        by ``sim.pipeline.forward_workload``.  LMs implement it; models that
        are not served (whisper, the MNIST MLP head aside) may not."""
        raise NotImplementedError(
            f"{type(self).__name__} declares no forward GEMM workload")

    # --- forward parts ---
    def embed(self, params, batch):
        raise NotImplementedError

    def run_segments(self, params, x0):
        """-> (x_final, {name: SavedSegment}, {name: aux_loss_scalar})"""
        raise NotImplementedError

    def head_logits(self, params, x_final, batch):
        raise NotImplementedError

    def loss_from_logits(self, logits, batch):
        """-> (loss, metrics dict)"""
        raise NotImplementedError

    # --- composed API ---
    def loss(self, params, batch):
        """Plain forward loss — used by the backprop baseline and eval."""
        x0 = self.embed(params, batch)
        x_final, _, auxes = self.run_segments(params, x0)
        logits = self.head_logits(params, x_final, batch)
        loss, metrics = self.loss_from_logits(logits, batch)
        aux_total = sum(auxes.values()) if auxes else 0.0
        metrics = dict(metrics)
        if auxes:
            metrics["aux_loss"] = aux_total
        return loss + aux_total, metrics

    # --- DFA hooks with defaults ---
    def embed_feedback(self, e_tap, fb_embed, x0, project_fn):
        """Cotangent injected at the embed output.  Default: single photonic
        projection of the (flattened-leading) error to x0's feature dim."""
        delta = project_fn(e_tap, fb_embed)
        return delta.astype(x0.dtype).reshape(x0.shape)


def cross_entropy_loss(logits, labels, *, mask=None, label_smoothing=0.0):
    """Mean CE over valid positions. logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if label_smoothing > 0.0:
        v = logits.shape[-1]
        mean_ll = jnp.mean(logits, axis=-1)
        nll = (1 - label_smoothing) * nll + label_smoothing * (logz - mean_ll)
        del v
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = (nll * mask).sum() / denom
        acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    else:
        loss = nll.mean()
        acc = (jnp.argmax(logits, -1) == labels).mean()
    return loss, {"ce_loss": loss, "accuracy": acc}
