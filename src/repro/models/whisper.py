"""Whisper-small backbone (enc-dec audio): 12+12 layers, LayerNorm, GELU,
learned positions, no rope.  The conv/mel frontend is a STUB — batches carry
precomputed frame embeddings (B, n_frames, d_model) per the assignment.

DFA for enc-dec (documented extension, DESIGN.md §6): decoder blocks receive
feedback from the decoder error tap directly; encoder blocks receive a fixed
random projection of the *pooled* decoder error (mean over target positions,
broadcast over frames) — a legitimate DFA feedback path since any fixed
random linear image of the output error aligns (ref [29]'s theory does not
require positional correspondence).  Cross-attention parameters train via
the decoder blocks' local vjp.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.sharding import annotate, unshard_fsdp
from repro.models.base import DFAModel, SavedSegment, SegmentSpec, cross_entropy_loss
from repro.nn.attention import Attention, CrossAttention
from repro.nn.embeddings import Embedding
from repro.nn.frontends import AudioFrontendStub
from repro.nn.linear import Linear, MLP
from repro.nn.module import Module, named_key, stack_init
from repro.nn.norms import LayerNorm


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_frames: int = 1500
    max_target: int = 448
    norm_eps: float = 1e-5
    pad_vocab_to: int | None = None
    dtype: jnp.dtype = jnp.float32

    @property
    def v_padded(self) -> int:
        return self.pad_vocab_to or self.vocab_size


@dataclasses.dataclass(frozen=True)
class _EncLayer(Module):
    cfg: WhisperConfig

    def _attn(self):
        c = self.cfg
        return Attention(d_model=c.d_model, n_heads=c.n_heads, n_kv_heads=c.n_heads,
                         qkv_bias=True, out_bias=True, rope=False, causal=False, dtype=c.dtype)

    def init(self, key):
        c = self.cfg
        return {
            "ln1": LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype).init(named_key(key, "ln1")),
            "attn": self._attn().init(named_key(key, "attn")),
            "ln2": LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype).init(named_key(key, "ln2")),
            "mlp": MLP(c.d_model, c.d_ff, "gelu", dtype=c.dtype).init(named_key(key, "mlp")),
        }

    def __call__(self, params, x, positions=None):
        c = self.cfg
        ln = LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype)
        x = x + self._attn()(params["attn"], ln(params["ln1"], x))
        x = x + MLP(c.d_model, c.d_ff, "gelu", dtype=c.dtype)(params["mlp"], ln(params["ln2"], x))
        return annotate(x, "act_btd"), jnp.float32(0.0)


@dataclasses.dataclass(frozen=True)
class _DecLayer(Module):
    cfg: WhisperConfig

    def _self(self):
        c = self.cfg
        return Attention(d_model=c.d_model, n_heads=c.n_heads, n_kv_heads=c.n_heads,
                         qkv_bias=True, out_bias=True, rope=False, causal=True, dtype=c.dtype)

    def _cross(self):
        c = self.cfg
        return CrossAttention(d_model=c.d_model, n_heads=c.n_heads, dtype=c.dtype)

    def init(self, key):
        c = self.cfg
        return {
            "ln1": LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype).init(named_key(key, "ln1")),
            "self": self._self().init(named_key(key, "self")),
            "ln2": LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype).init(named_key(key, "ln2")),
            "cross": self._cross().init(named_key(key, "cross")),
            "ln3": LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype).init(named_key(key, "ln3")),
            "mlp": MLP(c.d_model, c.d_ff, "gelu", dtype=c.dtype).init(named_key(key, "mlp")),
        }

    def __call__(self, params, x, enc):
        c = self.cfg
        ln = LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype)
        x = x + self._self()(params["self"], ln(params["ln1"], x))
        x = x + self._cross()(params["cross"], ln(params["ln2"], x), enc)
        x = x + MLP(c.d_model, c.d_ff, "gelu", dtype=c.dtype)(params["mlp"], ln(params["ln3"], x))
        return annotate(x, "act_btd"), jnp.float32(0.0)

    def decode(self, params, x, enc, cache, cache_len):
        c = self.cfg
        ln = LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype)
        h, cache = self._self().decode(params["self"], ln(params["ln1"], x), cache, cache_len)
        x = x + h
        x = x + self._cross()(params["cross"], ln(params["ln2"], x), enc)
        x = x + MLP(c.d_model, c.d_ff, "gelu", dtype=c.dtype)(params["mlp"], ln(params["ln3"], x))
        return x, cache


@dataclasses.dataclass(frozen=True)
class WhisperModel(DFAModel):
    cfg: WhisperConfig

    @property
    def d_tap(self) -> int:
        return self.cfg.d_model

    def segment_specs(self):
        c = self.cfg
        enc_layer = _EncLayer(c)
        dec_layer = _DecLayer(c)

        def enc_apply(p, x, extras):
            del extras
            return enc_layer(p, x)

        def dec_apply(p, x, extras):
            return dec_layer(p, x, extras)

        return (
            SegmentSpec(
                "enc", c.n_enc_layers, c.d_model, enc_apply,
                adapt_error=lambda e: jnp.mean(e, axis=1, keepdims=True),
                expand_delta=lambda d, shape: jnp.broadcast_to(d, shape),
            ),
            SegmentSpec("dec", c.n_dec_layers, c.d_model, dec_apply),
        )

    def init(self, key):
        c = self.cfg
        return {
            "embed": {
                "audio": AudioFrontendStub(c.d_model, c.n_frames,
                                           c.dtype).init(named_key(key, "audio")),
                "tok": Embedding(c.v_padded, c.d_model, c.dtype).init(named_key(key, "tok")),
                "pos": (jax.random.normal(named_key(key, "pos"),
                                          (c.max_target, c.d_model)) * 0.01).astype(c.dtype),
            },
            "enc": stack_init(_EncLayer(c), named_key(key, "enc"), c.n_enc_layers),
            "dec": stack_init(_DecLayer(c), named_key(key, "dec"), c.n_dec_layers),
            "head": {
                "ln_enc": LayerNorm(c.d_model, c.norm_eps,
                                    dtype=c.dtype).init(named_key(key, "ln_enc")),
                "ln": LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype).init(named_key(key, "ln")),
                "out": Linear(c.d_model, c.v_padded, dtype=c.dtype).init(named_key(key, "out")),
            },
        }

    def embed(self, params, batch):
        c = self.cfg
        enc0 = AudioFrontendStub(c.d_model, c.n_frames, c.dtype)(
            params["embed"]["audio"], batch["frames"].astype(c.dtype)
        )
        tok = Embedding(c.v_padded, c.d_model, c.dtype)(params["embed"]["tok"], batch["tokens"])
        s = tok.shape[1]
        # decode-time: absolute position offset comes via batch["pos_offset"]
        if s <= c.max_target:
            dec0 = tok + params["embed"]["pos"][:s]
        else:  # dry-run shapes larger than whisper's real context: tile
            reps = -(-s // c.max_target)
            pos = jnp.tile(params["embed"]["pos"], (reps, 1))[:s]
            dec0 = tok + pos
        return {"enc": enc0, "dec": dec0}

    def embed_feedback(self, e_tap, fb_embed, x0, project_fn):
        e_dec = project_fn(e_tap, fb_embed)
        e_pool = jnp.mean(e_dec, axis=1, keepdims=True)
        return {
            "enc": jnp.broadcast_to(e_pool, x0["enc"].shape).astype(x0["enc"].dtype),
            "dec": e_dec.astype(x0["dec"].dtype).reshape(x0["dec"].shape),
        }

    def run_segments(self, params, x0):
        c = self.cfg
        enc_layer = _EncLayer(c)
        dec_layer = _DecLayer(c)

        def enc_body(x, bp):
            bp = unshard_fsdp(bp)
            y, _ = enc_layer(bp, x)
            return y, x

        enc_final, enc_inputs = jax.lax.scan(enc_body, x0["enc"], params["enc"])

        def dec_body(x, bp):
            bp = unshard_fsdp(bp)
            y, _ = dec_layer(bp, x, enc_final)
            return y, x

        dec_final, dec_inputs = jax.lax.scan(dec_body, x0["dec"], params["dec"])
        saved = {
            "enc": SavedSegment(inputs=annotate(enc_inputs, "tape_lbsd")),
            "dec": SavedSegment(inputs=annotate(dec_inputs, "tape_lbsd"), extras=enc_final),
        }
        return dec_final, saved, {}

    def head_logits(self, params, x_final, batch):
        del batch
        c = self.cfg
        h = LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype)(params["head"]["ln"], x_final)
        logits = h @ params["head"]["out"]["w"]
        if c.pad_vocab_to:
            pad_mask = jnp.arange(c.v_padded) >= c.vocab_size
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
        return annotate(logits, "logits")

    def loss_from_logits(self, logits, batch):
        return cross_entropy_loss(logits, batch["labels"], mask=batch.get("mask"))

    # ---- serving ----------------------------------------------------------
    def encode(self, params, frames):
        c = self.cfg
        enc0 = AudioFrontendStub(c.d_model, c.n_frames, c.dtype)(
            params["embed"]["audio"], frames.astype(c.dtype)
        )
        enc_layer = _EncLayer(c)

        def body(x, bp):
            y, _ = enc_layer(bp, x)
            return y, None

        enc_final, _ = jax.lax.scan(body, enc0, params["enc"])
        return LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype)(params["head"]["ln_enc"], enc_final)

    def init_caches(self, batch: int, max_len: int, dtype=None):
        cache = _DecLayer(self.cfg)._self().init_cache(batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.cfg.n_dec_layers,) + x.shape).copy(), cache
        )

    def decode_step(self, params, token, enc_out, caches, cache_len):
        c = self.cfg
        tok = Embedding(c.v_padded, c.d_model, c.dtype)(params["embed"]["tok"], token)
        pos_idx = jnp.minimum(cache_len, c.max_target - 1)
        x = tok + params["embed"]["pos"][pos_idx][:, None, :]
        dec_layer = _DecLayer(c)

        def body(x, xs):
            bp, cache = xs
            bp = unshard_fsdp(bp)
            y, new_cache = dec_layer.decode(bp, x, enc_out, cache, cache_len)
            return y, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["dec"], caches))
        h = LayerNorm(c.d_model, c.norm_eps, dtype=c.dtype)(params["head"]["ln"], x)
        return h @ params["head"]["out"]["w"], new_caches
