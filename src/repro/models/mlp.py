"""The paper's feed-forward network: 784×800×800×10 ReLU MLP (Fig. 5).

error_tap = "logits": e = ∂L/∂logits = softmax(ŷ) − y, dim 10 — exactly the
error the photonic circuit amplitude-encodes onto the N WDM channels.  The
hidden DenseBlocks receive DFA feedback δ(k) = B(k)e ⊙ g'(a(k)) via the
engine's block-local vjp; the output linear layer ("head") is updated with
e exactly, as in the paper.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.base import DFAModel, SegmentSpec, cross_entropy_loss
from repro.nn.linear import DenseBlock, Linear
from repro.nn.module import named_key


@dataclasses.dataclass(frozen=True)
class MLPClassifier(DFAModel):
    in_dim: int = 784
    hidden: tuple = (800, 800)
    n_classes: int = 10
    activation: str = "relu"
    dtype: jnp.dtype = jnp.float32

    @property
    def error_tap(self) -> str:
        return "logits"

    @property
    def d_tap(self) -> int:
        return self.n_classes

    def _blocks(self):
        dims = (self.in_dim,) + tuple(self.hidden)
        return [
            DenseBlock(dims[i], dims[i + 1], self.activation, dtype=self.dtype)
            for i in range(len(self.hidden))
        ]

    def forward_gemm_specs(self):
        dims = (self.in_dim,) + tuple(self.hidden)
        specs = [(f"h{i}", dims[i + 1], dims[i]) for i in range(len(self.hidden))]
        specs.append(("head", self.n_classes, self.hidden[-1]))
        return specs

    def segment_specs(self):
        specs = []
        for i, blk in enumerate(self._blocks()):
            def apply(p, x, extras, blk=blk):
                del extras
                # stacked with L=1 → strip the layer axis handled by engine map
                return blk(p, x), jnp.float32(0.0)

            specs.append(
                SegmentSpec(name=f"h{i}", n_layers=1, d_inject=blk.out_dim, apply=apply)
            )
        return tuple(specs)

    def init(self, key):
        params = {"embed": {}}
        for i, blk in enumerate(self._blocks()):
            p = blk.init(named_key(key, f"h{i}"))
            params[f"h{i}"] = jax.tree_util.tree_map(lambda x: x[None], p)
        params["head"] = Linear(
            self.hidden[-1], self.n_classes, use_bias=True, dtype=self.dtype
        ).init(named_key(key, "head"))
        return params

    def embed(self, params, batch):
        return batch["x"].astype(self.dtype)

    def run_segments(self, params, x0):
        x = x0
        saved = {}
        for i, blk in enumerate(self._blocks()):
            name = f"h{i}"
            saved[name] = _tape(x[None])
            p = jax.tree_util.tree_map(lambda t: t[0], params[name])
            x = blk(p, x)
        return x, saved, {}

    def head_logits(self, params, x_final, batch):
        del batch
        return Linear(self.hidden[-1], self.n_classes, use_bias=True, dtype=self.dtype)(
            params["head"], x_final
        )

    def loss_from_logits(self, logits, batch):
        return cross_entropy_loss(logits, batch["y"])


def _tape(inputs):
    from repro.models.base import SavedSegment

    return SavedSegment(inputs=inputs, extras=None)
