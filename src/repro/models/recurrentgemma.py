"""RecurrentGemma (Griffin) — hybrid RG-LRU + local attention, 1:2 pattern.

38 layers = 12 × (Rec, Rec, LocalAttn) + (Rec, Rec) tail.  Each layer is a
Griffin residual layer: (norm → temporal-mix → residual) then (norm →
gated-MLP → residual).  DFA segments: the three group sub-positions (each a
stack of 12) plus the 2-layer tail — every layer gets its own feedback
matrix and local vjp; the RG-LRU recurrence stays inside the block.

long_500k is runnable: local attention caches are ring buffers of
``window`` (2048) slots and RG-LRU state is O(1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.photonics import forward_matmul
from repro.dist.sharding import annotate, unshard_fsdp
from repro.models.base import DFAModel, SavedSegment, SegmentSpec, cross_entropy_loss
from repro.nn.attention import Attention
from repro.nn.embeddings import Embedding
from repro.nn.linear import GatedMLP, Linear
from repro.nn.module import Module, named_key, stack_init
from repro.nn.norms import RMSNorm
from repro.nn.rglru import RGLRUBlock


@dataclasses.dataclass(frozen=True)
class RecurrentGemmaConfig:
    name: str
    n_layers: int  # total (pattern RRA, remainder = leading R's)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_rnn: int | None = None  # defaults to d_model
    window: int = 2048
    conv_width: int = 4
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    dtype: jnp.dtype = jnp.float32
    q_chunk: int = 2048
    k_chunk: int = 1024

    @property
    def n_groups(self) -> int:
        return self.n_layers // 3

    @property
    def n_tail(self) -> int:
        return self.n_layers - 3 * self.n_groups  # leading-R remainder


@dataclasses.dataclass(frozen=True)
class _Layer(Module):
    cfg: RecurrentGemmaConfig
    kind: str  # "rec" | "attn"

    def _mixer(self):
        c = self.cfg
        if self.kind == "rec":
            return RGLRUBlock(c.d_model, c.d_rnn or c.d_model, c.conv_width, c.dtype)
        return Attention(
            d_model=c.d_model, n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
            window=c.window, rope_theta=c.rope_theta, dtype=c.dtype,
        )

    def init(self, key):
        c = self.cfg
        return {
            "norm1": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "norm1")),
            "mixer": self._mixer().init(named_key(key, "mixer")),
            "norm2": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "norm2")),
            "mlp": GatedMLP(c.d_model, c.d_ff, "gelu", c.dtype).init(named_key(key, "mlp")),
        }

    def __call__(self, params, x, positions):
        c = self.cfg
        norm = RMSNorm(c.d_model, c.norm_eps, c.dtype)
        h = norm(params["norm1"], x)
        if self.kind == "rec":
            h = self._mixer()(params["mixer"], h)
        else:
            h = self._mixer()(params["mixer"], h, positions=positions,
                              q_chunk=c.q_chunk, k_chunk=c.k_chunk)
        x = x + h
        h = norm(params["norm2"], x)
        h = GatedMLP(c.d_model, c.d_ff, "gelu", c.dtype)(params["mlp"], h)
        return annotate(x + h, "act_btd"), jnp.float32(0.0)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        if self.kind == "rec":
            return self._mixer().init_cache(batch, 0, dtype)
        return self._mixer().init_cache(batch, max_len, dtype)

    def decode(self, params, x, cache, cache_len):
        c = self.cfg
        norm = RMSNorm(c.d_model, c.norm_eps, c.dtype)
        h = norm(params["norm1"], x)
        h, cache = self._mixer().decode(params["mixer"], h, cache, cache_len)
        x = x + h
        h = norm(params["norm2"], x)
        h = GatedMLP(c.d_model, c.d_ff, "gelu", c.dtype)(params["mlp"], h)
        return x + h, cache


@dataclasses.dataclass(frozen=True)
class RecurrentGemmaLM(DFAModel):
    cfg: RecurrentGemmaConfig

    @property
    def d_tap(self) -> int:
        return self.cfg.d_model

    def _rec(self):
        return _Layer(self.cfg, "rec")

    def _attn(self):
        return _Layer(self.cfg, "attn")

    def segment_specs(self):
        c = self.cfg

        def mk(layer):
            def apply(p, x, extras, layer=layer):
                return layer(p, x, extras)

            return apply

        specs = [
            SegmentSpec("grp_rec1", c.n_groups, c.d_model, mk(self._rec())),
            SegmentSpec("grp_rec2", c.n_groups, c.d_model, mk(self._rec())),
            SegmentSpec("grp_attn", c.n_groups, c.d_model, mk(self._attn())),
        ]
        if c.n_tail:
            specs.append(SegmentSpec("tail_rec", c.n_tail, c.d_model, mk(self._rec())))
        return tuple(specs)

    def init(self, key):
        c = self.cfg
        params = {
            "embed": {"tok": Embedding(c.vocab_size, c.d_model,
                                       c.dtype).init(named_key(key, "tok"))},
            "grp_rec1": stack_init(self._rec(), named_key(key, "grp_rec1"), c.n_groups),
            "grp_rec2": stack_init(self._rec(), named_key(key, "grp_rec2"), c.n_groups),
            "grp_attn": stack_init(self._attn(), named_key(key, "grp_attn"), c.n_groups),
            "head": {
                "norm": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "fnorm")),
                "out": Linear(c.d_model, c.vocab_size, dtype=c.dtype).init(named_key(key, "out")),
            },
        }
        if c.n_tail:
            params["tail_rec"] = stack_init(self._rec(), named_key(key, "tail_rec"), c.n_tail)
        return params

    def embed(self, params, batch):
        c = self.cfg
        return annotate(
            Embedding(c.vocab_size, c.d_model, c.dtype)(params["embed"]["tok"], batch["tokens"]),
            "act_btd",
        )

    def run_segments(self, params, x0):
        c = self.cfg
        b, s, _ = x0.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        rec, att = self._rec(), self._attn()

        def body(x, xs):
            p1, p2, p3 = (unshard_fsdp(q) for q in xs)
            x1 = x
            x, _ = rec(p1, x, positions)
            x2 = x
            x, _ = rec(p2, x, positions)
            x3 = x
            x, _ = att(p3, x, positions)
            return x, (x1, x2, x3)

        x, (i1, i2, i3) = jax.lax.scan(
            body, x0, (params["grp_rec1"], params["grp_rec2"], params["grp_attn"])
        )
        saved = {
            "grp_rec1": SavedSegment(inputs=annotate(i1, "tape_lbsd"), extras=positions),
            "grp_rec2": SavedSegment(inputs=annotate(i2, "tape_lbsd"), extras=positions),
            "grp_attn": SavedSegment(inputs=annotate(i3, "tape_lbsd"), extras=positions),
        }
        if c.n_tail:
            def tail_body(x, bp):
                bp = unshard_fsdp(bp)
                y, _ = rec(bp, x, positions)
                return y, x

            x, tin = jax.lax.scan(tail_body, x, params["tail_rec"])
            saved["tail_rec"] = SavedSegment(inputs=annotate(tin, "tape_lbsd"), extras=positions)
        return x, saved, {}

    def head_logits(self, params, x_final, batch):
        del batch
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["head"]["norm"], x_final)
        return annotate(h @ params["head"]["out"]["w"], "logits")

    def loss_from_logits(self, logits, batch):
        return cross_entropy_loss(logits, batch["labels"], mask=batch.get("mask"))

    # ---- serving ----------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, dtype=None):
        c = self.cfg
        rec_cache = self._rec().init_cache(batch, 0, dtype)
        attn_cache = self._attn().init_cache(batch, max_len, dtype)
        stack = lambda cache, n: jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), cache
        )
        caches = {
            "grp_rec1": stack(rec_cache, c.n_groups),
            "grp_rec2": stack(rec_cache, c.n_groups),
            "grp_attn": stack(attn_cache, c.n_groups),
        }
        if c.n_tail:
            caches["tail_rec"] = stack(rec_cache, c.n_tail)
        return caches

    def decode_step(self, params, token, caches, cache_len):
        c = self.cfg
        x = Embedding(c.vocab_size, c.d_model, c.dtype)(params["embed"]["tok"], token)
        rec, att = self._rec(), self._attn()

        def body(x, xs):
            (p1, c1), (p2, c2), (p3, c3) = xs
            p1, p2, p3 = unshard_fsdp(p1), unshard_fsdp(p2), unshard_fsdp(p3)
            x, n1 = rec.decode(p1, x, c1, cache_len)
            x, n2 = rec.decode(p2, x, c2, cache_len)
            x, n3 = att.decode(p3, x, c3, cache_len)
            return x, (n1, n2, n3)

        x, (n1, n2, n3) = jax.lax.scan(
            body, x,
            ((params["grp_rec1"], caches["grp_rec1"]),
             (params["grp_rec2"], caches["grp_rec2"]),
             (params["grp_attn"], caches["grp_attn"])),
        )
        new_caches = {"grp_rec1": n1, "grp_rec2": n2, "grp_attn": n3}
        if c.n_tail:
            def tail_body(x, xs):
                bp, cc = xs
                y, nc = rec.decode(bp, x, cc, cache_len)
                return y, nc

            x, nt = jax.lax.scan(tail_body, x, (params["tail_rec"], caches["tail_rec"]))
            new_caches["tail_rec"] = nt
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["head"]["norm"], x)
        return forward_matmul(h, params["head"]["out"]["w"]), new_caches

    def forward_gemm_specs(self):
        """(name, m, k) per-token forward projections (see
        ``sim.pipeline.forward_workload``).  Recurrent layers carry the
        RG-LRU block's five projections; attention layers the q/k/v/o set;
        every layer a gated MLP; plus the unembedding.  Convolutions and
        the diagonal recurrence are not bank products."""
        c = self.cfg
        d, dr = c.d_model, c.d_rnn or c.d_model
        hd = d // c.n_heads
        mlp = [("mlp.gate", c.d_ff, d), ("mlp.up", c.d_ff, d), ("mlp.down", d, c.d_ff)]
        rec = [("mixer.in_x", dr, d), ("mixer.in_gate", dr, d),
               ("mixer.w_a", dr, dr), ("mixer.w_i", dr, dr),
               ("mixer.out", d, dr)] + mlp
        attn = [("attn.q", c.n_heads * hd, d), ("attn.k", c.n_kv_heads * hd, d),
                ("attn.v", c.n_kv_heads * hd, d), ("attn.o", d, c.n_heads * hd)] + mlp
        specs = []
        layer = 0
        for _ in range(c.n_groups):
            for kind in (rec, rec, attn):
                specs += [(f"layers[{layer}].{n}", m, k) for (n, m, k) in kind]
                layer += 1
        for _ in range(c.n_tail):
            specs += [(f"layers[{layer}].{n}", m, k) for (n, m, k) in rec]
            layer += 1
        specs.append(("head.unembed", c.vocab_size, d))
        return specs
