"""Decoder-only transformer LM — the workhorse for 7 of the 10 assigned
architectures (qwen1.5 / qwen3 / granite / minicpm3-MLA / qwen2-moe /
kimi-k2 / internvl2 backbone).

Composable switches: GQA or MLA temporal mix, dense or MoE channel mix,
qkv-bias, qk-norm, sliding window, optional vision-stub prefix.  Layers are
scanned (stacked params) — HLO depth-independent; DFA sees one segment
named "blocks".
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.photonics import forward_matmul
from repro.dist.sharding import annotate, unshard_fsdp
from repro.models.base import DFAModel, SavedSegment, SegmentSpec, cross_entropy_loss
from repro.nn.attention import Attention, MLAttention
from repro.nn.embeddings import Embedding
from repro.nn.frontends import VisionFrontendStub
from repro.nn.linear import GatedMLP, Linear
from repro.nn.module import Module, named_key, stack_init
from repro.nn.moe import MoE
from repro.nn.norms import RMSNorm


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int | None = None
    capacity_factor: float = 1.25
    lb_weight: float = 0.01
    z_weight: float = 1e-3
    dispatch: str = "einsum"  # einsum | gather (see nn/moe.py)


@dataclasses.dataclass(frozen=True)
class MLASettings:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class VisionSettings:
    d_vision: int = 1024
    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    window: int | None = None
    moe: MoESettings | None = None
    mla: MLASettings | None = None
    vision: VisionSettings | None = None
    dtype: jnp.dtype = jnp.float32
    # attention chunking for long-sequence prefill
    q_chunk: int = 2048
    k_chunk: int = 1024
    # pad the embedding/unembedding vocab dim to a shard/MXU-aligned size;
    # odd vocabularies (e.g. 50280, 73448) otherwise fall back to unsharded
    # unembeddings whose logits all-reduce dominates the collective term
    pad_vocab_to: int | None = None

    @property
    def v_padded(self) -> int:
        return self.pad_vocab_to or self.vocab_size


@dataclasses.dataclass(frozen=True)
class DecoderBlock(Module):
    cfg: TransformerConfig

    def _attn(self):
        c = self.cfg
        if c.mla is not None:
            m = c.mla
            return MLAttention(
                d_model=c.d_model, n_heads=c.n_heads,
                q_lora_rank=m.q_lora_rank, kv_lora_rank=m.kv_lora_rank,
                qk_nope_dim=m.qk_nope_dim, qk_rope_dim=m.qk_rope_dim,
                v_head_dim=m.v_head_dim, rope_theta=c.rope_theta, dtype=c.dtype,
            )
        return Attention(
            d_model=c.d_model, n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
            head_dim=c.head_dim, qkv_bias=c.qkv_bias, qk_norm=c.qk_norm,
            rope_theta=c.rope_theta, window=c.window, dtype=c.dtype,
        )

    def _ffn(self):
        c = self.cfg
        if c.moe is not None:
            m = c.moe
            return MoE(
                d_model=c.d_model, d_ff_expert=m.d_ff_expert,
                n_experts=m.n_experts, top_k=m.top_k,
                n_shared_experts=m.n_shared_experts, d_ff_shared=m.d_ff_shared,
                capacity_factor=m.capacity_factor, dispatch=m.dispatch,
                dtype=c.dtype,
            )
        return GatedMLP(c.d_model, c.d_ff, dtype=c.dtype)

    def init(self, key):
        c = self.cfg
        return {
            "norm1": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "norm1")),
            "attn": self._attn().init(named_key(key, "attn")),
            "norm2": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "norm2")),
            "ffn": self._ffn().init(named_key(key, "ffn")),
        }

    def __call__(self, params, x, positions):
        """-> (y, weighted_aux_loss)."""
        c = self.cfg
        norm = RMSNorm(c.d_model, c.norm_eps, c.dtype)
        h = norm(params["norm1"], x)
        h = self._attn()(params["attn"], h, positions=positions,
                         q_chunk=c.q_chunk, k_chunk=c.k_chunk)
        x = x + h
        h = norm(params["norm2"], x)
        if c.moe is not None:
            h, aux = self._ffn()(params["ffn"], h)
            aux_loss = c.moe.lb_weight * aux["lb_loss"] + c.moe.z_weight * aux["z_loss"]
        else:
            h = self._ffn()(params["ffn"], h)
            aux_loss = jnp.float32(0.0)
        y = annotate(x + h, "act_btd")
        return y, aux_loss

    # --- serving ---
    def init_cache(self, batch: int, max_len: int, dtype=None):
        return self._attn().init_cache(batch, max_len, dtype)

    def decode(self, params, x, cache, cache_len):
        c = self.cfg
        norm = RMSNorm(c.d_model, c.norm_eps, c.dtype)
        h = norm(params["norm1"], x)
        h, cache = self._attn().decode(params["attn"], h, cache, cache_len)
        x = x + h
        h = norm(params["norm2"], x)
        if c.moe is not None:
            h, _ = self._ffn()(params["ffn"], h)
        else:
            h = self._ffn()(params["ffn"], h)
        return x + h, cache

    def prefill(self, params, x, cache, cache_len, n_valid):
        """Chunked multi-token cache fill: x (B, C, d).  Padded (invalid)
        chunk positions still flow through the FFN — harmless for dense
        blocks; under MoE they can contend for expert capacity, a serving
        approximation the dense configs never see."""
        c = self.cfg
        norm = RMSNorm(c.d_model, c.norm_eps, c.dtype)
        h = norm(params["norm1"], x)
        h, cache = self._attn().prefill(params["attn"], h, cache, cache_len, n_valid)
        x = x + h
        h = norm(params["norm2"], x)
        if c.moe is not None:
            h, _ = self._ffn()(params["ffn"], h)
        else:
            h = self._ffn()(params["ffn"], h)
        return x + h, cache


@dataclasses.dataclass(frozen=True)
class TransformerLM(DFAModel):
    cfg: TransformerConfig

    @property
    def block(self) -> DecoderBlock:
        return DecoderBlock(self.cfg)

    @property
    def d_tap(self) -> int:
        return self.cfg.d_model  # "hidden" tap (DESIGN.md §8.3)

    def segment_specs(self):
        c = self.cfg

        def apply(p, x, extras):
            positions = extras
            return self.block(p, x, positions)

        return (
            SegmentSpec("blocks", c.n_layers, c.d_model, apply),
        )

    def init(self, key):
        c = self.cfg
        embed = {"tok": Embedding(c.v_padded, c.d_model, c.dtype).init(named_key(key, "tok"))}
        if c.vision is not None:
            embed["vision"] = VisionFrontendStub(c.vision.d_vision, c.d_model, c.dtype).init(
                named_key(key, "vision")
            )
        return {
            "embed": embed,
            "blocks": stack_init(self.block, named_key(key, "blocks"), c.n_layers),
            "head": {
                "norm": RMSNorm(c.d_model, c.norm_eps, c.dtype).init(named_key(key, "fnorm")),
                "out": Linear(c.d_model, c.v_padded, dtype=c.dtype).init(named_key(key, "out")),
            },
        }

    def embed(self, params, batch):
        c = self.cfg
        tok = Embedding(c.v_padded, c.d_model, c.dtype)(params["embed"]["tok"], batch["tokens"])
        if c.vision is not None and "patch_embeds" in batch:
            # vision prefix is optional: text-only prefill/serving is valid
            pre = VisionFrontendStub(c.vision.d_vision, c.d_model, c.dtype)(
                params["embed"]["vision"], batch["patch_embeds"]
            )
            tok = jnp.concatenate([pre.astype(tok.dtype), tok], axis=1)
        return annotate(tok, "act_btd")

    def run_segments(self, params, x0):
        b, s, _ = x0.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

        def body(x, bp):
            bp = unshard_fsdp(bp)  # per-layer ZeRO-3 gather inside the scan
            y, aux = self.block(bp, x, positions)
            return y, (x, aux)

        x_final, (inputs, auxes) = jax.lax.scan(body, x0, params["blocks"])
        inputs = annotate(inputs, "tape_lbsd")  # model-sharded DFA tape
        saved = {"blocks": SavedSegment(inputs=inputs, extras=positions)}
        return x_final, saved, {"blocks": jnp.sum(auxes)}

    def head_logits(self, params, x_final, batch):
        del batch
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["head"]["norm"], x_final)
        return annotate(self._head(params, h), "logits")

    def loss_from_logits(self, logits, batch):
        c = self.cfg
        if c.vision is not None:
            # loss only over the text region (after n_patches prefix)
            logits = logits[:, -batch["labels"].shape[1]:]
        mask = batch.get("mask")
        return cross_entropy_loss(logits, batch["labels"], mask=mask)

    # ---- serving ----------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, dtype=None):
        """Stacked per-layer caches (L leading axis)."""
        cache = self.block.init_cache(batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.cfg.n_layers,) + x.shape).copy(), cache
        )

    def decode_step(self, params, token, caches, cache_len):
        """token: (B, 1) int. Returns (logits (B,1,V), new caches)."""
        c = self.cfg
        x = Embedding(c.v_padded, c.d_model, c.dtype)(params["embed"]["tok"], token)

        def body(x, xs):
            bp, cache = xs
            bp = unshard_fsdp(bp)
            y, new_cache = self.block.decode(bp, x, cache, cache_len)
            return y, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["head"]["norm"], x)
        return self._head(params, h), new_caches

    def _head(self, params, h):
        """Unembedding with the same pad-vocab masking as ``head_logits`` —
        greedy serving must never emit a padding token id."""
        c = self.cfg
        logits = forward_matmul(h, params["head"]["out"]["w"])
        if c.pad_vocab_to:
            pad_mask = jnp.arange(c.v_padded) >= c.vocab_size
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
        return logits

    @property
    def supports_parallel_prefill(self) -> bool:
        """Global-attention caches are absolute-indexed, so a whole prompt
        chunk can be scattered and attended in one forward; windowed
        (ring-buffer) variants must replay token-by-token."""
        return self.cfg.window is None

    def prefill_step(self, params, tokens, caches, cache_len, n_valid):
        """tokens (B, C) -> (logits (B, C, V), new caches).  ``cache_len``
        is NOT advanced here — the engine owns slot bookkeeping."""
        c = self.cfg
        x = Embedding(c.v_padded, c.d_model, c.dtype)(params["embed"]["tok"], tokens)

        def body(x, xs):
            bp, cache = xs
            bp = unshard_fsdp(bp)
            y, new_cache = self.block.prefill(bp, x, cache, cache_len, n_valid)
            return y, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
        h = RMSNorm(c.d_model, c.norm_eps, c.dtype)(params["head"]["norm"], x)
        return self._head(params, h), new_caches

    def forward_gemm_specs(self):
        """(name, m, k) of every weight-stationary forward projection of one
        token — the GEMMs ``photonics.forward_matmul`` routes, consumed by
        ``sim.pipeline.forward_workload``.  MoE counts router + the top-k
        (+ shared) expert FFNs actually streamed per token."""
        c = self.cfg
        hd = c.head_dim or c.d_model // c.n_heads
        per_layer = []
        if c.mla is not None:
            m = c.mla
            per_layer += [
                ("attn.q_down", m.q_lora_rank, c.d_model),
                ("attn.q_up", c.n_heads * (m.qk_nope_dim + m.qk_rope_dim), m.q_lora_rank),
                ("attn.kv_down", m.kv_lora_rank + m.qk_rope_dim, c.d_model),
                ("attn.o", c.d_model, c.n_heads * m.v_head_dim),
            ]
        else:
            per_layer += [
                ("attn.q", c.n_heads * hd, c.d_model),
                ("attn.k", c.n_kv_heads * hd, c.d_model),
                ("attn.v", c.n_kv_heads * hd, c.d_model),
                ("attn.o", c.d_model, c.n_heads * hd),
            ]
        if c.moe is not None:
            mo = c.moe
            ff = mo.top_k * mo.d_ff_expert
            if mo.n_shared_experts:
                ff += mo.n_shared_experts * (mo.d_ff_shared or mo.d_ff_expert)
            per_layer.append(("ffn.router", mo.n_experts, c.d_model))
        else:
            ff = c.d_ff
        per_layer += [
            ("ffn.gate", ff, c.d_model),
            ("ffn.up", ff, c.d_model),
            ("ffn.down", c.d_model, ff),
        ]
        specs = []
        for i in range(c.n_layers):
            specs += [(f"blocks[{i}].{n}", m, k) for (n, m, k) in per_layer]
        specs.append(("head.unembed", c.v_padded, c.d_model))
        return specs
