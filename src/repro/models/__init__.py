from repro.models.base import DFAModel, SavedSegment, SegmentSpec, cross_entropy_loss
from repro.models.mamba import MambaConfig, MambaLM
from repro.models.mlp import MLPClassifier
from repro.models.recurrentgemma import RecurrentGemmaConfig, RecurrentGemmaLM
from repro.models.transformer import (
    MLASettings,
    MoESettings,
    TransformerConfig,
    TransformerLM,
    VisionSettings,
)
from repro.models.whisper import WhisperConfig, WhisperModel

__all__ = [
    "DFAModel", "SavedSegment", "SegmentSpec", "cross_entropy_loss",
    "MambaConfig", "MambaLM", "MLPClassifier",
    "RecurrentGemmaConfig", "RecurrentGemmaLM",
    "MLASettings", "MoESettings", "TransformerConfig", "TransformerLM",
    "VisionSettings", "WhisperConfig", "WhisperModel",
]
