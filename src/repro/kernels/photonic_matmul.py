"""Pallas TPU kernel: photonic weight-bank matrix product.

Computes  C = A @ Bᵀ (+ bank read-noise)  where A:(T,K) are the
amplitude-encoded inputs (DFA error vectors) and B:(M,K) is the inscribed
weight panel.  This is the TPU realisation of the paper's M×N MRR bank +
balanced photodetectors (DESIGN.md §2):

* HBM→VMEM tiles play the role of weight-bank panels; the grid's K steps are
  the GeMM compiler's "operational cycles".
* Tiles are MXU-aligned (multiples of 128) instead of physical bank width;
  noise is drawn per K-step with variance σ²·(block_k/bank_cols) so the
  accumulated statistics match block_k/bank_cols physical bank passes.
* Noise modes:
    - "none"  : ideal hardware (exact matmul) — CPU-validatable.
    - "input" : total accumulated noise streamed as an operand (one draw per
                output element) — CPU-validatable bit-exactly vs ref.py.
    - "prng"  : on-chip noise from the TPU PRNG (Box–Muller over
                pltpu.prng_random_bits) — the zero-copy production path.
                (In interpret mode the PRNG stub yields zero bits ⇒ zero
                noise ⇒ output equals the exact product, which is exactly
                what the structural test asserts.)

Accumulation is f32 in a VMEM scratch tile regardless of operand dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _uniform_from_bits(bits):
    """uint32 -> uniform [0, 1) float32 using 24 high bits."""
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


def _gaussian_tile(shape):
    """Box–Muller gaussian from the on-core PRNG (seed must be set)."""
    u1 = _uniform_from_bits(pltpu.prng_random_bits(shape))
    u2 = _uniform_from_bits(pltpu.prng_random_bits(shape))
    # log(1-u1): u1 in [0,1) keeps the argument in (0,1]; zero bits -> z=0.
    r = jnp.sqrt(-2.0 * jnp.log1p(-u1))
    return r * jnp.cos(2.0 * jnp.pi * u2)


def _kernel(a_ref, b_ref, *rest, nk: int, noise_mode: str,
            sigma_step: float, out_dtype):
    """rest = [noise_ref?], [seed_ref?], o_ref, acc_ref (positional layout)."""
    idx = 0
    noise_ref = None
    seed_ref = None
    if noise_mode == "input":
        noise_ref = rest[idx]
        idx += 1
    if noise_mode == "prng":
        seed_ref = rest[idx]
        idx += 1
    o_ref = rest[idx]
    acc_ref = rest[idx + 1]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    part = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    if noise_mode == "prng" and sigma_step > 0.0:
        i = pl.program_id(0)
        j = pl.program_id(1)
        nm = pl.num_programs(1)
        pltpu.prng_seed(seed_ref[0] + (i * nm + j) * nk + k)
        part = part + sigma_step * _gaussian_tile(part.shape)
    acc_ref[...] += part

    @pl.when(k == nk - 1)
    def _done():
        out = acc_ref[...]
        if noise_mode == "input":
            out = out + noise_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(out_dtype)


def photonic_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    noise: jax.Array | None = None,
    seed: jax.Array | None = None,
    sigma_step: float = 0.0,
    block_t: int = 128,
    block_m: int = 128,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ Bᵀ with optional bank noise.  A:(T,K) B:(M,K) → (T,M).

    Shapes must be multiples of the block sizes (ops.py pads).  Exactly one
    of {noise (T,M) array, seed scalar (with sigma_step>0)} selects the
    noise mode; neither ⇒ ideal hardware.
    """
    t, k_dim = a.shape
    m, kb = b.shape
    assert k_dim == kb, (a.shape, b.shape)
    block_t = min(block_t, t)
    block_m = min(block_m, m)
    block_k = min(block_k, k_dim)
    assert t % block_t == 0 and m % block_m == 0 and k_dim % block_k == 0
    nt, nm, nk = t // block_t, m // block_m, k_dim // block_k
    out_dtype = out_dtype or a.dtype

    if noise is not None:
        noise_mode = "input"
    elif seed is not None:
        # prng structure (seed operand, SMEM spec, grid) is kept even at
        # sigma_step == 0 — the kernel skips the PRNG draw but the zero-noise
        # interpret path still validates the real operand layout
        noise_mode = "prng"
    else:
        noise_mode = "none"

    in_specs = [
        pl.BlockSpec((block_t, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (j, k)),
    ]
    operands = [a, b]
    if noise_mode == "input":
        in_specs.append(pl.BlockSpec((block_t, block_m), lambda i, j, k: (i, j)))
        operands.append(noise)
    if noise_mode == "prng":
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        operands.append(jnp.asarray(seed, jnp.int32).reshape(1))

    kern = functools.partial(
        _kernel, nk=nk, noise_mode=noise_mode, sigma_step=sigma_step,
        out_dtype=out_dtype,
    )

    return pl.pallas_call(
        kern,
        grid=(nt, nm, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_t, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, m), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_t, block_m), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)


def vmem_bytes(block_t: int, block_m: int, block_k: int, itemsize: int = 4) -> int:
    """Working-set estimate for BlockSpec selection (must fit ~16 MB VMEM)."""
    return (
        block_t * block_k * itemsize  # A tile
        + block_m * block_k * itemsize  # B tile
        + 2 * block_t * block_m * 4  # acc scratch + out tile
        + block_t * block_m * itemsize  # noise tile (worst case)
    )
