"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def photonic_matmul_ref(a, b, *, noise=None):
    """C = A @ Bᵀ (+ noise).  a:(T,K) b:(M,K) noise:(T,M)|None."""
    out = jnp.einsum("tk,mk->tm", a.astype(jnp.float32), b.astype(jnp.float32))
    if noise is not None:
        out = out + noise.astype(jnp.float32)
    return out.astype(a.dtype)


def dfa_gradient_ref(a, b, mask, *, noise=None):
    """δ = (A @ Bᵀ + η) ⊙ mask."""
    out = jnp.einsum("tk,mk->tm", a.astype(jnp.float32), b.astype(jnp.float32))
    if noise is not None:
        out = out + noise.astype(jnp.float32)
    out = out * mask.astype(jnp.float32)
    return out.astype(a.dtype)


def total_noise(key, shape, k_dim: int, cfg, dtype=jnp.float32):
    """Draw the accumulated bank noise for a (T,M) output with contraction
    length k_dim — shared by ops.py ("input" mode) and the reference path."""
    from repro.core import photonics

    sigma = photonics.noise_sigma_total(k_dim, 1.0, 1.0, cfg)
    return sigma * jax.random.normal(key, shape, dtype=dtype)
