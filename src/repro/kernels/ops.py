"""Public jit'd wrappers around the Pallas kernels.

Handles: operand normalisation to the photonic [-1,1] range, fake-quant,
padding to block multiples, noise-mode selection, and rescaling — so callers
see the same semantics as ``repro.core.photonics.photonic_matmul`` (the
pure-JAX path) but executed by the weight-bank kernel.

The kernel implements the *abstract* noise model (σ per MAC/block) only:
device-level effects carried by ``PhotonicConfig.mrr`` — Lorentzian
transfer, thermal crosstalk, resonance drift — are the "emu" backend's
domain (``repro.hardware.channel``) and are intentionally ignored here, so
ref↔pallas equivalence is exact and perf comparisons stay apples-to-apples.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core import photonics
from repro.kernels import ref as kref
from repro.kernels.dfa_gradient import dfa_gradient_pallas
from repro.kernels.photonic_matmul import photonic_matmul_pallas


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "noise_mode", "block_t", "block_m", "block_k", "interpret"),
)
def photonic_matmul(a, b, cfg, key=None, *, mask=None, noise_mode="auto",
                    block_t=128, block_m=128, block_k=512, interpret=False):
    """Weight-bank product with the paper's noise model, kernel-executed.

    a: (T, K) inputs; b: (M, K) weights; mask: optional (T, M) epilogue.
    noise_mode: auto|none|input|prng — "auto" picks `input` when a key is
    given (reproducible, CPU-validatable) and `none` for ideal hardware.
    """
    t, k_dim = a.shape
    if not cfg.enabled:
        out = a @ b.T
        return out * mask if mask is not None else out

    a_n, b_n, s_a, s_b = photonics.normalise_operands(a, b, cfg)

    if noise_mode == "auto":
        noise_mode = "input" if (cfg.noise_std > 0 and key is not None) else "none"

    a_p = _pad_to(_pad_to(a_n, block_t, 0), block_k, 1)
    b_p = _pad_to(_pad_to(b_n, block_m, 0), block_k, 1)
    bt = min(block_t, a_p.shape[0])
    bm = min(block_m, b_p.shape[0])
    bk = min(block_k, a_p.shape[1])

    noise = None
    seed = None
    sigma_step = 0.0
    if noise_mode == "input":
        noise = kref.total_noise(key, (a_p.shape[0], b_p.shape[0]), k_dim, cfg)
    elif noise_mode == "prng":
        from jax.experimental.pallas import tpu as pltpu

        nk = a_p.shape[1] // bk
        sigma_total = photonics.noise_sigma_total(k_dim, 1.0, 1.0, cfg)
        # host math on config floats, not a device sync
        sigma_step = float(sigma_total / math.sqrt(nk))  # lint: disable=RL002
        seed = (
            jax.random.key_data(key)[-1].astype(jnp.int32)
            if key is not None
            else jnp.int32(0)
        )
        if interpret:
            # pltpu PRNG primitives need the TPU-semantics interpreter
            # (bits come back zero there — structure-only validation).
            _InterpretParams = getattr(pltpu, "InterpretParams", None)
            if _InterpretParams is not None:
                interpret = _InterpretParams()
            else:
                # jax < 0.5: the plain interpreter has no prng_seed rule.
                # sigma_step=0 skips the PRNG ops inside the kernel while
                # keeping the full prng-mode operand/grid structure — same
                # zero-noise contract the TPU-semantics interpreter gives.
                sigma_step = 0.0

    if mask is not None:
        m_p = _pad_to(_pad_to(mask, bt, 0), bm, 1)
        out = dfa_gradient_pallas(
            a_p, b_p, m_p, noise=noise, seed=seed, sigma_step=sigma_step,
            block_t=bt, block_m=bm, block_k=bk, out_dtype=jnp.float32,
            interpret=interpret,
        )
    else:
        out = photonic_matmul_pallas(
            a_p, b_p, noise=noise, seed=seed, sigma_step=sigma_step,
            block_t=bt, block_m=bm, block_k=bk, out_dtype=jnp.float32,
            interpret=interpret,
        )
    out = out[:t, : b.shape[0]] * (s_a * s_b)
    return out.astype(a.dtype)


def dfa_gradient(a, b, mask, cfg, key=None, **kw):
    """Fused δ = (A@Bᵀ + η) ⊙ mask — alias with mandatory mask."""
    return photonic_matmul(a, b, cfg, key, mask=mask, **kw)
