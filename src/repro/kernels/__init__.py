from repro.kernels import ops, ref
from repro.kernels.dfa_gradient import dfa_gradient_pallas
from repro.kernels.photonic_matmul import photonic_matmul_pallas

__all__ = ["ops", "ref", "dfa_gradient_pallas", "photonic_matmul_pallas"]
