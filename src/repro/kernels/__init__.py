from repro.kernels import emu_matmul, ops, ref
from repro.kernels.dfa_gradient import dfa_gradient_pallas
from repro.kernels.emu_matmul import fused_bank_product
from repro.kernels.photonic_matmul import photonic_matmul_pallas

__all__ = [
    "emu_matmul",
    "fused_bank_product",
    "ops",
    "ref",
    "dfa_gradient_pallas",
    "photonic_matmul_pallas",
]
