"""Pallas TPU kernel: fused DFA gradient  δ = (A @ Bᵀ + η) ⊙ g'(a).

This is the paper's full electro-optic circuit in one VMEM pass (Fig. 4b):
the weight-bank product (MRR array + BPDs), the analog read noise, and the
TIA gain stage that implements the Hadamard with g'(a) — fused as a matmul
epilogue so δ never round-trips HBM between the product and the mask.

Same noise modes as photonic_matmul (none / input / prng); the mask is a
mandatory operand tiled like the output.  For ReLU networks the mask is
binary, exactly as the paper notes for the TIA gains.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.photonic_matmul import _CompilerParams, _gaussian_tile


def _kernel(a_ref, b_ref, mask_ref, *rest, nk: int, noise_mode: str,
            sigma_step: float, out_dtype):
    idx = 0
    noise_ref = None
    seed_ref = None
    if noise_mode == "input":
        noise_ref = rest[idx]
        idx += 1
    if noise_mode == "prng":
        seed_ref = rest[idx]
        idx += 1
    o_ref = rest[idx]
    acc_ref = rest[idx + 1]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    part = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    if noise_mode == "prng" and sigma_step > 0.0:
        i = pl.program_id(0)
        j = pl.program_id(1)
        nm = pl.num_programs(1)
        pltpu.prng_seed(seed_ref[0] + (i * nm + j) * nk + k)
        part = part + sigma_step * _gaussian_tile(part.shape)
    acc_ref[...] += part

    @pl.when(k == nk - 1)
    def _done():
        out = acc_ref[...]
        if noise_mode == "input":
            out = out + noise_ref[...].astype(jnp.float32)
        out = out * mask_ref[...].astype(jnp.float32)  # TIA gain epilogue
        o_ref[...] = out.astype(out_dtype)


def dfa_gradient_pallas(
    a: jax.Array,
    b: jax.Array,
    mask: jax.Array,
    *,
    noise: jax.Array | None = None,
    seed: jax.Array | None = None,
    sigma_step: float = 0.0,
    block_t: int = 128,
    block_m: int = 128,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """δ = (A @ Bᵀ + η) ⊙ mask.  A:(T,K) B:(M,K) mask:(T,M) → (T,M)."""
    t, k_dim = a.shape
    m, kb = b.shape
    assert k_dim == kb and mask.shape == (t, m)
    block_t = min(block_t, t)
    block_m = min(block_m, m)
    block_k = min(block_k, k_dim)
    assert t % block_t == 0 and m % block_m == 0 and k_dim % block_k == 0
    nt, nm, nk = t // block_t, m // block_m, k_dim // block_k
    out_dtype = out_dtype or a.dtype

    if noise is not None:
        noise_mode = "input"
    elif seed is not None:
        # keep the prng operand/grid structure even at sigma_step == 0
        # (zero-noise interpret validation — see photonic_matmul.py)
        noise_mode = "prng"
    else:
        noise_mode = "none"

    in_specs = [
        pl.BlockSpec((block_t, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (j, k)),
        pl.BlockSpec((block_t, block_m), lambda i, j, k: (i, j)),
    ]
    operands = [a, b, mask]
    if noise_mode == "input":
        in_specs.append(pl.BlockSpec((block_t, block_m), lambda i, j, k: (i, j)))
        operands.append(noise)
    if noise_mode == "prng":
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        operands.append(jnp.asarray(seed, jnp.int32).reshape(1))

    kern = functools.partial(
        _kernel, nk=nk, noise_mode=noise_mode, sigma_step=sigma_step,
        out_dtype=out_dtype,
    )

    return pl.pallas_call(
        kern,
        grid=(nt, nm, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_t, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, m), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_t, block_m), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
