"""Fused kernel for the emu backend's DAC→ring→ADC hot path.

``hardware.channel.bank_product`` executes the emulated signal chain as a
sequence of jitted ops: one giant einsum materialising EVERY per-panel
partial sum p[t, i, r, q, j] — a tensor ⌈K/bank_cols⌉× the output size —
followed by full-size noise draws, the idle-slot mask, the per-pass ADC
fake-quant, and the digital accumulation.  This module fuses the bus-tiled
panel loop into one kernel invocation per GEMM: each (bus q, bus-cycle j)
slot's Lorentzian transfer, MAC, per-(bus,pass) BPD noise, and ADC
quantisation happen while the partial lives in registers/VMEM, and only
the accumulated (T, M) digital output is ever written back.

Two implementations share the schedule and the PRNG bit-stream:

* ``impl="pallas"`` — a Pallas TPU kernel (grid = row-blocks × output
  row-panels × bus-cycles, f32 VMEM accumulator).  On non-TPU backends it
  runs in the Pallas interpreter (slow — testing only; see ``kernels/ops``
  for the same convention).
* ``impl="xla"``    — the same fused slot loop lowered through
  ``lax.scan``: compiled on every backend, and the fast path for CPU/GPU
  hosts where Mosaic is unavailable.  This is what "compiled fused path"
  means off-TPU in BENCH_emu_kernel.json.

Noise: the unfused path draws per-(bus,pass) thermal and shot noise with
``jax.random.normal`` over the materialised partial tensor.  Here the
draws happen inside the kernel from an inlined threefry2x32 keyed by
(key, slot, element) counters — both impls use the *same* counters, so
pallas and xla noise is bit-identical — and idle padded slots are masked
exactly like the unfused path, keeping ``noise_sigma_total``'s real-panel
accounting (one draw per REAL contraction panel).  Against the unfused
path the noise is statistically identical but not bit-identical (different
PRNG stream); with noise off the two paths agree to f32 tolerance.

Physics boundary: weight *inscription* (heater-DAC quantisation and the
controller's Jacobi crosstalk pre-compensation) is control-plane work
shared verbatim with the unfused path (``channel.effective_deltas``); the
kernel takes the effective drift-perturbed detunings and applies the
photonic part — Lorentzian transfer, dead-ring masking, the MAC, BPD
noise, per-pass ADC — plus the digital accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.lint.runtime import check_finite
from repro.utils import prng

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# ---------------------------------------------------------------------------
# threefry2x32 — inlined so the same counter→bits map runs inside the Pallas
# kernel and in the XLA twin (plain uint32 vector ops, no pltpu PRNG needed,
# so interpret mode draws REAL noise too)
# ---------------------------------------------------------------------------

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, c0, c1):
    """The Threefry-2x32 block cipher (20 rounds): (key, counter) -> two
    independent uint32 words per counter.  Elementwise over broadcastable
    uint32 inputs."""
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(0x1BD11BDA))
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


# Irwin–Hall(4) scale: sum of four 16-bit uniforms has variance
# 4·(65536²−1)/12; √3/65536 normalises it to 1 − 2.3e-10.
_IH4_SCALE = 3.0**0.5 / 65536.0
# counter tweak separating the shot-noise stream from the thermal stream:
# slot counters c0 stay far below 2³¹, so the top bit is free
_SHOT_STREAM = 0x80000000


def counter_gaussian(k0, k1, c0, c1):
    """One standard gaussian per counter: the four 16-bit lanes of the two
    threefry words summed (Irwin–Hall n=4) and rescaled to unit variance.

    Exact mean 0 and variance 1 − 2.3e-10; tails truncate at ±2√3 σ —
    far beyond anything the per-pass ADC resolves, and well inside the
    tolerance of ``noise_sigma_total``'s accounting.  Chosen over
    Box–Muller deliberately: no transcendentals, so it runs inside the
    Pallas kernel without lowering surprises and costs ~an order of
    magnitude less than ``log``+``cos`` over the full partial tensor on
    CPU hosts."""
    b0, b1 = threefry2x32(k0, k1, c0, c1)
    m = jnp.uint32(0xFFFF)
    s = ((b0 & m) + (b0 >> jnp.uint32(16))
         + (b1 & m) + (b1 >> jnp.uint32(16)))
    return (s.astype(jnp.float32) - 131070.0) * _IH4_SCALE


def _adc(part, adc_bits: int | None, amax: float):
    """Per-pass ADC — op-for-op identical to photonics.fake_quant with a
    static amax (full scale = the bank's maximal inner product)."""
    if adc_bits is None:
        return part
    levels = max(2 ** (adc_bits - 1) - 1, 1)
    scaled = jnp.clip(part / amax, -1.0, 1.0) * levels
    return jnp.round(scaled) / levels * amax


def _slot_noise(part, k0, k1, c0, c1, valid, sigma: float, shot: float):
    """Per-(bus,pass) BPD noise for one slot's (..., rows) partials: the
    thermal/read floor + signal-dependent shot noise, masked on idle padded
    slots (``valid``) so accumulated noise counts REAL panels only.  The
    two draws come from disjoint counter streams (``_SHOT_STREAM``); each
    is skipped entirely when its amplitude is statically zero."""
    noise = jnp.zeros_like(part)
    if sigma > 0.0:
        noise = noise + sigma * counter_gaussian(k0, k1, c0, c1)
    if shot > 0.0:
        z_sh = counter_gaussian(k0, k1, c0 ^ jnp.uint32(_SHOT_STREAM), c1)
        noise = noise + shot * jnp.sqrt(jnp.abs(part)) * z_sh
    return part + noise * valid


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------


def _emu_kernel(a_ref, d_ref, *rest, q_buses: int, nj: int, n_panels: int,
                gamma: float, sigma: float, shot: float,
                adc_bits: int | None, amax: float, rows: int, block_t: int,
                has_mask: bool, noisy: bool):
    """rest = [mask_ref?], [seed_ref?], o_ref, acc_ref."""
    idx = 0
    mask_ref = None
    seed_ref = None
    if has_mask:
        mask_ref = rest[idx]
        idx += 1
    if noisy:
        seed_ref = rest[idx]
        idx += 1
    o_ref = rest[idx]
    acc_ref = rest[idx + 1]

    tb = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if noisy:
        k0 = seed_ref[0].astype(jnp.uint32)
        k1 = seed_ref[1].astype(jnp.uint32)
        # element id within the (T, rows) face of this slot: rows is the
        # full bank height, so (t_global, r) is globally unique per slot
        tt = jax.lax.broadcasted_iota(jnp.int32, (block_t, rows), 0)
        rr = jax.lax.broadcasted_iota(jnp.int32, (block_t, rows), 1)
        c1 = ((tb * block_t + tt) * rows + rr).astype(jnp.uint32)

    g2 = gamma * gamma
    for q in range(q_buses):
        a = a_ref[q, 0].astype(jnp.float32)  # (block_t, cols)
        delta = d_ref[0, q, 0].astype(jnp.float32)  # (rows, cols)
        d2 = delta * delta
        w = (d2 - g2) / (d2 + g2)  # Lorentzian BPD transfer
        if has_mask:
            w = w * mask_ref[q]  # fabrication-dead rings read 0
        part = jax.lax.dot_general(
            a, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        if noisy:
            slot = j * q_buses + q  # panel index this (bus, cycle) executes
            c0 = (i * (q_buses * nj) + slot).astype(jnp.uint32)
            valid = (slot < n_panels).astype(jnp.float32)
            part = _slot_noise(part, k0, k1, c0, c1, valid, sigma, shot)
        part = _adc(part, adc_bits, amax)
        acc_ref[...] += part

    @pl.when(j == nj - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def emu_bank_product_pallas(a_t, delta_eff, dead_mask, *, n_panels: int,
                            gamma: float, sigma: float, shot: float,
                            adc_bits: int | None, amax: float,
                            seed=None, block_t: int = 128,
                            interpret: bool = False):
    """One fused kernel invocation for a whole bus-tiled GEMM.

    a_t: (T, Q, NJ, C) tiled inputs; delta_eff: (nm, Q, rows, NJ, C)
    effective detunings; dead_mask: (Q, rows, C) survival mask or None.
    Returns the accumulated (T, nm*rows) digital output (caller slices M).
    """
    t, q_buses, nj, cols = a_t.shape
    nm, _q, rows, _nj, _c = delta_eff.shape
    noisy = sigma > 0.0 or shot > 0.0
    if noisy and seed is None:
        raise ValueError("noisy fused bank requires a PRNG seed")

    # TPU-friendly layouts: last two dims of every block are the big ones
    a_k = jnp.moveaxis(a_t, 0, 2)  # (Q, NJ, T, C)
    rem = (-t) % block_t
    if rem:
        a_k = jnp.pad(a_k, ((0, 0), (0, 0), (0, rem), (0, 0)))
    t_pad = t + rem
    bt = min(block_t, t_pad)
    d_k = jnp.moveaxis(delta_eff, 2, 3)  # (nm, Q, NJ, rows, C)

    in_specs = [
        pl.BlockSpec((q_buses, 1, bt, cols), lambda tb, i, j: (0, j, tb, 0)),
        pl.BlockSpec((1, q_buses, 1, rows, cols),
                     lambda tb, i, j: (i, 0, j, 0, 0)),
    ]
    operands = [a_k, d_k]
    if dead_mask is not None:
        in_specs.append(pl.BlockSpec((q_buses, rows, cols),
                                     lambda tb, i, j: (0, 0, 0)))
        operands.append(dead_mask)
    if noisy:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        operands.append(jnp.asarray(seed, jnp.uint32).astype(jnp.int32))

    kern = functools.partial(
        _emu_kernel, q_buses=q_buses, nj=nj, n_panels=n_panels, gamma=gamma,
        sigma=sigma, shot=shot, adc_bits=adc_bits, amax=amax, rows=rows,
        block_t=bt, has_mask=dead_mask is not None, noisy=noisy)

    out = pl.pallas_call(
        kern,
        grid=(t_pad // bt, nm, nj),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, rows), lambda tb, i, j: (tb, i)),
        out_shape=jax.ShapeDtypeStruct((t_pad, nm * rows), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, rows), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:t]


# ---------------------------------------------------------------------------
# XLA twin — the same slot decomposition, slot-major batched dot_general
# ---------------------------------------------------------------------------


def emu_bank_product_xla(a_t, delta_eff, dead_mask, *, n_panels: int,
                         gamma: float, sigma: float, shot: float,
                         adc_bits: int | None, amax: float, seed=None):
    """Compiled-everywhere realisation of the fused panel loop.

    Where the unfused path's ``einsum("tqjc,iqrjc->tirqj")`` decomposes
    into ⌈M/rows⌉·Q·NJ *tiny* (T×C)·(C×rows) products — pathological for
    XLA:CPU's GEMM — this lowers the identical math as ONE batched
    ``dot_general`` over the n_panels slot axis with (T, C, nm·rows)
    per-slot shapes, and the noise + ADC epilogue as a single vectorised
    pass XLA fuses into the consumer (one threefry draw per element,
    not one ``random.normal`` sub-launch per scan step).  Same counter
    scheme as the Pallas kernel ⇒ bit-identical noise."""
    t, q_buses, nj, cols = a_t.shape
    nm, _q, rows, _nj, _c = delta_eff.shape
    noisy = sigma > 0.0 or shot > 0.0
    if noisy and seed is None:
        raise ValueError("noisy fused bank requires a PRNG seed")

    g2 = gamma * gamma
    d2 = jnp.square(delta_eff)
    w = (d2 - g2) / (d2 + g2)
    if dead_mask is not None:
        w = w * dead_mask[None, :, :, None, :]
    n_slots = q_buses * nj
    m_pad = nm * rows
    # slot-major layouts: slot s = j·Q + q (cycle-major, matching the
    # emulator's panel→(bus, cycle) assignment and the kernel's counters)
    a_sl = a_t.transpose(2, 1, 0, 3).reshape(n_slots, t, cols)
    w_sl = w.transpose(3, 1, 0, 2, 4).reshape(n_slots, m_pad, cols)
    part = jax.lax.dot_general(
        a_sl, w_sl, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)  # (S, T, m_pad)

    if noisy:
        k0 = jnp.asarray(seed, jnp.uint32)[0]
        k1 = jnp.asarray(seed, jnp.uint32)[1]
        # counters off the (S, T, nm, rows) view: the kernel's (i, slot)
        # and (t_global, r) ids fall straight out of the iotas — no
        # integer div/mod, which XLA:CPU scalarises (no SIMD idiv) at
        # several× the cost of the threefry itself
        shape4 = (n_slots, t, nm, rows)
        ss = jax.lax.broadcasted_iota(jnp.int32, shape4, 0)
        tt = jax.lax.broadcasted_iota(jnp.int32, shape4, 1)
        ii = jax.lax.broadcasted_iota(jnp.int32, shape4, 2)
        rr = jax.lax.broadcasted_iota(jnp.int32, shape4, 3)
        c0 = (ii * n_slots + ss).astype(jnp.uint32)
        c1 = (tt * rows + rr).astype(jnp.uint32)
        valid = (ss < n_panels).astype(jnp.float32)
        part = _slot_noise(part.reshape(shape4), k0, k1, c0, c1, valid,
                           sigma, shot).reshape(n_slots, t, m_pad)
    part = _adc(part, adc_bits, amax)
    return jnp.sum(part, axis=0)  # digital accumulation over all slots


# ---------------------------------------------------------------------------
# bank_product drop-in
# ---------------------------------------------------------------------------


def fused_bank_product(a_n, b_n, cfg, key=None, *, residual=None,
                       impl: str = "xla", block_t: int = 128,
                       interpret: bool | None = None):
    """Drop-in for ``hardware.channel.bank_product`` on the fused path.

    a_n: (T, K), b_n: (M, K) normalised operands -> (T, M) in bank output
    units (the caller rescales by s_a·s_b, exactly as for the unfused
    path).  ``impl``: "pallas" (TPU kernel; interpret-mode fallback off
    TPU) or "xla" (the scan twin, compiled everywhere).
    """
    from repro.hardware import channel  # lazy: channel lazily imports us
    from repro.hardware import mrr

    device = cfg.mrr or mrr.MRRConfig()
    t = a_n.shape[0]
    m = b_n.shape[0]
    a_t, b_t, n_panels = channel.tile_operands(a_n, b_n, cfg)
    residual = channel.alive_residual(residual, cfg)
    delta_eff = channel.effective_deltas(b_t, cfg, residual)
    dead_mask = channel.alive_dead_ring_mask(cfg)

    sigma = channel._per_pass_sigma(cfg)
    shot = device.shot_noise
    noisy = sigma > 0.0 or shot > 0.0
    seed = None
    if noisy:
        if key is None:
            raise ValueError("noisy emulated bank requires a PRNG key")
        seed = (jax.random.key_data(prng.consume(key))
                .reshape(-1)[-2:].astype(jnp.uint32))

    kwargs = dict(n_panels=n_panels, gamma=float(device.gamma),
                  sigma=float(sigma), shot=float(shot),
                  adc_bits=device.adc_bits, amax=float(cfg.bank_cols),
                  seed=seed)
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = emu_bank_product_pallas(a_t, delta_eff, dead_mask,
                                      block_t=block_t, interpret=interpret,
                                      **kwargs)
    elif impl == "xla":
        out = emu_bank_product_xla(a_t, delta_eff, dead_mask, **kwargs)
    else:
        raise ValueError(f"unknown fused impl {impl!r} (pallas | xla)")
    return check_finite(out[:t, :m], f"fused_bank_product[{impl}] output")
