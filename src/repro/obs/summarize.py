"""Render an obs metrics JSONL file as a table (and optionally a
BENCH-style report).

  PYTHONPATH=src python -m repro.obs.summarize run-metrics.jsonl
  PYTHONPATH=src python -m repro.obs.summarize run-metrics.jsonl \
      --bench-json bench-out       # writes BENCH_obs_summary.json

The input is what ``obs.metrics.JsonlSink`` wrote: one JSON object per
line, ``{"t": unix, "step": int|null, "metrics": {name: value}}``.  Every
metric is aggregated over the file (count / mean / p50 / p99 / min / max
/ last) with the same linear-interpolation percentiles the registry's
histograms use.  Runs probed with ``--probe-every`` additionally get an
**alignment table** (per-layer DFA-vs-BP cosine: first / last / Δ over
the run) and a **noise-budget table** (per-source share of the emu
backend's observed error power, the Σ/total closure, and the
thermal-vs-analytic cross-check).  ``--bench-json`` serializes the
aggregate through ``repro.bench.write_bench`` — the exact schema CI
validates for every other BENCH_*.json — so a metrics log can join the
perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import math

from repro.obs.metrics import Histogram


def read_rows(path: str) -> list[dict]:
    """Parse a metrics JSONL file, tolerating a torn trailing line (a run
    killed mid-write): corrupt lines at the end are dropped, a corrupt
    line with valid rows after it raises (that file is truly damaged)."""
    rows = []
    bad_at = None
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                if bad_at is None:
                    bad_at = i
                continue
            if bad_at is not None:
                raise ValueError(
                    f"{path}: corrupt JSONL at line {bad_at + 1} "
                    "followed by valid rows")
            rows.append(row)
    return rows


def aggregate(rows: list[dict]) -> dict[str, dict]:
    """metric name -> {count, mean, p50, p99, min, max, last} over the
    file, insertion-ordered by first appearance."""
    hists: dict[str, Histogram] = {}
    last: dict[str, float] = {}
    for row in rows:
        for name, v in row.get("metrics", {}).items():
            v = float(v)
            if not math.isfinite(v):
                continue
            if name not in hists:
                hists[name] = Histogram(name, window=1 << 20)
            hists[name].observe(v)
            last[name] = v
    out = {}
    for name, h in hists.items():
        s = h.summary()
        s["last"] = last[name]
        out[name] = s
    return out


def render(table: dict[str, dict], steps: int, out=print) -> None:
    cols = ("count", "mean", "p50", "p99", "min", "max", "last")
    width = max((len(n) for n in table), default=6)
    out(f"{'metric':<{width}}  " + "  ".join(f"{c:>12}" for c in cols))
    for name, stats in table.items():
        cells = []
        for c in cols:
            v = stats[c]
            cells.append(f"{int(v):>12d}" if c == "count"
                         else f"{v:>12.6g}")
        out(f"{name:<{width}}  " + "  ".join(cells))
    out(f"({steps} logged rows)")


def alignment_table(rows: list[dict]) -> dict[str, dict]:
    """Per ``align_*`` series: first / last / Δ over the run — the probe's
    headline "is DFA aligning" view.  Empty for unprobed runs."""
    series: dict[str, list[float]] = {}
    for row in rows:
        for name, v in row.get("metrics", {}).items():
            if name.startswith("align_") and math.isfinite(float(v)):
                series.setdefault(name, []).append(float(v))
    return {name: {"first": vals[0], "last": vals[-1],
                   "delta": vals[-1] - vals[0], "samples": len(vals)}
            for name, vals in series.items()}


def render_alignment(table: dict[str, dict], out=print) -> None:
    width = max(len(n) for n in table)
    out("")
    out("alignment (DFA-vs-BP cosine)")
    out(f"{'series':<{width}}  " + "  ".join(
        f"{c:>10}" for c in ("first", "last", "delta", "samples")))
    for name, s in table.items():
        out(f"{name:<{width}}  {s['first']:>10.4f}  {s['last']:>10.4f}  "
            f"{s['delta']:>+10.4f}  {s['samples']:>10d}")


def noise_budget_table(rows: list[dict]) -> dict:
    """Last ``nb_*`` row -> per-source share of the observed error power,
    plus the Σ/total closure and the thermal-vs-analytic cross-check.
    Empty for runs without attribution rows (non-emu backends)."""
    last: dict = {}
    for row in rows:
        m = row.get("metrics", {})
        if "nb_total_var" in m:
            last = m
    if not last:
        return {}
    total = float(last["nb_total_var"])
    sources = {}
    for k, v in last.items():
        if (k.startswith("nb_") and k.endswith("_var")
                and k not in ("nb_total_var", "nb_sum_var")):
            v = float(v)
            sources[k[3:-4]] = {
                "var": v, "share": v / total if total > 0 else float("nan")}
    return {"sources": sources, "total_var": total,
            "closure": float(last.get("nb_closure", float("nan"))),
            "thermal_vs_analytic": float(
                last.get("nb_thermal_vs_analytic", float("nan")))}


def render_noise_budget(nb: dict, out=print) -> None:
    out("")
    out("noise budget (emu backend, error power vs ideal twin)")
    width = max(len(n) for n in nb["sources"])
    out(f"{'source':<{width}}  {'var':>12}  {'share':>8}")
    ordered = sorted(nb["sources"].items(),
                     key=lambda kv: -kv[1]["var"])
    for name, s in ordered:
        out(f"{name:<{width}}  {s['var']:>12.6g}  {s['share']:>7.1%}")
    out(f"{'total':<{width}}  {nb['total_var']:>12.6g}  "
        f"closure(Σ/total)={nb['closure']:.3f}")
    if math.isfinite(nb["thermal_vs_analytic"]):
        out(f"thermal measured/analytic sigma ratio: "
            f"{nb['thermal_vs_analytic']:.3f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="metrics JSONL written by obs.JsonlSink")
    ap.add_argument("--bench-json", default=None, metavar="DIR",
                    help="also write the aggregate as "
                         "BENCH_obs_summary.json to DIR")
    args = ap.parse_args(argv)

    rows = read_rows(args.path)
    if not rows:
        print(f"{args.path}: no metric rows")
        return 1
    table = aggregate(rows)
    render(table, len(rows))
    align = alignment_table(rows)
    if align:
        render_alignment(align)
    nb = noise_budget_table(rows)
    if nb:
        render_noise_budget(nb)
    if args.bench_json:
        from repro.bench import write_bench

        flat = {}
        for name, stats in table.items():
            for stat in ("mean", "p50", "p99", "last"):
                flat[f"{name}_{stat}"] = stats[stat]
        path = write_bench("obs_summary", flat,
                           meta={"source": args.path, "rows": len(rows)},
                           out_dir=args.bench_json)
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
