"""Render an obs metrics JSONL file as a table (and optionally a
BENCH-style report).

  PYTHONPATH=src python -m repro.obs.summarize run-metrics.jsonl
  PYTHONPATH=src python -m repro.obs.summarize run-metrics.jsonl \
      --bench-json bench-out       # writes BENCH_obs_summary.json

The input is what ``obs.metrics.JsonlSink`` wrote: one JSON object per
line, ``{"t": unix, "step": int|null, "metrics": {name: value}}``.  Every
metric is aggregated over the file (count / mean / p50 / p99 / min / max
/ last) with the same linear-interpolation percentiles the registry's
histograms use.  ``--bench-json`` serializes the aggregate through
``repro.bench.write_bench`` — the exact schema CI validates for every
other BENCH_*.json — so a metrics log can join the perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import math

from repro.obs.metrics import Histogram


def read_rows(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def aggregate(rows: list[dict]) -> dict[str, dict]:
    """metric name -> {count, mean, p50, p99, min, max, last} over the
    file, insertion-ordered by first appearance."""
    hists: dict[str, Histogram] = {}
    last: dict[str, float] = {}
    for row in rows:
        for name, v in row.get("metrics", {}).items():
            v = float(v)
            if not math.isfinite(v):
                continue
            if name not in hists:
                hists[name] = Histogram(name, window=1 << 20)
            hists[name].observe(v)
            last[name] = v
    out = {}
    for name, h in hists.items():
        s = h.summary()
        s["last"] = last[name]
        out[name] = s
    return out


def render(table: dict[str, dict], steps: int, out=print) -> None:
    cols = ("count", "mean", "p50", "p99", "min", "max", "last")
    width = max((len(n) for n in table), default=6)
    out(f"{'metric':<{width}}  " + "  ".join(f"{c:>12}" for c in cols))
    for name, stats in table.items():
        cells = []
        for c in cols:
            v = stats[c]
            cells.append(f"{int(v):>12d}" if c == "count"
                         else f"{v:>12.6g}")
        out(f"{name:<{width}}  " + "  ".join(cells))
    out(f"({steps} logged rows)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="metrics JSONL written by obs.JsonlSink")
    ap.add_argument("--bench-json", default=None, metavar="DIR",
                    help="also write the aggregate as "
                         "BENCH_obs_summary.json to DIR")
    args = ap.parse_args(argv)

    rows = read_rows(args.path)
    if not rows:
        print(f"{args.path}: no metric rows")
        return 1
    table = aggregate(rows)
    render(table, len(rows))
    if args.bench_json:
        from repro.bench import write_bench

        flat = {}
        for name, stats in table.items():
            for stat in ("mean", "p50", "p99", "last"):
                flat[f"{name}_{stat}"] = stats[stat]
        path = write_bench("obs_summary", flat,
                           meta={"source": args.path, "rows": len(rows)},
                           out_dir=args.bench_json)
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
