"""Lightweight metrics: counters, gauges, histograms, pluggable sinks.

The registry is the host-side half of a jit-safe metrics pipeline.  The
contract with jitted code (``train.Trainer._train_step``, the serve
engine's jitted phases) is: metrics computed on device are *returned* from
the step as arrays in a dict — never read inside the step — and the fit
loop drains the whole dict with ONE batched ``jax.device_get`` per logging
interval (``Registry.record``), so observability costs one host sync per
interval instead of one per scalar (the seed's ``{k: float(v)}`` pattern).

Sinks receive one row per ``record``/``emit`` call::

    {"t": <unix seconds>, "step": <int | None>, "metrics": {name: float}}

* ``MemorySink`` — bounded ring (introspection, tests, live dashboards)
* ``JsonlSink``  — one JSON object per line; ``repro.obs.summarize``
  renders the file back into bench-style tables

Histograms keep a bounded sample window and compute linear-interpolation
percentiles (the numpy default — tests cross-check against
``np.percentile``).
"""

from __future__ import annotations

import collections
import json
import math
import os
import time


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-value-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded sample window with numpy-compatible percentiles."""

    __slots__ = ("name", "values")

    def __init__(self, name: str, window: int = 8192):
        self.name = name
        self.values: collections.deque = collections.deque(maxlen=window)

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def __len__(self) -> int:
        return len(self.values)

    def percentile(self, q: float) -> float:
        """Linear-interpolation percentile (numpy's default method).
        ``q`` in [0, 100]."""
        if not self.values:
            raise ValueError(f"histogram {self.name!r} is empty")
        srt = sorted(self.values)
        rank = (q / 100.0) * (len(srt) - 1)
        lo = math.floor(rank)
        hi = math.ceil(rank)
        if lo == hi:
            return srt[int(rank)]
        frac = rank - lo
        return srt[lo] * (1.0 - frac) + srt[hi] * frac

    def summary(self) -> dict:
        srt = sorted(self.values)
        n = len(srt)
        return {
            "count": float(n),
            "mean": sum(srt) / n if n else float("nan"),
            "p50": self.percentile(50) if n else float("nan"),
            "p99": self.percentile(99) if n else float("nan"),
            "min": srt[0] if n else float("nan"),
            "max": srt[-1] if n else float("nan"),
        }


class MemorySink:
    """In-memory ring of the last ``capacity`` rows."""

    def __init__(self, capacity: int = 4096):
        self.rows: collections.deque = collections.deque(maxlen=capacity)

    def write(self, row: dict) -> None:
        self.rows.append(row)

    def close(self) -> None:
        pass


class JsonlSink:
    """Append-only JSONL file, one row per line (crash-safe: every row is
    flushed, so a killed run keeps everything logged so far, and a
    partial trailing line from a hard kill is truncated away on the next
    append-open — the file is parseable JSONL at every point in its
    life)."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._truncate_partial_tail(path)
        self._f = open(path, "a")

    @staticmethod
    def _truncate_partial_tail(path: str) -> None:
        """Drop an unterminated final line left by a run killed mid-write
        (every complete row ends in a newline, so anything after the last
        one is a torn write)."""
        try:
            size = os.path.getsize(path)
        except OSError:
            return
        if size == 0:
            return
        with open(path, "rb+") as f:
            f.seek(-1, os.SEEK_END)
            if f.read(1) == b"\n":
                return
            f.seek(0)
            data = f.read()
            f.truncate(data.rfind(b"\n") + 1)

    def write(self, row: dict) -> None:
        self._f.write(json.dumps(row) + "\n")
        self._f.flush()

    def flush(self) -> None:
        if not self._f.closed:
            self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class Registry:
    """Named counters/gauges/histograms plus the sink fan-out."""

    def __init__(self, sinks: list | None = None):
        self.sinks = list(sinks or [])
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    # ---- instruments ----
    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter(name)
        return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge(name)
        return self._gauges[name]

    def histogram(self, name: str, window: int = 8192) -> Histogram:
        if name not in self._hists:
            self._hists[name] = Histogram(name, window)
        return self._hists[name]

    # ---- the batched drain ----
    @staticmethod
    def drain(scalars) -> dict:
        """Device metrics dict -> host float dict in ONE batched transfer.

        ``scalars`` may hold jax arrays (drained with a single
        ``jax.device_get`` over the whole dict) or plain host numbers.
        """
        needs_get = any(hasattr(v, "device") or hasattr(v, "devices")
                        for v in scalars.values())
        if needs_get:
            import jax

            scalars = jax.device_get(dict(scalars))
        return {k: float(v) for k, v in scalars.items()}

    def record(self, step, scalars) -> dict:
        """Drain one logging interval's device metrics in a single batched
        transfer and fan the host floats out to gauges + sinks."""
        host = self.drain(scalars)
        for k, v in host.items():
            self.gauge(k).set(v)
        self.emit(step, host)
        return host

    def emit(self, step, metrics: dict) -> None:
        """Write one already-host-side row to every sink."""
        row = {"t": time.time(), "step": None if step is None else int(step),
               "metrics": dict(metrics)}
        for sink in self.sinks:
            sink.write(row)

    # ---- snapshot / teardown ----
    def snapshot(self) -> dict:
        """Flat view of every instrument's current value (histograms as
        their summary stats)."""
        out = {}
        for c in self._counters.values():
            out[c.name] = c.value
        for g in self._gauges.values():
            out[g.name] = g.value
        for h in self._hists.values():
            for stat, v in h.summary().items():
                out[f"{h.name}_{stat}"] = v
        return out

    def flush(self) -> None:
        """Best-effort flush of every sink that buffers (JSONL files) —
        the exception-path half of the crash-safe logging contract."""
        for sink in self.sinks:
            flush = getattr(sink, "flush", None)
            if flush is not None:
                flush()

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()
