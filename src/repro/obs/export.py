"""Chrome-trace (Perfetto) JSON export, including simulated timelines.

Two kinds of timeline meet in one trace file:

* host timelines — whatever a ``TraceRecorder`` collected live (training
  step spans, engine ticks, request lifecycle tracks), stamped on the
  recorder's monotonic clock;
* simulated timelines — ``repro.sim`` discrete-event schedules, stamped
  in *simulated* seconds from zero.  ``pipeline_to_trace`` renders a
  ``PipelineReport``'s per-bus per-stage events as one track per
  (bus, stage) pair, so a photonic schedule (bus fill, ADC occupancy,
  heater epilogue, rerouting around failed buses) is visually
  inspectable in ``chrome://tracing`` / https://ui.perfetto.dev;
  ``serving_to_trace`` renders a serving simulation's rounds and
  per-request lifecycle tracks the same way.

Simulated timelines claim their own pids (process groups) so they never
interleave with host tracks.  ``write`` serializes any recorder to the
JSON object format (``{"traceEvents": [...]}``) both viewers load.
"""

from __future__ import annotations

import json
import os

from repro.obs.trace import TraceRecorder

# process ids for simulated timelines (host events use trace.HOST_PID)
SIM_PIPELINE_PID = 100
SIM_SERVING_PID = 101


def write(recorder: TraceRecorder, path: str) -> str:
    """Serialize the recorder as Perfetto-loadable JSON; returns ``path``."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(recorder.to_chrome(), f)
        f.write("\n")
    return path


def resolve_recorder(trace) -> tuple[TraceRecorder, str | None]:
    """A ``trace=`` argument (recorder | path | None) -> (recorder, path to
    write on completion or None).  ``None`` creates a fresh recorder."""
    if trace is None or isinstance(trace, TraceRecorder):
        return (trace if trace is not None else TraceRecorder()), None
    if isinstance(trace, str):
        return TraceRecorder(), trace
    raise TypeError(f"trace must be a TraceRecorder or a path, got {trace!r}")


def pipeline_to_trace(report, recorder: TraceRecorder | None = None,
                      pid: int = SIM_PIPELINE_PID) -> TraceRecorder:
    """Export a ``sim.pipeline.PipelineReport``'s event timeline as one
    Chrome-trace track per (bus, stage).

    Simulated seconds map to trace microseconds from 0.  Stage tracks are
    ordered in signal order per bus, so the pipeline skew (mod after dac,
    adc last, the off-pipeline heater epilogue) reads top-to-bottom the
    way the paper's Fig. 3 draws it.  Track durations sum to exactly the
    ``stage_busy`` the report's ``occupancy`` was computed from (as long
    as the event sample was not capped — ``sim.pipeline.MAX_EVENTS``).
    """
    rec = recorder if recorder is not None else TraceRecorder()
    stages = _report_stages(report)
    order = {s: i for i, s in enumerate(stages)}
    rec.name_process(pid, f"sim.pipeline ({report.tiling} tiling, "
                          f"{report.n_buses} buses)")
    for bus, stage, start_s, end_s, gemm in report.events:
        tid = bus * len(stages) + order[stage]
        rec.name_thread(pid, tid, f"bus{bus}/{stage}")
        rec.complete(gemm, start_s * 1e6, (end_s - start_s) * 1e6,
                     cat="sim.pipeline", pid=pid, tid=tid, stage=stage,
                     bus=bus)
    for stage, occ in report.occupancy.items():
        rec.counter(f"occupancy/{stage}", {"busy_frac": occ},
                    cat="sim.pipeline", pid=pid, ts_us=0.0)
    rec.instant("pipeline-report", cat="sim.pipeline", pid=pid,
                tid=0, ts_us=report.wall_clock_s * 1e6,
                wall_clock_us=report.wall_clock_s * 1e6,
                macs_per_s=report.macs_per_s,
                utilisation=report.utilisation,
                pj_per_mac=report.pj_per_mac)
    return rec


def _report_stages(report) -> tuple:
    from repro.sim.components import STAGES

    return tuple(STAGES) + ("heater",)


def serving_to_trace(rounds, requests, recorder: TraceRecorder | None = None,
                     pid: int = SIM_SERVING_PID) -> TraceRecorder:
    """Export a serving simulation as round spans + per-request tracks.

    ``rounds``   — (kind, start_s, end_s, tokens, n_slots) tuples
    ``requests`` — dicts with ``id``, ``arrival_s``, ``admit_s``,
                   ``first_token_s``, ``finish_s`` (simulated seconds)
    """
    rec = recorder if recorder is not None else TraceRecorder()
    rec.name_process(pid, "sim.serving")
    rec.name_thread(pid, 1, "rounds")
    for kind, start_s, end_s, tokens, n_slots in rounds:
        rec.complete(kind, start_s * 1e6, (end_s - start_s) * 1e6,
                     cat="sim.serving", pid=pid, tid=1, tokens=tokens,
                     slots=n_slots)
    for r in requests:
        track = f"request-{r['id']}"
        rec.async_begin(track, r["id"], cat="sim.serving", pid=pid,
                        ts_us=r["arrival_s"] * 1e6,
                        prompt_len=r.get("prompt_len", 0),
                        decode_len=r.get("decode_len", 0))
        rec.async_instant("ADMIT", r["id"], cat="sim.serving", pid=pid,
                          ts_us=r["admit_s"] * 1e6)
        if r.get("first_token_s") is not None:
            rec.async_instant("FIRST_TOKEN", r["id"], cat="sim.serving",
                              pid=pid, ts_us=r["first_token_s"] * 1e6)
        rec.async_end(track, r["id"], cat="sim.serving", pid=pid,
                      ts_us=r["finish_s"] * 1e6)
    return rec
