"""Photonic hardware health monitoring: planned vs observed drift.

The emulated MRR bank carries its physical state (OU resonance drift +
the controller's calibration estimate) through training, and the jitted
step already returns the summary scalars host-side (``hw_drift_rms``,
``hw_residual_rms``, ``hw_dead_rings`` — computed on device, drained in
the fit loop's one batched ``device_get`` per logging interval).  The
monitor closes the loop the PR 7 autotuner opened: the schedule search
*planned* a recalibration cadence whose end-of-window residual
(``sim.expected_drift_sigma``) stays under a ``drift_budget``; this
module compares the *observed* residual against that plan every logged
step and raises a warn-level alert the moment the budget is crossed —
the signal that the cadence the tuner picked is no longer holding on the
(simulated) silicon.

Alerts are edge-triggered: one alert per budget crossing, re-armed when
the residual recovers below the budget (a recalibration sweep landing),
so a long excursion is one event, not one per logged step.

Derived gauges per sample:

* ``hw_drift_rms`` / ``hw_residual_rms`` — raw vs uncompensated detuning
* ``hw_expected_sigma`` — the OU prediction for the configured cadence
* ``hw_residual_vs_expected`` — observed/predicted (≈1 means the device
  behaves like the model the autotuner planned against)
* ``hw_effective_bits`` — ``photonics.sigma_to_resolution`` of the
  residual: the resolution the analog path currently delivers
* ``hw_dead_rings`` — rings whose residual exceeds the dead-ring
  threshold (default 3× the stationary drift σ)
* ``hw_failed_buses`` — dead buses the schedule reroutes around
"""

from __future__ import annotations

import dataclasses

# residual threshold (in stationary drift σ) past which a ring counts as
# dead — shared by the trainer's in-step ``hw_dead_rings`` metric and the
# monitor's gauge so the two always agree
DEAD_RING_FACTOR = 3.0


@dataclasses.dataclass(frozen=True)
class HwAlert:
    """One warn-level hardware event."""

    step: int
    kind: str  # "drift_budget"
    value: float  # the observed residual rms
    budget: float
    message: str


class HardwareMonitor:
    """Samples carried hardware state scalars each logged step.

    Parameters
    ----------
    device : hardware.mrr.MRRConfig | None
        The bank's device description (drift σ/τ, cal noise).
    recalibrate_every : int
        The in-situ recalibration cadence the run uses — sets the OU
        residual prediction the observed drift is compared against.
    drift_budget : float | None
        The residual the schedule was planned for (the autotuner's
        ``drift_budget``); defaults to half the stationary drift σ — the
        regime where the drift-recovery benchmarks keep DFA training.
    dead_ring_factor : float
        Residual threshold (in stationary σ) past which a ring counts as
        dead in ``hw_dead_rings``.
    """

    def __init__(self, device, recalibrate_every: int = 0,
                 drift_budget: float | None = None,
                 dead_ring_factor: float = DEAD_RING_FACTOR,
                 n_failed_buses: int = 0):
        from repro.sim.autotune import expected_drift_sigma

        self.device = device
        self.recalibrate_every = int(recalibrate_every)
        sigma = float(getattr(device, "drift_sigma", 0.0) or 0.0)
        if drift_budget is None and sigma > 0:
            drift_budget = 0.5 * sigma
        self.drift_budget = drift_budget
        self.expected_sigma = expected_drift_sigma(device, recalibrate_every)
        self.dead_ring_threshold = dead_ring_factor * sigma
        self.n_failed_buses = int(n_failed_buses)
        self.alerts: list[HwAlert] = []
        self._over_budget = False  # edge-trigger arm

    def sample(self, step: int, scalars: dict) -> dict:
        """Derive the health gauges from one logged step's host scalars
        (must contain ``hw_residual_rms``; the rest are optional) and
        fire the budget alert on a below→above crossing.  Returns the
        gauge dict (empty when the row carries no hardware scalars)."""
        if "hw_residual_rms" not in scalars:
            return {}
        from repro.core.photonics import sigma_to_resolution

        resid = float(scalars["hw_residual_rms"])
        out = {"hw_residual_rms": resid}
        if "hw_drift_rms" in scalars:
            out["hw_drift_rms"] = float(scalars["hw_drift_rms"])
        if "hw_dead_rings" in scalars:
            out["hw_dead_rings"] = float(scalars["hw_dead_rings"])
        out["hw_expected_sigma"] = self.expected_sigma
        if self.expected_sigma > 0:
            out["hw_residual_vs_expected"] = resid / self.expected_sigma
        if resid > 0:
            # the resolution the analog path currently delivers (an ideal
            # zero-residual bank would be unbounded — omit the gauge)
            out["hw_effective_bits"] = sigma_to_resolution(resid)
        out["hw_failed_buses"] = float(self.n_failed_buses)
        if self.drift_budget is not None:
            over = resid > self.drift_budget
            if over and not self._over_budget:
                self.alerts.append(HwAlert(
                    step=int(step), kind="drift_budget", value=resid,
                    budget=self.drift_budget,
                    message=(f"residual drift rms {resid:.4f} exceeds the "
                             f"planned budget {self.drift_budget:.4f} at "
                             f"step {step} (recal cadence "
                             f"{self.recalibrate_every})")))
            self._over_budget = over
        return out
