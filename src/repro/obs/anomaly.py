"""Streaming anomaly detection over metric rows: EWMA center + MAD-proxy
bands, edge-triggered WARN alerts.

A drifting chip, a dying bus, or an alignment collapse all show up as a
*step change* in some already-logged scalar (``hw_residual_rms``,
``align_global``, ``loss``, throughput) long before the loss curve is
obviously wrong.  ``AnomalyDetector`` watches a configurable set of row
keys and keeps, per metric, an exponential moving average of the value
and of its absolute deviation (a cheap streaming stand-in for the median
absolute deviation).  A sample outside ``center ± k·band`` fires ONE
alert at the crossing — like ``hwmon``'s drift-budget alerts, the
detector re-arms only after the metric returns inside the band, so a
sustained excursion is one named event, not a page per row.  Non-finite
samples always alert.

Statistics keep updating while out-of-band: a legitimate level shift
(e.g. loss dropping as training works) converges the center onto the new
level instead of alerting forever.  The ``Observer`` feeds every drained
row through ``observe`` and turns alerts into ``WARN:anomaly:<metric>``
trace instants, an ``anomaly_alerts`` counter, and an
``anomaly_<metric>`` flag on the JSONL row.  Pure host-side float
arithmetic on already-drained scalars — zero device work.
"""

from __future__ import annotations

import dataclasses
import math

# Row keys watched by default: the training signal, the probe's global
# alignment, the hardware drift residual, and throughput-ish gauges.
# Keys absent from a row are simply skipped, so one default serves
# ref/pallas/emu sessions alike.
DEFAULT_WATCH: tuple[str, ...] = (
    "loss", "align_global", "hw_residual_rms", "throughput", "steps_per_s")


@dataclasses.dataclass(frozen=True)
class AnomalyAlert:
    """One edge-triggered band crossing (or non-finite sample)."""

    step: int
    metric: str
    value: float
    center: float
    band: float
    message: str


class _Track:
    __slots__ = ("center", "spread", "n", "over")

    def __init__(self):
        self.center = 0.0
        self.spread = 0.0
        self.n = 0
        self.over = False


class AnomalyDetector:
    """EWMA + MAD-band detector over streaming metric rows.

    alpha: EWMA smoothing for both center and spread; k: band half-width
    in spread units (deviation > k·spread alerts); warmup: rows a metric
    must accumulate before it can alert (the bands need an estimate
    first).
    """

    def __init__(self, watch=DEFAULT_WATCH, *, alpha: float = 0.1,
                 k: float = 8.0, warmup: int = 8):
        self.watch = tuple(watch)
        self.alpha = float(alpha)
        self.k = float(k)
        self.warmup = int(warmup)
        self._tracks: dict[str, _Track] = {}
        self._alerts: list[AnomalyAlert] = []

    @property
    def alerts(self) -> tuple[AnomalyAlert, ...]:
        """Every alert fired over the detector's lifetime."""
        return tuple(self._alerts)

    def observe(self, step: int, scalars: dict) -> list[AnomalyAlert]:
        """Feed one drained row; -> alerts that fired on THIS row."""
        fired: list[AnomalyAlert] = []
        for name in self.watch:
            if name not in scalars:
                continue
            value = float(scalars[name])
            track = self._tracks.setdefault(name, _Track())
            alert = self._observe_one(track, step, name, value)
            if alert is not None:
                fired.append(alert)
        self._alerts.extend(fired)
        return fired

    def _observe_one(self, track, step, name, value):
        if not math.isfinite(value):
            alert = None
            if not track.over:
                alert = AnomalyAlert(
                    step=step, metric=name, value=value,
                    center=track.center, band=self.k * track.spread,
                    message=f"step {step}: {name}={value} is non-finite")
            track.over = True
            return alert  # poison the stats with nothing; keep center

        alert = None
        deviation = abs(value - track.center)
        # floor the band so flat-line series don't page on float jitter
        band = self.k * max(track.spread, 1e-3 * abs(track.center), 1e-9)
        if track.n >= self.warmup:
            outside = deviation > band
            if outside and not track.over:
                alert = AnomalyAlert(
                    step=step, metric=name, value=value,
                    center=track.center, band=band,
                    message=(f"step {step}: {name}={value:.6g} outside "
                             f"{track.center:.6g} ± {band:.6g}"))
            track.over = outside
        if track.n == 0:
            track.center = value
        else:
            a = self.alpha
            track.center = (1.0 - a) * track.center + a * value
            track.spread = (1.0 - a) * track.spread + a * deviation
        track.n += 1
        return alert
