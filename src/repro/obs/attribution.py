"""Photonic noise-budget attribution: decompose the emu backend's output
error into per-source physical contributions.

``noise_budget(e, b, cfg, key, residual=)`` re-runs ONE sampled feedback
panel product (e·Bᵀ, the paper's Eq. 1 projection) through
``hardware.channel.bank_product`` several times:

* a **clean** pass under ``channel.ideal_twin(cfg)`` — same geometry and
  panel schedule, every nonideality off;
* the **full** configured chain (the error power actually observed);
* one **sole-source** pass per ``channel.NOISE_SOURCES`` entry
  (quantization, thermal, shot, ADC, drift residual, crosstalk, dead
  rings) under ``channel.isolate_source``.

All passes share the caller's PRNG key, so a sole-source run sees the
same per-pass noise realisation as the full chain and the error powers
are directly comparable.  Emitted gauges (all mean-square error vs the
clean pass, natural output units):

* ``nb_<source>_var`` per source, ``nb_total_var`` for the full chain;
* ``nb_sum_var`` and ``nb_closure`` = Σ sources / total — for
  independent zero-mean sources this is ≈ 1; the residual IS the gauge.
  A closure drifting from 1 means the noise model grew a coupling (or a
  bug) that the per-source accounting does not capture;
* ``nb_thermal_vs_analytic`` — measured thermal-only error std over
  ``photonics.noise_sigma_total``'s closed-form accounting.  This is the
  canonical consistency check between ``hardware/channel.py``'s sampled
  chain and ``core/photonics.py``'s analytic path: any future edit that
  changes one but not the other moves this ratio off 1.

Everything is pure traceable jnp — ``obs.introspect.AlignmentProbe``
folds it into its single jitted probe function on stateful-hardware
sessions, and tests/benchmarks call it standalone.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import photonics
from repro.hardware import channel

SOURCES = channel.NOISE_SOURCES

_TINY = 1e-30


def _product(a, b, cfg, key, residual):
    """emulated_matmul's "ref" spine with the residual under explicit
    control: encode, bank product, rescale.  No ambient drift-state
    lookup — sole-source runs must not pick up the trainer's
    ``drift.use_state`` context."""
    a_n, b_n, s_a, s_b = photonics.normalise_operands(a, b, cfg)
    out = channel.bank_product(a_n, b_n, cfg, key, residual=residual)
    return out * (s_a * s_b)


def _power(x):
    return jnp.mean(jnp.square(x.astype(jnp.float32)))


def noise_budget(e, b, cfg, key, *, residual=None) -> dict:
    """Per-source error-power attribution for one panel product.

    e: (T, K) sampled error rows; b: (M, K) feedback bank; cfg: the emu
    session's ``PhotonicConfig``; key: a probe-owned key (never a
    training key); residual: the carried drift-cal residual, if any.
    -> flat dict of traceable scalar gauges (``nb_*``).
    """
    clean = _product(e, b, channel.ideal_twin(cfg), None, None)
    full = _product(e, b, cfg, key, residual)
    total = _power(full - clean)
    out = {"nb_total_var": total}
    acc = jnp.float32(0.0)
    for src in SOURCES:
        res = residual if src == "drift" else None
        # common random numbers BY DESIGN: every sole-source run must see
        # the same draw as the full run, so differences are purely the
        # source being toggled
        only = _product(e, b, channel.isolate_source(cfg, src), key, res)  # lint: disable=RL001
        power = _power(only - clean)
        out[f"nb_{src}_var"] = power
        acc = acc + power
    out["nb_sum_var"] = acc
    out["nb_closure"] = acc / jnp.maximum(total, _TINY)
    if cfg.noise_std > 0.0:
        _, _, s_a, s_b = photonics.normalise_operands(e, b, cfg)
        analytic = photonics.noise_sigma_total(e.shape[-1], s_a, s_b, cfg)
        out["nb_thermal_vs_analytic"] = (
            jnp.sqrt(out["nb_thermal_var"]) / jnp.maximum(analytic, _TINY))
    return out
