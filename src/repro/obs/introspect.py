"""In-situ DFA alignment telemetry: the interval-sampled probe behind
``TrainerConfig.probe_every`` / ``build_session(probe_every=)``.

The paper's training claim is *feedback alignment*: the fixed photonic
feedback banks only train the network if the DFA update progressively
aligns with the true gradient.  Loss curves cannot distinguish "aligning
slowly" from "alignment silently broken by analog noise" — this probe
can.  Every ``probe_every`` steps the Trainer calls ``AlignmentProbe``
on the step's own (state, batch) BEFORE the update runs and logs:

* ``align_<segment>`` — cosine between the DFA gradient and the exact
  BP gradient of the same batch, per parameter subtree (the paper's
  ref [29] predicts these grow during the align phase);
* ``align_global``   — the cosine over all compared leaves at once;
* ``gnorm_dfa_<s>`` / ``gnorm_bp_<s>`` — per-subtree gradient norms;
* ``upd_ratio_<s>``  — lr·‖g_dfa‖/‖p‖, the update/parameter norm ratio
  (the classic "is this layer actually moving" gauge);
* on stateful-hardware (emu) sessions, the ``nb_*`` noise-budget
  attribution of ``repro.obs.attribution`` for one sampled feedback
  panel product.

Contract with training (tested by tests/test_introspect.py):

* **No PRNG consumption.**  The probe re-derives the step's keys from
  ``(seed, step, name)`` exactly as ``Trainer._train_step`` does — pure
  function evaluation, nothing drawn from a carried stream — so
  probe-on and probe-off runs produce bit-identical training states.
* **No donation.**  The probe's jitted function never donates its
  inputs; the fit loop hands the same state buffers to the (donating)
  train step right after.
* **One batched drain.**  The probe returns device scalars; the fit
  loop pushes them through ``Observer.log_step`` (one ``device_get``).

Analytic anchor: with ideal photonics and the last segment's feedback
bank set to the head weights W (so B = W, δ = e·Bᵀ = e·Wᵀ — exactly
BP's cotangent at the last hidden output), the last segment's alignment
is identically 1.  Random feedback at init instead gives |cos| of order
1/√n_params.  Both are regression-tested.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from repro import algos
from repro.algos.dfa import tree_cosine
from repro.hardware import calibrate as hw_calibrate
from repro.hardware import drift as hw_drift
from repro.utils import prng


def _leaves32(tree):
    return [x.astype(jnp.float32) for x in jax.tree_util.tree_leaves(tree)]


def _norm(leaves):
    if not leaves:
        return jnp.float32(0.0)
    return jnp.sqrt(sum(jnp.vdot(x, x) for x in leaves).real)


def _resolve_lr(optimizer, opt_state):
    """Best-effort learning rate for the update/param ratio: a float
    ``lr`` attribute, a callable schedule evaluated at the optimizer
    step, else 1.0 (the ratio degrades to grad/param norm)."""
    lr = getattr(optimizer, "lr", None)
    if lr is None:
        return jnp.float32(1.0)
    if callable(lr):
        step = None
        if isinstance(opt_state, dict):
            step = opt_state.get("step")
        return jnp.float32(lr(step + 1)) if step is not None else jnp.float32(1.0)
    return jnp.float32(lr)


class AlignmentProbe:
    """Jit-once alignment probe bound to one Trainer.

    ``probe(state, batch)`` returns a flat dict of device scalars; the
    caller drains them (``Observer.log_step``).  The DFA side reuses the
    trainer's own value_and_grad (microbatch accumulation included) so
    the probed update is exactly the one training applies; the BP side
    is ``algos.get("bp")`` on the same model/batch.
    """

    def __init__(self, trainer, *, attribution_rows: int = 64):
        self._trainer = trainer
        cfg = trainer.cfg
        model = trainer.model
        self._bp_vg = algos.get("bp").value_and_grad(model, cfg.dfa)
        self._attribution = bool(
            getattr(trainer, "_hw_stateful", False)
            and cfg.dfa.photonics.mrr is not None)
        self._attribution_rows = int(attribution_rows)
        # jitted WITHOUT donation: the fit loop still owns `state`
        self._fn = jax.jit(self._probe_fn)

    # ---- the traced body ----
    def _probe_fn(self, state, batch):
        trainer = self._trainer
        cfg = trainer.cfg
        rng = prng.step_key(cfg.seed, state["step"], "noise")
        hw = state.get("hw")
        if hw is not None:
            # replay the train step's hardware advance so the probed DFA
            # gradient sees the same drift/calibration residual the real
            # update will — pure recomputation, the carried state is
            # untouched
            hw = hw_calibrate.advance(
                hw, cfg.dfa.photonics, state["step"],
                prng.step_key(cfg.seed, state["step"], "hardware"),
                recalibrate_every=cfg.recalibrate_every)
            hw_ctx = hw_drift.use_state(hw)
        else:
            hw_ctx = contextlib.nullcontext()
        with hw_ctx:
            (_, _), dfa_grads = trainer._grads(
                state["params"], state["fb"], batch, rng)
        # exact gradient of the same batch (BP's batch-mean IS the
        # microbatch average, so no accumulation needed on this side);
        # rng reuse is deliberate — BP must see the same step conditions
        # as the DFA pass it is compared against
        (_, _), bp_grads = self._bp_vg(state["params"], state["fb"], batch, rng)  # lint: disable=RL001

        out = {}
        lr = _resolve_lr(cfg.optimizer, state.get("opt"))
        all_dfa, all_bp = [], []
        for name in sorted(dfa_grads):
            if name not in bp_grads:
                continue
            d = _leaves32(dfa_grads[name])
            b = _leaves32(bp_grads[name])
            if not d or not b:
                continue  # parameter-free subtree (e.g. the MLP's embed)
            all_dfa += d
            all_bp += b
            gn_d, gn_b = _norm(d), _norm(b)
            out[f"align_{name}"] = tree_cosine(dfa_grads[name], bp_grads[name])
            out[f"gnorm_dfa_{name}"] = gn_d
            out[f"gnorm_bp_{name}"] = gn_b
            pn = _norm(_leaves32(state["params"][name]))
            out[f"upd_ratio_{name}"] = lr * gn_d / jnp.maximum(pn, 1e-12)
        num = sum(jnp.vdot(x, y) for x, y in zip(all_dfa, all_bp)).real
        out["align_global"] = num / jnp.maximum(
            _norm(all_dfa) * _norm(all_bp), 1e-12)

        if self._attribution:
            out.update(self._noise_budget(state, batch, hw))
        return out

    def _noise_budget(self, state, batch, hw):
        """One sampled feedback panel product through the sole-source
        decomposition of ``repro.obs.attribution`` — the probe's own key
        stream ("probe-nb"), never the training one."""
        from repro.algos import dfa as dfa_lib
        from repro.obs import attribution

        trainer = self._trainer
        cfg = trainer.cfg
        fwd = dfa_lib.forward_with_error(
            trainer.model, state["params"], cfg.dfa, batch)
        e = fwd["e_tap"].reshape(-1, fwd["e_tap"].shape[-1])
        e = e[: self._attribution_rows].astype(jnp.float32)
        last = trainer.model.segment_specs()[-1].name
        bmat = state["fb"][last][0].astype(jnp.float32)
        residual = hw_drift.residual(hw) if hw is not None else None
        key = prng.step_key(cfg.seed, state["step"], "probe-nb")
        return attribution.noise_budget(
            e, bmat, cfg.dfa.photonics, key, residual=residual)

    # ---- public entry ----
    def probe(self, state, batch) -> dict:
        """-> flat dict of device scalars for one (state, batch)."""
        return self._fn(state, batch)

    __call__ = probe
