"""Span/event tracing in the Chrome-trace (Perfetto) event model.

A ``TraceRecorder`` accumulates trace events host-side as plain dicts in
the Chrome Trace Event Format (the JSON `chrome://tracing` / Perfetto
load directly):

* ``span`` — a synchronous "X" (complete) event; nests naturally on one
  track when spans open and close LIFO (the context manager guarantees
  it).  Used for training steps, engine ticks, drain/log intervals.
* ``instant`` — an "i" event (recalibration sweeps, hwmon warnings).
* ``counter`` — a "C" event; Perfetto charts the value series (slot
  occupancy, queue depth, drift gauges).
* ``async_begin/instant/end`` — "b"/"n"/"e" events keyed by ``id``; each
  id renders as its own async track.  The serve engine gives every
  request one id, so a request's QUEUED→PREFILL→DECODE lifecycle is one
  horizontal track per request.
* ``complete`` — an "X" event with *explicit* timestamps, for timelines
  that do not run on this host's clock (the ``repro.sim`` discrete-event
  schedules export through this).

Timestamps are microseconds on a monotonic clock, zeroed at recorder
creation, so traces are immune to wall-clock steps and line up with the
engine/trainer ``time.monotonic`` measurements.  ``repro.obs.export``
serializes the recorder to a Perfetto-loadable JSON file.
"""

from __future__ import annotations

import contextlib
import time

# default pid/tid for host-side events; exporters claim other pids for
# simulated timelines so they land in separate process groups
HOST_PID = 1
HOST_TID = 1


class TraceRecorder:
    """Accumulates Chrome-trace events; see the module docstring."""

    def __init__(self):
        self.events: list[dict] = []
        self.t0 = time.monotonic()
        self._names: dict = {}  # (pid, tid|None) -> declared name

    # ---- clock ----
    def now_us(self) -> float:
        return (time.monotonic() - self.t0) * 1e6

    # ---- track naming (metadata events) ----
    def name_process(self, pid: int, name: str) -> None:
        if (pid, None) in self._names:
            return
        self._names[(pid, None)] = name
        self.events.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": name}})

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) in self._names:
            return
        self._names[(pid, tid)] = name
        self.events.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # ---- synchronous spans ----
    @contextlib.contextmanager
    def span(self, name: str, cat: str = "host", pid: int = HOST_PID,
             tid: int = HOST_TID, **args):
        start = self.now_us()
        try:
            yield self
        finally:
            self.complete(name, start, self.now_us() - start, cat=cat,
                          pid=pid, tid=tid, **args)

    def complete(self, name: str, ts_us: float, dur_us: float,
                 cat: str = "host", pid: int = HOST_PID, tid: int = HOST_TID,
                 **args) -> None:
        ev = {"name": name, "cat": cat, "ph": "X", "ts": ts_us,
              "dur": dur_us, "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ---- instants & counters ----
    def instant(self, name: str, cat: str = "host", pid: int = HOST_PID,
                tid: int = HOST_TID, ts_us: float | None = None,
                **args) -> None:
        ev = {"name": name, "cat": cat, "ph": "i",
              "ts": self.now_us() if ts_us is None else ts_us,
              "pid": pid, "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, values: dict, cat: str = "host",
                pid: int = HOST_PID, ts_us: float | None = None) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "C",
            "ts": self.now_us() if ts_us is None else ts_us,
            "pid": pid, "tid": 0,
            "args": {k: float(v) for k, v in values.items()}})

    # ---- async tracks (one per id) ----
    def _async(self, ph: str, name: str, track_id, cat: str,
               pid: int, ts_us: float | None, args: dict) -> None:
        ev = {"name": name, "cat": cat, "ph": ph, "id": track_id,
              "ts": self.now_us() if ts_us is None else ts_us,
              "pid": pid, "tid": HOST_TID}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def async_begin(self, name: str, track_id, cat: str = "async",
                    pid: int = HOST_PID, ts_us: float | None = None,
                    **args) -> None:
        self._async("b", name, track_id, cat, pid, ts_us, args)

    def async_instant(self, name: str, track_id, cat: str = "async",
                      pid: int = HOST_PID, ts_us: float | None = None,
                      **args) -> None:
        self._async("n", name, track_id, cat, pid, ts_us, args)

    def async_end(self, name: str, track_id, cat: str = "async",
                  pid: int = HOST_PID, ts_us: float | None = None,
                  **args) -> None:
        self._async("e", name, track_id, cat, pid, ts_us, args)

    # ---- serialization (see repro.obs.export) ----
    def to_chrome(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}
