"""``repro.obs`` — unified observability: metrics, tracing, hardware
health monitoring, and the diagnostics plane (alignment telemetry,
noise-budget attribution, anomaly detection) across train / serve / sim.

One ``Observer`` bundles the planes:

* ``metrics`` (``obs.metrics.Registry``) — counters / gauges /
  histograms fanned out to pluggable sinks (in-memory ring, JSONL file).
  Jit-safe by construction: device metrics are drained with ONE batched
  ``jax.device_get`` per logging interval (``Observer.log_step``), never
  one blocking transfer per scalar.
* ``trace`` (``obs.trace.TraceRecorder``) — Chrome-trace spans, instants,
  counters and per-request async tracks; ``obs.export`` writes the
  Perfetto-loadable JSON and renders ``repro.sim`` discrete-event
  timelines as per-bus stage tracks.
* ``hwmon`` (``obs.hwmon.HardwareMonitor``) — planned-vs-observed drift:
  the OU residual prediction for the run's recalibration cadence against
  the measured ``hw_residual_rms``, warn-level alerts when the PR 7
  autotuner's ``drift_budget`` is crossed, effective-bits and dead-ring
  gauges.  Attached only when the device actually drifts
  (``MRRConfig.stateful``) — a drift-free or abstract-noise session logs
  no ``hw_*`` rows.
* ``anomaly`` (``obs.anomaly.AnomalyDetector``) — EWMA + MAD bands over
  the drained rows (loss, alignment, ``hw_residual_rms``, throughput)
  firing edge-triggered ``WARN:anomaly:<metric>`` instants, so an
  alignment collapse or a dying bus is a *named* event in the trace and
  JSONL, not a flat curve.

The in-situ diagnostics themselves live beside this module:
``obs.introspect.AlignmentProbe`` (DFA-vs-BP alignment sampled every
``probe_every`` steps — ``build_session(probe_every=)``,
``launch/train.py --probe-every``) and ``obs.attribution.noise_budget``
(per-physical-source error decomposition on the emu backend, with the
analytic ``noise_sigma_total`` cross-check).

Wiring: ``api.build_session(observe=..., probe_every=...)`` /
``Session.fit(observer=)`` / ``Engine(observer=)``; ``launch/train.py``
and ``launch/serve.py`` expose ``--trace-out`` / ``--metrics-out``;
``python -m repro.obs.summarize`` renders a metrics JSONL back into
tables (alignment and noise-budget tables included);
``benchmarks/obs_overhead.py`` / ``benchmarks/alignment.py`` measure the
observer's and the probe's cost (BENCH_obs.json / BENCH_alignment.json,
CI-gated).

``NULL`` is the disabled-observer fast path: every method is a no-op and
``span`` returns one shared reusable context manager, so instrumented
code pays a constant few attribute lookups — no allocation — when
observability is off.
"""

from __future__ import annotations

import contextlib

from repro.obs import export
from repro.obs.anomaly import AnomalyAlert, AnomalyDetector
from repro.obs.hwmon import HardwareMonitor, HwAlert
from repro.obs.metrics import (Counter, Gauge, Histogram, JsonlSink,
                               MemorySink, Registry)
from repro.obs.trace import TraceRecorder


class Observer:
    """The bound (metrics, trace, hwmon, anomaly) bundle instrumented
    code talks to.

    All parts are optional; missing ones default to fresh in-memory
    instances (``hwmon`` to None — attach one via ``for_session`` or the
    constructor when the run carries hardware state; ``anomaly`` to a
    default-watch ``AnomalyDetector``).  ``metrics_path`` /
    ``trace_path`` add a JSONL sink / write the trace on ``close()``.
    """

    enabled = True

    def __init__(self, *, metrics: Registry | None = None,
                 trace: TraceRecorder | None = None,
                 hwmon: HardwareMonitor | None = None,
                 anomaly: AnomalyDetector | None = None,
                 metrics_path: str | None = None,
                 trace_path: str | None = None,
                 memory_capacity: int = 4096):
        if metrics is None:
            sinks: list = [MemorySink(memory_capacity)]
            if metrics_path:
                sinks.append(JsonlSink(metrics_path))
            metrics = Registry(sinks)
        elif metrics_path:
            metrics.sinks.append(JsonlSink(metrics_path))
        self.metrics = metrics
        self.trace = trace if trace is not None else TraceRecorder()
        self.hwmon = hwmon
        self.anomaly = anomaly if anomaly is not None else AnomalyDetector()
        self.trace_path = trace_path
        self._alerts_emitted = 0

    # ---- tracing passthrough ----
    def span(self, name: str, **args):
        return self.trace.span(name, **args)

    def event(self, name: str, **args) -> None:
        self.trace.instant(name, **args)

    def counter(self, name: str, values: dict) -> None:
        self.trace.counter(name, values)

    # ---- the per-logging-interval drain ----
    def log_step(self, step, device_metrics) -> dict:
        """Drain one interval's device metrics (single batched
        ``device_get`` inside ``Registry.record``), run the hardware
        monitor and the anomaly detector over the host scalars, chart the
        hw gauges as trace counters, and surface any new alert as a warn
        instant.  Returns the host-side scalar dict (hw gauges and
        anomaly flags merged in)."""
        host = self.metrics.drain(device_metrics)
        if self.hwmon is not None:
            gauges = self.hwmon.sample(step, host)
            if gauges:
                self.trace.counter("hwmon", gauges, cat="hwmon")
                host = {**host, **gauges}
            new = self.hwmon.alerts[self._alerts_emitted:]
            for alert in new:
                self.trace.instant(f"WARN:{alert.kind}", cat="hwmon",
                                   step=alert.step, value=alert.value,
                                   budget=alert.budget,
                                   message=alert.message)
                self.metrics.counter("hwmon_alerts").inc()
            self._alerts_emitted = len(self.hwmon.alerts)
        if self.anomaly is not None:
            for alert in self.anomaly.observe(step, host):
                self.trace.instant(f"WARN:anomaly:{alert.metric}",
                                   cat="anomaly", step=alert.step,
                                   value=alert.value, center=alert.center,
                                   band=alert.band, message=alert.message)
                self.metrics.counter("anomaly_alerts").inc()
                host = {**host, f"anomaly_{alert.metric}": 1.0}
        for k, v in host.items():
            self.metrics.gauge(k).set(v)
        self.metrics.emit(step, host)
        return host

    @property
    def alerts(self) -> list:
        """hwmon + anomaly alerts, in emission order per plane."""
        out: list = [] if self.hwmon is None else list(self.hwmon.alerts)
        if self.anomaly is not None:
            out.extend(self.anomaly.alerts)
        return out

    # ---- teardown ----
    def flush(self) -> None:
        """Push buffered sink bytes to disk — the fit/engine loops call
        this on the way out of an exception so an interrupted run still
        leaves parseable JSONL."""
        self.metrics.flush()

    def close(self) -> str | None:
        """Flush the sinks; write the trace when ``trace_path`` was given.
        Returns the trace path written (or None)."""
        self.metrics.close()
        if self.trace_path:
            return export.write(self.trace, self.trace_path)
        return None


class NullObserver:
    """Disabled observability: constant-cost no-ops, zero allocation.

    ``span`` hands back one shared reusable ``nullcontext`` and every
    other method returns immediately, so hot loops can call the observer
    unconditionally.
    """

    enabled = False
    _NULL_CTX = contextlib.nullcontext()

    def span(self, name: str, **args):
        return self._NULL_CTX

    def event(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, values: dict) -> None:
        pass

    def log_step(self, step, device_metrics) -> dict:
        return {}

    @property
    def alerts(self) -> list:
        return []

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL = NullObserver()


def resolve(observer) -> Observer | NullObserver:
    """``observer=`` argument -> something instrumented code can call:
    None/False -> the shared NULL fast path; True -> a fresh in-memory
    Observer; an Observer/NullObserver passes through."""
    if observer is None or observer is False:
        return NULL
    if observer is True:
        return Observer()
    return observer


def for_session(session, *, metrics_path: str | None = None,
                trace_path: str | None = None) -> Observer:
    """An ``Observer`` wired for one ``api.Session``: when the session's
    backend carries stateful hardware AND the device actually drifts
    (``MRRConfig.stateful``), a ``HardwareMonitor`` is attached with the
    session's device description, recalibration cadence, and — when the
    schedule autotuner planned one — its ``drift_budget``.  Drift-free
    devices (``emu_ideal``) and the ref/pallas backends get no monitor,
    so their rows carry no vacuous ``hw_*`` gauges."""
    hwmon = None
    cfg = session.config
    device = cfg.dfa.photonics.mrr
    if (getattr(session.trainer, "_hw_stateful", False)
            and device is not None and device.stateful):
        budget = None
        if session.schedule is not None:
            budget = getattr(session.schedule, "drift_budget", None)
        hwmon = HardwareMonitor(
            device, recalibrate_every=cfg.recalibrate_every,
            drift_budget=budget,
            n_failed_buses=len(cfg.dfa.photonics.failed_buses))
    return Observer(hwmon=hwmon, metrics_path=metrics_path,
                    trace_path=trace_path)


__all__ = [
    "AnomalyAlert", "AnomalyDetector", "Counter", "Gauge",
    "HardwareMonitor", "Histogram", "HwAlert", "JsonlSink", "MemorySink",
    "NULL", "NullObserver", "Observer", "Registry", "TraceRecorder",
    "export", "for_session", "resolve",
]
