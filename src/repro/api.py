"""``repro.api`` — the one-call facade over the algorithm × hardware ×
backend matrix.

The paper's experiment grid is three independent axes:

* **algo**     — a name in ``repro.algos`` (``bp`` | ``dfa`` | ``dfa-fused``
  | ``dfa-layerwise`` | anything registered later)
* **hardware** — a ``core.photonics`` preset name (``ideal`` |
  ``single_mrr`` | ``offchip_bpd`` | ``onchip_bpd`` | ``digital`` |
  ``emu_ideal`` | ``emu_offchip`` | ``emu_onchip``) or a
  ``PhotonicConfig`` instance
* **backend**  — how projections execute: ``auto`` | ``ref`` | ``pallas``
  | ``emu`` (or a ``PhotonicBackend`` instance)

The ``emu`` backend runs projections through the device-level MRR
emulation (``repro.hardware``): when the chosen hardware carries no
``MRRConfig`` the default device (drift ON) is attached, and
``recalibrate_every`` defaults to periodic in-situ recalibration so long
fits degrade — and recover — realistically.

Typical use::

    from repro import api

    session = api.build_session(arch="mnist_mlp", algo="dfa",
                                hardware="offchip_bpd")
    state, metrics = session.fit(data_fn, total_steps=512)
    session.evaluate(state, eval_batches)

``arch`` is a name from ``repro.configs`` (or an already-built DFAModel
instance).  Everything else is optional with paper-faithful defaults
(SGD momentum 0.9, lr 0.01 — the paper's §4 optimizer).

``schedule="auto"`` invokes the ``repro.sim`` autotuner: the fastest
(n_buses, tiling, f_s) for THIS model's DFA backward under
``power_budget_w`` is simulated from the emulator's real panel schedule
and applied to the session's photonics; the winning ``TunedSchedule``
(timeline report included) is kept on ``Session.schedule``.

``Session.engine()`` opens the serving plane on the same cell: a
continuous-batching ``serve.Engine`` whose forward projections run on
the session's photonic backend (``launch/serve.py`` is the CLI).
"""

from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp

from repro import algos, configs
from repro import obs as obs_lib
from repro.algos.dfa import DFAConfig
from repro.core import feedback as fb_lib
from repro.core import photonics
from repro.train import SGDM, Trainer, TrainerConfig


def resolve_hardware(hardware) -> photonics.PhotonicConfig:
    """Preset name or PhotonicConfig -> PhotonicConfig."""
    if isinstance(hardware, photonics.PhotonicConfig):
        return hardware
    return photonics.preset(hardware)


def build_model(arch, *, smoke: bool = False, dtype=jnp.float32):
    """Arch name (repro.configs) or a model instance -> DFAModel."""
    if not isinstance(arch, str):
        return arch  # already a model
    a = configs.get(arch)
    if smoke:
        return a.make_smoke()
    return a.make_model(dtype)


@dataclasses.dataclass
class Session:
    """A bound (model, algorithm, hardware, backend) cell of the matrix."""

    model: typing.Any
    algorithm: algos.Algorithm
    trainer: Trainer
    # the autotuned photonic schedule (repro.sim), when built with
    # schedule="auto"; None means the hardware config was taken as given
    schedule: typing.Any = None
    # the bound repro.obs.Observer when built with observe=... (or attached
    # later via Session.observe()); None means observability is off
    observer: typing.Any = None

    @property
    def config(self) -> TrainerConfig:
        return self.trainer.cfg

    # ---- observability ----
    def observe(self, *, metrics_path: str | None = None,
                trace_path: str | None = None):
        """Attach (and return) an ``obs.Observer`` wired for this session:
        hardware monitor on stateful-hw backends (with the autotuned
        ``drift_budget`` when a schedule was planned), optional JSONL
        metrics sink and trace output path.  ``fit``/``engine`` pick it
        up automatically."""
        self.observer = obs_lib.for_session(self, metrics_path=metrics_path,
                                            trace_path=trace_path)
        return self.observer

    # ---- training ----
    def init_state(self, key=None):
        return self.trainer.init_state(key)

    def step(self, state, batch):
        return self.trainer.step(state, batch)

    def fit(self, data_fn, total_steps: int, eval_fn=None, verbose: bool = True,
            timer=None, observer=None):
        """Run the training loop; under ``data_parallel`` the batch dim is
        sharded across all local devices (see train.Trainer).  ``timer`` is
        an optional ``repro.bench.StepTimer`` for throughput telemetry;
        ``observer`` an ``obs.Observer`` (defaults to the session's)."""
        return self.trainer.fit(data_fn, total_steps, eval_fn=eval_fn,
                                verbose=verbose, timer=timer,
                                observer=observer if observer is not None
                                else self.observer)

    @property
    def mesh(self):
        """The active data-parallel mesh (None on the single-device path)."""
        return self.trainer.mesh

    def step_cost(self, state, batch):
        """Per-device HLO cost of one train step (utils.hlo_cost)."""
        return self.trainer.step_cost(state, batch)

    # ---- gradients / eval ----
    def value_and_grad(self):
        """fn(params, extra_state, batch, rng) -> ((loss, metrics), grads)."""
        return self.algorithm.value_and_grad(self.model, self.config.dfa)

    def fused_step(self, optimizer=None):
        """Memory-optimised step (algorithm-specific; generic fallback)."""
        return self.algorithm.fused_step(
            self.model, self.config.dfa, optimizer or self.config.optimizer)

    def evaluate(self, state, batches) -> dict:
        return self.trainer.evaluate(state, batches)

    # ---- serving ----
    def engine(self, params=None, *, batch_slots: int = 8, max_len: int = 512,
               eos_id: int | None = None, prefill_chunk: int = 16,
               hw_state=None, seed: int = 0, observer=None):
        """A ``serve.Engine`` on this session's (hardware, backend) cell.

        The session's backend choice carries over: ``auto``/``ref`` with
        photonics disabled serves the exact digital forward; ``emu`` (or an
        enabled photonic config) routes every forward projection through
        the same banks training used — drift, crosstalk, quantisation and
        all.  ``params`` defaults to a fresh ``model.init``.
        """
        from repro.serve import Engine

        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        hw_cfg = self.config.dfa.photonics
        backend = self.config.dfa.backend
        if not isinstance(backend, str):
            backend = "ref"
        if backend == "auto" and hw_cfg.enabled:
            backend = "ref"
        if not hw_cfg.enabled:
            backend = None
        return Engine(self.model, params, batch_slots=batch_slots,
                      max_len=max_len, eos_id=eos_id,
                      prefill_chunk=prefill_chunk, backend=backend,
                      photonics=hw_cfg if backend is not None else None,
                      hw_state=hw_state, seed=seed,
                      observer=observer if observer is not None
                      else self.observer,
                      debug_checks=self.config.debug_checks)


def build_session(*, arch="mnist_mlp", algo: str = "dfa", hardware="ideal",
                  backend="auto", emu_kernel: str | None = None,
                  optimizer=None, seed: int = 0,
                  smoke: bool = False, dtype=jnp.float32,
                  error_compress: str = "none", freeze_norms: bool = False,
                  feedback: fb_lib.FeedbackConfig | None = None,
                  n_buses: int | None = None,
                  schedule: str | None = None,
                  power_budget_w: float | None = None,
                  schedule_batch: int | None = None,
                  microbatches: int = 1,
                  data_parallel: bool | str = "auto", prefetch: int = 2,
                  digital_step_s: float | None = None,
                  recalibrate_every: int | str | None = None,
                  ckpt_dir: str | None = None,
                  ckpt_every: int = 500, log_every: int = 50,
                  log_path: str | None = None,
                  step_deadline_s: float | None = None,
                  observe=False, probe_every: int | None = None,
                  debug_checks: bool = False) -> Session:
    """Compose one cell of the algorithm × hardware × backend matrix.

    ``observe``: ``False`` (default) runs without observability; ``True``
    attaches a session-wired ``obs.Observer`` (hardware monitor on
    stateful-hw backends); an ``Observer`` instance is taken as given.

    ``probe_every``: in-situ diagnostics cadence — every this many steps
    ``fit`` runs the ``obs.introspect.AlignmentProbe`` (DFA-vs-BP
    alignment per layer, grad norms, and on the emu backend the
    ``obs.attribution`` noise budget), logged as observer rows.  The
    default None keeps training bit-identical to an unprobed run.

    ``debug_checks``: opt into the ``repro.lint.runtime`` sanitizers — the
    train step (and any ``session.engine()``) runs under
    ``jax.experimental.checkify`` (NaN/Inf, div-by-zero, plus the emu
    channel's explicit finiteness checks) and a recompilation sentinel
    raises ``lint.RecompileError`` if a hot path retraces after warmup.
    """
    model = build_model(arch, smoke=smoke, dtype=dtype)
    algorithm = algos.get(algo)             # fail fast on unknown names
    backend_obj = photonics.get_backend(backend)  # (likewise for the backend)
    if emu_kernel is not None:
        # emu execution-path override ("ref" | "pallas" | "xla"): rebuild
        # the backend instance so the whole session (train + recalibrate)
        # runs the requested kernel.  Only meaningful on the emu backend.
        if not isinstance(backend_obj, photonics.EmulatedMRRBackend):
            raise ValueError(
                f"emu_kernel={emu_kernel!r} requires backend='emu', "
                f"got {backend_obj.name!r}")
        from repro.hardware.channel import resolve_emu_kernel

        resolve_emu_kernel(emu_kernel)      # fail fast on unknown specs
        backend_obj = dataclasses.replace(backend_obj, emu_kernel=emu_kernel)
        backend = backend_obj
    hw_cfg = resolve_hardware(hardware)
    if n_buses is not None:
        # multi-wavelength scale-out: override the preset's bus count
        hw_cfg = dataclasses.replace(hw_cfg, n_buses=n_buses)
    if backend_obj.stateful_hardware and hw_cfg.mrr is None:
        # device-level backend with an abstract hardware preset: attach the
        # default device description (drift ON) so the emulation has a bank
        # (before the schedule search so the autotuner sees the device too)
        from repro.hardware.mrr import MRRConfig

        hw_cfg = dataclasses.replace(hw_cfg, mrr=MRRConfig())
    tuned = None
    if schedule == "auto":
        # repro.sim schedule autotuning: search (n_buses, tiling, f_s) on
        # THIS model's DFA backward under the power budget and run the
        # session on the winner.  A caller-pinned n_buses narrows the
        # search to that bus count; schedule_batch is the nominal per-step
        # vector count the timelines stream (relative ranking is
        # batch-insensitive — fills and heater epilogues amortise).
        from repro import sim

        workload = sim.dfa_backward_workload(model, t=schedule_batch or 64)
        bus_counts = ((n_buses,) if n_buses is not None
                      else sim.DEFAULT_BUS_COUNTS)
        recal_candidates = (0,)
        drift_budget = None
        if recalibrate_every == "auto":
            # co-optimise the recalibration cadence: the heater sweep's
            # amortised sim-time cost trades against drift accuracy, held
            # under a budget of half the stationary drift (the regime
            # where BENCH_hardware's recovery curves keep DFA training)
            device = hw_cfg.mrr
            recal_candidates = sim.DEFAULT_RECAL_CANDIDATES
            if device is not None and device.drift_sigma > 0:
                drift_budget = 0.5 * device.drift_sigma
        # search only "panel" tilings: that is the layout the emulator
        # actually executes, so the applied (n_buses, f_s) is optimal for
        # the schedule the session will really run ("layer" projections
        # stay available through sim.autotune directly)
        tuned = sim.autotune(workload, hw_cfg,
                             power_budget_w=power_budget_w,
                             bus_counts=bus_counts, tilings=("panel",),
                             digital_s=digital_step_s or 0.0,
                             recal_candidates=recal_candidates,
                             drift_budget=drift_budget)
        hw_cfg = tuned.apply(hw_cfg)
        if recalibrate_every == "auto":
            recalibrate_every = tuned.recalibrate_every
    elif schedule is not None:
        raise ValueError(f"unknown schedule {schedule!r} (None | 'auto')")
    elif (power_budget_w is not None or schedule_batch is not None
          or digital_step_s is not None or recalibrate_every == "auto"):
        # these only steer the autotuner — accepting them without
        # schedule="auto" would silently enforce nothing
        raise ValueError("power_budget_w/schedule_batch/digital_step_s/"
                         "recalibrate_every='auto' require schedule='auto'")
    if recalibrate_every is None:
        # default cadence: in-situ recalibration on for any drifting device
        drifting = (backend_obj.stateful_hardware and hw_cfg.mrr is not None
                    and hw_cfg.mrr.stateful)
        recalibrate_every = 500 if drifting else 0
    dfa_cfg = DFAConfig(
        photonics=hw_cfg,
        feedback=feedback or fb_lib.FeedbackConfig(),
        error_compress=error_compress,
        backend=backend,
        freeze_norms=freeze_norms,
    )
    cfg = TrainerConfig(
        algo=algo, dfa=dfa_cfg,
        optimizer=optimizer or SGDM(lr=0.01, momentum=0.9),
        seed=seed, microbatches=microbatches,
        data_parallel=data_parallel, prefetch=prefetch,
        recalibrate_every=recalibrate_every,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        log_every=log_every, log_path=log_path,
        step_deadline_s=step_deadline_s,
        probe_every=probe_every,
        debug_checks=debug_checks,
    )
    session = Session(model=model, algorithm=algorithm,
                      trainer=Trainer(model, cfg), schedule=tuned)
    if observe is True:
        session.observe()
    elif observe:
        session.observer = observe
    return session
